"""End-to-end training driver: a small LM for a few hundred steps on the
host, through the full production stack (sharded step, deterministic data,
fault-tolerant checkpointed loop, watchdog).

    PYTHONPATH=src python examples/train_lm.py                # ~5 min CPU
    PYTHONPATH=src python examples/train_lm.py --steps 300 --wide

--wide uses a ~100M-parameter config (the task-spec scale; sized for real
accelerators — expect minutes/step on a 1-core CPU host).
"""
import argparse
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--wide", action="store_true",
                    help="~100M params instead of the CPU-sized default")
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    from repro.launch.train import main as train_main

    argv = ["--arch", args.arch, "--reduced", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--ckpt-dir", args.ckpt_dir,
            "--save-every", "50", "--log-every", "20"]
    if args.wide:
        # ~100M params: widen the reduced config via a custom registry entry
        import dataclasses
        from repro.configs import ARCHS, get_config
        cfg = dataclasses.replace(
            get_config(args.arch).reduced(), d_model=768, n_layers=12,
            n_heads=12, n_kv_heads=4, d_head=64, d_ff=3072,
            vocab_size=32000, name=args.arch + "-100m")
        ARCHS[cfg.name] = cfg
        argv[1] = cfg.name
        argv.remove("--reduced")
        print(f"wide config: ~{cfg.n_params()/1e6:.0f}M params")
    res = train_main(argv)
    losses = [h["loss"] for h in res.metrics_history if "loss" in h]
    print(f"\nfinal: loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps; restarts={res.restarts}")


if __name__ == "__main__":
    main()
