"""Quickstart: automatic offloading of the paper's three applications to a
mixed destination environment (paper Fig. 3 behaviour).

    PYTHONPATH=src python examples/quickstart.py [--full]

For each app the planner runs the six ordered verifications (FB->many-core,
FB->GPU, FB->FPGA, loops->many-core, loops->GPU, loops->FPGA analogues),
measures every candidate in the verification environment, checks result
equality against the single-core reference, and picks the fastest pattern
meeting the user target.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.apps import APPS
from repro.core.ga import GAConfig
from repro.core.measure import TimedRunner
from repro.core.planner import UserTarget, plan_offload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full paper sizes (slower)")
    ap.add_argument("--target-speedup", type=float, default=None)
    ap.add_argument("--max-price", type=float, default=None)
    ap.add_argument("--policy", default="host-time",
                    help="destination-selection policy "
                         "(repro.backends.policy): host-time (paper's "
                         "fastest-correct rule) | modeled (rank by "
                         "mesh-verified roofline when recorded) | "
                         "price-weighted | power (modeled joules per "
                         "step, repro.power) | edp (energy-delay "
                         "product)")
    args = ap.parse_args()

    target = UserTarget(target_speedup=args.target_speedup,
                        max_price=args.max_price)
    for name in ("3mm", "NAS.BT", "tdFIR"):
        app = APPS[name]()
        inputs = app.make_inputs(seed=0, small=not args.full)
        report = plan_offload(
            app, target, inputs=inputs, runner=TimedRunner(repeats=1),
            ga_cfg=GAConfig.for_gene_length(min(app.gene_length, 6),
                                            seed=0),
            policy=args.policy)
        print(f"\n=== {name} ===  single-core: "
              f"{report.ref_time_s*1e3:.2f} ms  [policy={report.policy}]"
              f"{'  (early stop)' if report.early_stopped else ''}")
        for r in report.records:
            mark = " <== selected" if r is report.selected else ""
            t = ("-" if r.best_time_s == float("inf")
                 else f"{r.best_time_s*1e3:8.2f} ms")
            measured = r.cache_stats.get("measured", r.n_measurements)
            reused = r.cache_stats.get("reused", 0)
            dedupe = f", reused {reused}" if reused else ""
            print(f"  {r.order}. {r.paper_analogue:14s} {r.method:15s} "
                  f"{t}  x{r.improvement:6.2f}  "
                  f"(measured {measured} patterns{dedupe}){mark}")
        sel = report.selected
        print(f"  offload pattern: "
              f"{ {k: v for k, v in sel.choice.items() if v != 'seq'} }")


if __name__ == "__main__":
    main()
