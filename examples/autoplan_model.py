import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

"""Framework-side offload search: the paper's GA over *execution-plan*
genes (sharding / remat / microbatching / compression) for an LM training
step, with the compiled-artifact roofline as the fitness measurement —
DESIGN.md §2's CompiledCostRunner verification environment.

    python examples/autoplan_model.py [--arch h2o-danube-1.8b]
"""
import argparse
import sys

sys.path.insert(0, "src")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--generations", type=int, default=4)
    ap.add_argument("--population", type=int, default=5)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.core.ga import Evaluation, GAConfig, run_ga
    from repro.core.measure import CompiledCostRunner
    from repro.dist.plan import Plan
    from repro.dist.sharding import Rules, tree_shardings
    from repro.launch.mesh import make_test_mesh
    from repro.models.lm import Model, param_axes
    from repro.train import optimizer, train_step as ts

    cfg = get_config(args.arch).reduced()
    shape = ShapeConfig("plan-search", 64, 16, "train")
    mesh = make_test_mesh((4, 2))
    tcfg = TrainConfig()
    runner = CompiledCostRunner(mesh)

    def evaluate(genes):
        plan = Plan.from_genes(list(genes))
        try:
            rules = Rules(mesh, plan)
            model = Model(cfg, plan, rules)
            params_sds = jax.eval_shape(
                model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
            p_sh = tree_shardings(rules, param_axes(cfg), params_sds)
            opt_sds = jax.eval_shape(lambda p: optimizer.init(p, tcfg),
                                     params_sds)
            batch_sds = {
                "tokens": jax.ShapeDtypeStruct(
                    (shape.global_batch, shape.seq_len), jnp.int32),
                "labels": jax.ShapeDtypeStruct(
                    (shape.global_batch, shape.seq_len), jnp.int32)}
            fn = ts.make_train_step(model, tcfg)
            jitted = jax.jit(fn, in_shardings=(p_sh, None, None, None))
            return runner.measure_lowered(
                jitted, params_sds, opt_sds, batch_sds,
                jax.ShapeDtypeStruct((), jnp.int32))
        except Exception as e:
            return Evaluation(time_s=float("inf"), correct=False,
                              info={"error": repr(e)[:200]})

    cards = Plan.gene_cardinalities()
    cfg_ga = GAConfig(population=args.population,
                      generations=args.generations, seed=0,
                      cardinalities=cards)
    res = run_ga(len(cards), evaluate, cfg_ga)
    best = Plan.from_genes(list(res.best_genes))
    print(f"\nbest plan for {args.arch} (modeled step "
          f"{res.best_eval.time_s*1e6:.1f} us on {mesh.shape}):")
    for name, _ in Plan.GENE_SPACE:
        print(f"  {name:22s} = {getattr(best, name)}")
    print(f"measured {res.n_measurements} compiled candidates")


if __name__ == "__main__":
    main()
