import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

"""Framework-side offload search: the paper's GA over *execution-plan*
genes (sharding / remat / microbatching / compression) for an LM training
step, with the compiled-artifact roofline as the fitness measurement —
DESIGN.md §2's CompiledCostRunner verification environment.

    python examples/autoplan_model.py [--arch h2o-danube-1.8b]
"""
import argparse
import sys

sys.path.insert(0, "src")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--generations", type=int, default=4)
    ap.add_argument("--population", type=int, default=5)
    ap.add_argument("--compile-workers", type=int, default=4,
                    help="threads tracing+compiling one generation's "
                         "unique structural candidates")
    ap.add_argument("--cache-dir", default="experiments/search_cache",
                    help="directory for the on-disk search-cache JSON "
                         "(repro.core.search_cache); a warm cache scores "
                         "repeat searches with zero XLA compiles")
    ap.add_argument("--no-disk-cache", action="store_true",
                    help="keep the search cache in memory only")
    ap.add_argument("--policy", default="modeled",
                    help="plan-selection policy (repro.backends.policy): "
                         "modeled / host-time rank pure modeled step time; "
                         "price-weighted weights each plan's per-device "
                         "memory traffic (a machine-size proxy); power / "
                         "edp rank the modeled joules per step of each "
                         "candidate's roofline under the mesh's TPU chip "
                         "envelope (repro.power)")
    args = ap.parse_args()

    from pathlib import Path

    import jax
    import jax.numpy as jnp

    from repro.backends import get_policy
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.core import search_cache as sc
    from repro.core.ga import GAConfig, run_ga
    from repro.core.measure import CompiledCostRunner
    from repro.dist.plan import Plan
    from repro.dist.sharding import Rules, tree_shardings
    from repro.launch import specs
    from repro.launch.mesh import make_test_mesh
    from repro.models.lm import Model, param_axes
    from repro.train import optimizer, train_step as ts

    cfg = get_config(args.arch).reduced()
    shape = ShapeConfig("plan-search", 64, 16, "train")
    # a pod axis so the pipeline-schedule genes have a destination.  The
    # schedule genes are scored by *model*: the compiled artifact stays the
    # dp/tp step (the verification machine cannot execute a pod-scale
    # pipeline — CompiledCostRunner's charter), and each candidate's step
    # time is stretched by the bubble its declared schedule would impose on
    # the pod ranks, so schedule/virtual_stages/microbatches trade off
    # inside one consistent modeled objective
    mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
    pipe_ranks = mesh.shape["pod"]
    tcfg = TrainConfig()
    runner = CompiledCostRunner(mesh)
    pol = get_policy(args.policy)

    def lower_plan(plan):
        """Trace + lower one plan candidate (no XLA compilation yet).

        Runs on the evaluator's worker pool: tracing is no longer a serial
        prefix of the generation, and only one candidate per unique
        structural key is ever traced.
        """
        rules = Rules(mesh, plan)
        model = Model(cfg, plan, rules)
        params_sds = jax.eval_shape(
            model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        p_sh = tree_shardings(rules, param_axes(cfg), params_sds)
        opt_sds = jax.eval_shape(lambda p: optimizer.init(p, tcfg),
                                 params_sds)
        batch_sds = specs.batch_specs(cfg, shape)   # arch-aware (mm extras)
        fn = ts.make_train_step(model, tcfg)
        jitted = jax.jit(fn, in_shardings=(p_sh, None, None, None))
        return jitted.lower(params_sds, opt_sds, batch_sds,
                            jax.ShapeDtypeStruct((), jnp.int32))

    # structure-keyed search cache: candidates are deduped by
    # Plan.structural_key() before tracing (the 3x2 schedule combinations
    # per structural plan share one compile), and the on-disk layer lets a
    # repeat search over the same (arch, shape, mesh) run with zero compiles
    cache_path = None if args.no_disk_cache else (
        Path(args.cache_dir) / f"autoplan-{args.arch}.json")
    cache = sc.SearchCache(cache_path)
    evaluate_batch = sc.make_cached_batch_evaluator(
        lower_plan, runner, cache,
        key_extra=("autoplan", args.arch, shape.name,
                   sc.mesh_fingerprint(mesh)),
        pipe_ranks=pipe_ranks, workers=args.compile_workers)

    cards = Plan.gene_cardinalities()
    cfg_ga = GAConfig(population=args.population,
                      generations=args.generations, seed=0,
                      cardinalities=cards)
    res = run_ga(len(cards), evaluate_batch.evaluate, cfg_ga,
                 evaluate_batch=evaluate_batch)

    # policy selection over every compiled candidate: price is proxied by
    # the plan's per-device memory traffic (relative to the leanest
    # candidate), so price-weighted prefers memory-lean plans when modeled
    # step time is close; power / edp rerank the GA front by the modeled
    # energy of each candidate's roofline (utilization x the mesh slice's
    # TPU chip envelope — a comm/bubble-heavy plan burns idle watts over a
    # longer step and loses even when its host ranking was close)
    from repro.core.candidates import Candidate
    from repro.power import cell_energy
    valid_bytes = [x.info["roofline"]["bytes_per_device"]
                   for x in res.evaluations.values()
                   if x.correct and "roofline" in x.info]
    base_bytes = max(min(valid_bytes), 1.0) if valid_bytes else 1.0

    def price_proxy(e):
        return e.info["roofline"]["bytes_per_device"] / base_bytes

    def cand_score(e):
        return pol.score_candidate(Candidate.from_roofline(
            e.info["roofline"], n_chips=mesh.size, price=price_proxy(e),
            time_s=e.time_s, backend="mesh", arch=args.arch, ref=e))

    scored = [(cand_score(e), genes, e)
              for genes, e in res.evaluations.items()
              if e.correct and "roofline" in e.info]
    if scored:
        _, best_genes, best_eval = min(scored, key=lambda s: s[0])
    else:
        best_genes, best_eval = res.best_genes, res.best_eval
    best = Plan.from_genes(list(best_genes))
    best_energy = ("roofline" in best_eval.info
                   and cell_energy(best_eval.info["roofline"], mesh.size))
    e_tag = (f", {best_energy.energy_j:.1f} J/step "
             f"@ {best_energy.avg_watts:.0f} W" if best_energy else "")
    print(f"\nbest plan for {args.arch} under policy={pol.name} "
          f"(modeled step {best_eval.time_s*1e6:.1f} us{e_tag} "
          f"on {mesh.shape}):")
    for gene in Plan.GENE_SPACE:
        tag = "" if gene.structural else "   [model-only]"
        print(f"  {gene.field:22s} = {getattr(best, gene.field)}{tag}")
    st = cache.stats
    print(f"scored {res.n_measurements} candidates | "
          f"unique compiles {st.unique_compiles} | "
          f"cache hit rate {st.hit_rate:.0%} "
          f"(disk {st.disk_hits}) | "
          f"compile time {st.compile_s:.1f}s")
    if cache_path is not None:
        print(f"search cache: {cache_path}")


if __name__ == "__main__":
    main()
