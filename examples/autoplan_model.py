import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

"""Framework-side offload search: the paper's GA over *execution-plan*
genes (sharding / remat / microbatching / compression) for an LM training
step, with the compiled-artifact roofline as the fitness measurement —
DESIGN.md §2's CompiledCostRunner verification environment.

    python examples/autoplan_model.py [--arch h2o-danube-1.8b]
"""
import argparse
import sys

sys.path.insert(0, "src")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--generations", type=int, default=4)
    ap.add_argument("--population", type=int, default=5)
    ap.add_argument("--compile-workers", type=int, default=4,
                    help="threads compiling one generation's candidates")
    ap.add_argument("--policy", default="modeled",
                    help="plan-selection policy (repro.backends.policy): "
                         "modeled / host-time rank pure modeled step time; "
                         "price-weighted / power also weight each plan's "
                         "per-device memory traffic (a machine-size / "
                         "power-envelope proxy)")
    args = ap.parse_args()

    import time
    from concurrent.futures import ThreadPoolExecutor

    import jax
    import jax.numpy as jnp

    from repro.backends import get_policy
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.core import cost_model
    from repro.core.ga import Evaluation, GAConfig, run_ga
    from repro.core.measure import CompiledCostRunner
    from repro.dist.plan import Plan
    from repro.dist.sharding import Rules, tree_shardings
    from repro.launch.mesh import make_test_mesh
    from repro.models.lm import Model, param_axes
    from repro.train import optimizer, train_step as ts

    cfg = get_config(args.arch).reduced()
    shape = ShapeConfig("plan-search", 64, 16, "train")
    # a pod axis so the pipeline-schedule genes have a destination.  The
    # schedule genes are scored by *model*: the compiled artifact stays the
    # dp/tp step (the verification machine cannot execute a pod-scale
    # pipeline — CompiledCostRunner's charter), and each candidate's step
    # time is stretched by the bubble its declared schedule would impose on
    # the pod ranks, so schedule/virtual_stages/microbatches trade off
    # inside one consistent modeled objective
    mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
    pipe_ranks = mesh.shape["pod"]
    tcfg = TrainConfig()
    runner = CompiledCostRunner(mesh)
    pol = get_policy(args.policy)

    def lower_candidate(genes):
        """Trace + lower one plan candidate (no XLA compilation yet)."""
        plan = Plan.from_genes(list(genes))
        rules = Rules(mesh, plan)
        model = Model(cfg, plan, rules)
        params_sds = jax.eval_shape(
            model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        p_sh = tree_shardings(rules, param_axes(cfg), params_sds)
        opt_sds = jax.eval_shape(lambda p: optimizer.init(p, tcfg),
                                 params_sds)
        batch_sds = {
            "tokens": jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len), jnp.int32)}
        fn = ts.make_train_step(model, tcfg)
        jitted = jax.jit(fn, in_shardings=(p_sh, None, None, None))
        return jitted.lower(params_sds, opt_sds, batch_sds,
                            jax.ShapeDtypeStruct((), jnp.int32))

    def evaluate_batch(generation):
        """Score a whole GA generation: lower every candidate first, then
        compile the lowered artifacts concurrently, then roofline-score —
        instead of the serial lower/compile/score per candidate."""
        lowered = []
        for genes in generation:
            bubble = cost_model.plan_bubble_fraction(
                Plan.from_genes(list(genes)), pipe_ranks)
            try:
                lowered.append((lower_candidate(genes), bubble))
            except Exception as e:
                lowered.append(Evaluation(time_s=float("inf"), correct=False,
                                          info={"error": repr(e)[:200]}))

        def compile_one(item):
            if isinstance(item, Evaluation):     # lowering already failed
                return item
            low, bubble = item
            try:
                t0 = time.perf_counter()
                compiled = low.compile()
                return runner.score_compiled(compiled,
                                             time.perf_counter() - t0,
                                             bubble_fraction=bubble)
            except Exception as e:
                return Evaluation(time_s=float("inf"), correct=False,
                                  info={"error": repr(e)[:200]})

        workers = max(1, min(args.compile_workers, len(lowered)))
        with ThreadPoolExecutor(max_workers=workers) as ex:
            return list(ex.map(compile_one, lowered))

    def evaluate(genes):
        return evaluate_batch([genes])[0]

    cards = Plan.gene_cardinalities()
    cfg_ga = GAConfig(population=args.population,
                      generations=args.generations, seed=0,
                      cardinalities=cards)
    res = run_ga(len(cards), evaluate, cfg_ga,
                 evaluate_batch=evaluate_batch)

    # policy selection over every compiled candidate: price is proxied by
    # the plan's per-device memory traffic (relative to the leanest
    # candidate), so price-weighted / power prefer memory-lean plans when
    # their modeled step time is close
    valid_bytes = [x.info["roofline"]["bytes_per_device"]
                   for x in res.evaluations.values()
                   if x.correct and "roofline" in x.info]
    base_bytes = max(min(valid_bytes), 1.0) if valid_bytes else 1.0

    def price_proxy(e):
        return e.info["roofline"]["bytes_per_device"] / base_bytes

    scored = [(pol.score_parts(e.time_s, price=price_proxy(e),
                               modeled_s=e.time_s), genes, e)
              for genes, e in res.evaluations.items()
              if e.correct and "roofline" in e.info]
    if scored:
        _, best_genes, best_eval = min(scored, key=lambda s: s[0])
    else:
        best_genes, best_eval = res.best_genes, res.best_eval
    best = Plan.from_genes(list(best_genes))
    print(f"\nbest plan for {args.arch} under policy={pol.name} "
          f"(modeled step {best_eval.time_s*1e6:.1f} us on {mesh.shape}):")
    for name, _ in Plan.GENE_SPACE:
        print(f"  {name:22s} = {getattr(best, name)}")
    print(f"measured {res.n_measurements} compiled candidates")


if __name__ == "__main__":
    main()
