"""Batched serving example: prefill + greedy decode across architectures,
including the attention-free and hybrid families.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-1.3b]
"""
import argparse
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="one arch id; default: a representative trio")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    from repro.launch.serve import main as serve_main

    archs = ([args.arch] if args.arch else
             ["granite-3-2b", "mamba2-1.3b", "recurrentgemma-2b"])
    for arch in archs:
        serve_main(["--arch", arch, "--batch", str(args.batch),
                    "--prompt-len", "32", "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
