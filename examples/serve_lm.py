"""Serving example: continuous-batching greedy decode across architectures,
including the attention-free and hybrid families.  Each arch runs through
``repro.serve.ContinuousBatcher`` (slot-pool decode, requests join/leave at
decode-step granularity); pass ``--trace N`` to replay a synthetic
open-loop arrival trace instead of one gang batch.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-1.3b]
    PYTHONPATH=src python examples/serve_lm.py --trace 6
"""
import argparse
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="one arch id; default: a representative trio")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--trace", type=int, default=0,
                    help="serve N staggered arrivals (open-loop trace)")
    args = ap.parse_args()

    from repro.launch.serve import main as serve_main

    archs = ([args.arch] if args.arch else
             ["granite-3-2b", "mamba2-1.3b", "recurrentgemma-2b"])
    for arch in archs:
        flags = ["--arch", arch, "--batch", str(args.batch),
                 "--prompt-len", "32", "--gen", str(args.gen)]
        if args.trace:
            flags += ["--trace", str(args.trace)]
        serve_main(flags)


if __name__ == "__main__":
    main()
