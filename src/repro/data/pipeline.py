"""Deterministic, shardable, checkpointable synthetic data pipeline.

Batches are a pure function of (seed, step) — the pipeline state is just the
step counter, so checkpoint/restore and elastic re-sharding are trivial and
exactly reproducible.  The token stream follows a noisy affine recurrence
(token_{t+1} = a*token_t + c + eps mod V), so a language model has real
structure to learn and training loss visibly decreases.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05
    # modality stubs
    n_img_tokens: int = 0
    n_frames: int = 0
    d_model: int = 0


class SyntheticTokens:
    """Stateless-by-construction LM data pipeline."""

    def __init__(self, cfg: DataConfig, sharding=None):
        self.cfg = cfg
        self.sharding = sharding
        self._gen = jax.jit(self._make_batch, static_argnums=())

    def _make_batch(self, step):
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        k0, k1, k2 = jax.random.split(key, 3)
        start = jax.random.randint(k0, (cfg.global_batch, 1), 0,
                                   cfg.vocab_size)
        a, c = 31, 17

        def step_fn(tok, eps):
            nxt = (a * tok + c + eps) % cfg.vocab_size
            return nxt, nxt

        eps = (jax.random.uniform(k1, (cfg.seq_len, cfg.global_batch, 1))
               < cfg.noise).astype(jnp.int32) * \
            jax.random.randint(k2, (cfg.seq_len, cfg.global_batch, 1), 0,
                               cfg.vocab_size)
        _, toks = jax.lax.scan(step_fn, start, eps)
        toks = jnp.swapaxes(toks[..., 0], 0, 1)        # [B, S]
        tokens = toks[:, :-1] if cfg.seq_len > 1 else toks
        labels = toks[:, 1:] if cfg.seq_len > 1 else toks
        # keep [B, seq_len] by regenerating length seq_len+1 semantics:
        tokens = jnp.pad(tokens, ((0, 0), (0, 1)))[:, :cfg.seq_len]
        labels = jnp.pad(labels, ((0, 0), (0, 1)))[:, :cfg.seq_len]
        batch = {"tokens": tokens, "labels": labels}
        if cfg.n_img_tokens:
            batch["img_embed"] = jax.random.normal(
                k1, (cfg.global_batch, cfg.n_img_tokens, cfg.d_model),
                jnp.float32)
        if cfg.n_frames:
            batch["frames"] = jax.random.normal(
                k2, (cfg.global_batch, cfg.n_frames, cfg.d_model),
                jnp.float32)
        return batch

    def batch(self, step: int) -> Dict[str, jax.Array]:
        b = self._gen(jnp.int32(step))
        if self.sharding is not None:
            b = {k: jax.device_put(v, self.sharding[k])
                 if k in self.sharding else v for k, v in b.items()}
        return b

    # --- checkpointable state ---
    def state_dict(self, step: int) -> dict:
        return {"step": int(step), "seed": self.cfg.seed}

    @staticmethod
    def resume_step(state: dict) -> int:
        return int(state["step"])


def data_config_for(cfg, shape, seed=0) -> DataConfig:
    return DataConfig(
        vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
        global_batch=shape.global_batch, seed=seed,
        n_img_tokens=cfg.n_img_tokens if cfg.family == "vlm" else 0,
        n_frames=cfg.n_frames if cfg.family == "audio" else 0,
        d_model=cfg.d_model)
