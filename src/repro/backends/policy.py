"""Selection policies: how the planner ranks verified destinations.

The paper selects the fastest correct pattern by measured host wall-clock
(``host-time``).  Yamato's follow-ups change the *objective* without
changing the pipeline — power-efficient selection (arXiv 2110.11520), cost
awareness — so the objective is a pluggable :class:`SelectionPolicy`:

  * ``host-time``       — today's behavior: min measured ``best_time_s``.
  * ``modeled``         — min ``mesh_time_s`` when a mesh verification
    recorded one (so dp/tp candidates are ranked by the compiled-artifact
    roofline, communication cost included), host time as fallback for
    destinations without a mesh analogue.
  * ``price-weighted``  — min ``best_time_s × price``: throughput per
    relative dollar, using the paper's price ordering.
  * ``power``           — min modeled joules per step (repro.power): the
    planner charges every correct record's energy against its backend's
    power envelope and this policy ranks the charge.
  * ``edp``             — min energy-delay product (``energy_j × time``):
    the compromise objective when pure joules would tolerate an arbitrary
    slowdown.

**The Candidate contract (PR 8).** Every consumer — ``plan_offload``
record selection, the serve-time :class:`~repro.serve.Router`, dryrun cell
ranking, the autoplan rerank, the fleet placement planner — builds
:class:`~repro.core.candidates.Candidate` objects and calls one entry
point: :meth:`SelectionPolicy.rank(candidates, power_budget_w=,
max_slowdown=)`.  :meth:`score_candidate` is the one ranking key a policy
implements; the pre-Candidate faces (``score`` / ``score_parts`` /
``score_cell``) survive as thin deprecation shims, and a *custom* policy
registered against them keeps working — ``score_candidate``'s default
bridges to whichever legacy face the subclass overrode (a Candidate quacks
like a ``VerificationRecord``, so the old arithmetic ranks it unchanged).

Selection constraints compose with any policy (:meth:`rank` /
:meth:`select`): ``power_budget_w`` drops candidates whose modeled average
draw exceeds the budget (the follow-up's "within allowed power" mode),
``max_slowdown`` drops candidates slower than the fastest correct one by
more than the factor ("power saving within allowed slowdown":
``plan_offload(policy="power", max_slowdown=1.3)``).

Every policy ranks only *correct, finite* candidates — a penalized wrong
result can never be the chosen destination, whatever the objective.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Union


def _modeled_or_host(cand) -> float:
    m = getattr(cand, "mesh_time_s", None)
    return m if m is not None else cand.best_time_s


class SelectionPolicy:
    """Rank candidates; lower ``score_candidate`` wins."""

    name: str = "base"

    # ------------------------------------------------------ canonical face
    def score_candidate(self, cand) -> float:
        """Ranking key for one :class:`~repro.core.candidates.Candidate`
        (or anything with its duck fields: ``correct`` / ``best_time_s`` /
        ``price`` / ``mesh_time_s`` / ``energy_j`` / ``avg_watts``).

        Built-in policies override this; the default bridges a *legacy*
        subclass — one that overrode ``score`` or ``score_parts`` before
        the Candidate refactor — by routing through its old face.
        """
        cls = type(self)
        if cls.score is not SelectionPolicy.score:
            return cls.score(self, cand)
        if cls.score_parts is not SelectionPolicy.score_parts:
            return cls.score_parts(self, cand.best_time_s,
                                   getattr(cand, "price", 1.0),
                                   getattr(cand, "mesh_time_s", None))
        raise NotImplementedError(
            f"{cls.__name__} must implement score_candidate "
            f"(or a legacy score/score_parts face)")

    # ------------------------------------------------- deprecated shims
    def score(self, record) -> float:
        """Deprecated shim (pre-Candidate face): rank one planner
        ``VerificationRecord``.  Records carry the Candidate duck fields,
        so this is :meth:`score_candidate` verbatim."""
        return self.score_candidate(record)

    def score_parts(self, time_s: float, price: float = 1.0,
                    modeled_s: Optional[float] = None) -> float:
        """Deprecated shim (pre-Candidate face): rank from raw parts."""
        from repro.core.candidates import Candidate
        return self.score_candidate(Candidate(
            best_time_s=time_s, price=price, mesh_time_s=modeled_s,
            source="parts"))

    def score_cell(self, step_time_s: float, price: float = 1.0,
                   energy: Optional[Dict] = None) -> float:
        """Deprecated shim (pre-Candidate face): rank one compiled mesh
        cell.  ``Candidate.from_cell`` is the replacement."""
        from repro.core.candidates import Candidate
        return self.score_candidate(Candidate.from_cell(
            step_time_s, n_chips=price, energy=energy))

    # -------------------------------------------------------- selection
    def rank(self, candidates: List, *,
             power_budget_w: Optional[float] = None,
             max_slowdown: Optional[float] = None) -> List:
        """Surviving candidates, best first (possibly empty) — THE
        selection entry point every consumer shares.

        ``power_budget_w`` keeps only candidates whose modeled
        ``avg_watts`` fits the budget (a candidate without a modeled draw
        is over budget by definition — an unknown draw cannot prove it
        fits).  ``max_slowdown`` keeps only candidates within the factor
        of the fastest surviving correct candidate's time.  A serve-time
        router falls through the returned order when the best endpoint has
        no free slot, without re-ranking.
        """
        done = [c for c in candidates
                if c.correct and c.best_time_s < float("inf")]
        if power_budget_w is not None:
            done = [c for c in done
                    if getattr(c, "avg_watts", None) is not None
                    and c.avg_watts <= power_budget_w]
        if max_slowdown is not None and done:
            fastest = min(c.best_time_s for c in done)
            done = [c for c in done
                    if c.best_time_s <= max_slowdown * fastest]
        return sorted(done, key=self.score_candidate)

    def select(self, candidates: List, *,
               power_budget_w: Optional[float] = None,
               max_slowdown: Optional[float] = None):
        """The winning candidate, or None when nothing is correct + finite
        (or nothing satisfies the constraints).  ``rank(...)[0]``."""
        ranked = self.rank(candidates, power_budget_w=power_budget_w,
                           max_slowdown=max_slowdown)
        return ranked[0] if ranked else None


class HostTimePolicy(SelectionPolicy):
    name = "host-time"

    def score_candidate(self, cand):
        return cand.best_time_s


class ModeledPolicy(SelectionPolicy):
    name = "modeled"

    def score_candidate(self, cand):
        return _modeled_or_host(cand)


class PriceWeightedPolicy(SelectionPolicy):
    name = "price-weighted"

    def score_candidate(self, cand):
        return cand.best_time_s * getattr(cand, "price", 1.0)


class PowerPolicy(SelectionPolicy):
    """Rank by modeled joules per step (repro.power.EnergyModel)."""

    name = "power"

    @staticmethod
    def _fallback_joules(cand) -> float:
        """Joule-scale charge for a candidate nothing charged (not produced
        by this build's plan_offload / Candidate constructors): the generic
        envelope at peak over the modeled-or-host time.  Keeping the unit
        in joules matters — a seconds-scale proxy would let every *unknown*
        draw outrank every modeled one in a mixed candidate set."""
        from repro.power import GENERIC
        return GENERIC.peak_w * _modeled_or_host(cand)

    def score_candidate(self, cand):
        e = getattr(cand, "energy_j", None)
        return e if e is not None else self._fallback_joules(cand)

    def score_parts(self, time_s, price=1.0, modeled_s=None):
        # deprecated shim; keeps the historical price scaling (a
        # machine-size stand-in) of the uncharged joule-scale fallback
        from repro.power import GENERIC
        t = modeled_s if modeled_s is not None else time_s
        return GENERIC.peak_w * t * price

    def score_cell(self, step_time_s, price=1.0, energy=None):
        if energy is not None:
            return self.score_candidate(__import__(
                "repro.core.candidates", fromlist=["Candidate"]
            ).Candidate.from_cell(step_time_s, n_chips=price, energy=energy))
        # deprecated shim, uncharged cell: same unit rule as
        # _fallback_joules, scaled by the cell's price (chip count) — an
        # unmodelled big slice must not under-score a modeled one
        from repro.power import GENERIC
        return GENERIC.peak_w * step_time_s * price


class EdpPolicy(SelectionPolicy):
    """Rank by the energy-delay product (joules × seconds per step)."""

    name = "edp"

    def score_candidate(self, cand):
        e = getattr(cand, "energy_j", None)
        if e is None:
            e = PowerPolicy._fallback_joules(cand)
        return e * _modeled_or_host(cand)

    def score_parts(self, time_s, price=1.0, modeled_s=None):
        # deprecated shim; see PowerPolicy.score_parts
        from repro.power import GENERIC
        t = modeled_s if modeled_s is not None else time_s
        return GENERIC.peak_w * t * t * price

    def score_cell(self, step_time_s, price=1.0, energy=None):
        if energy is not None:
            return energy["edp"]
        # deprecated shim, uncharged cell; see PowerPolicy.score_cell
        from repro.power import GENERIC
        return GENERIC.peak_w * step_time_s * step_time_s * price


POLICIES: Dict[str, SelectionPolicy] = {}


def register_policy(policy: SelectionPolicy) -> SelectionPolicy:
    POLICIES[policy.name] = policy
    return policy


for _p in (HostTimePolicy(), ModeledPolicy(), PriceWeightedPolicy(),
           PowerPolicy(), EdpPolicy()):
    register_policy(_p)

DEFAULT_POLICY = "host-time"


def get_policy(policy: Union[str, SelectionPolicy, None]) -> SelectionPolicy:
    """Resolve a policy name (or pass an instance through)."""
    if policy is None:
        return POLICIES[DEFAULT_POLICY]
    if isinstance(policy, SelectionPolicy):
        return policy
    try:
        return POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown selection policy {policy!r}; "
            f"known: {sorted(POLICIES)}") from None
