"""Selection policies: how the planner ranks verified destinations.

The paper selects the fastest correct pattern by measured host wall-clock
(``host-time``).  Yamato's follow-ups change the *objective* without
changing the pipeline — power-efficient selection (arXiv 2110.11520), cost
awareness — so the objective is a pluggable :class:`SelectionPolicy`:

  * ``host-time``       — today's behavior: min measured ``best_time_s``.
  * ``modeled``         — min ``mesh_time_s`` when a mesh verification
    recorded one (so dp/tp candidates are ranked by the compiled-artifact
    roofline, communication cost included), host time as fallback for
    destinations without a mesh analogue.
  * ``price-weighted``  — min ``best_time_s × price``: throughput per
    relative dollar, using the paper's price ordering.
  * ``power``           — min modeled joules per step (repro.power): the
    planner charges every correct record's energy against its backend's
    power envelope — roofline-utilization watts when a ``cost_runner``
    recorded a mesh roofline, envelope × host-time otherwise — and this
    policy ranks ``VerificationRecord.energy_j``.
  * ``edp``             — min energy-delay product (``energy_j × time``):
    the compromise objective when pure joules would tolerate an arbitrary
    slowdown.

Selection constraints compose with any policy (``SelectionPolicy.select``):
``power_budget_w`` drops records whose modeled average draw exceeds the
budget (the follow-up's "within allowed power" mode), ``max_slowdown``
drops records slower than the fastest correct one by more than the factor
(its "power saving within allowed slowdown" evaluation:
``plan_offload(policy="power", max_slowdown=1.3)``).

Every policy ranks only *correct, finite* records — a penalized wrong
result can never be the chosen destination, whatever the objective.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Union


class SelectionPolicy:
    """Rank verification records; lower ``score`` wins."""

    name: str = "base"

    def score_parts(self, time_s: float, price: float = 1.0,
                    modeled_s: Optional[float] = None) -> float:
        """Ranking key from raw parts.  Mesh cells are ranked through
        :meth:`score_cell` (repro.launch.dryrun, where ``price`` is the
        chip count), whose default delegates here; the energy policies
        override ``score_cell`` to consume the cell's modeled joules."""
        raise NotImplementedError

    def score(self, record) -> float:
        """Ranking key for a planner VerificationRecord (duck-typed:
        ``best_time_s`` / ``price`` / ``mesh_time_s`` / ``energy_j``)."""
        return self.score_parts(record.best_time_s, record.price,
                                getattr(record, "mesh_time_s", None))

    def score_cell(self, step_time_s: float, price: float = 1.0,
                   energy: Optional[Dict] = None) -> float:
        """Ranking key for one compiled artifact (a dryrun mesh cell or an
        autoplan GA candidate): modeled step time, relative price (chip
        count / memory-traffic proxy) and, when modeled, the cell's
        ``EnergyReport.to_dict()``."""
        return self.score_parts(step_time_s, price=price,
                                modeled_s=step_time_s)

    def rank(self, records: List, *,
             power_budget_w: Optional[float] = None,
             max_slowdown: Optional[float] = None) -> List:
        """Surviving records, best first (possibly empty).

        The constraint semantics of :meth:`select`, returning the full
        ranked list instead of only the winner — a serve-time router
        (repro.serve.router) falls through to the next-ranked destination
        when the best one has no free slot, without re-ranking.

        ``power_budget_w`` keeps only records whose modeled ``avg_watts``
        fits the budget (records without a modeled draw are over budget by
        definition — an unknown draw cannot prove it fits).
        ``max_slowdown`` keeps only records within the factor of the
        fastest surviving correct record's host time.
        """
        done = [r for r in records
                if r.correct and r.best_time_s < float("inf")]
        if power_budget_w is not None:
            done = [r for r in done
                    if getattr(r, "avg_watts", None) is not None
                    and r.avg_watts <= power_budget_w]
        if max_slowdown is not None and done:
            fastest = min(r.best_time_s for r in done)
            done = [r for r in done
                    if r.best_time_s <= max_slowdown * fastest]
        return sorted(done, key=self.score)

    def select(self, records: List, *,
               power_budget_w: Optional[float] = None,
               max_slowdown: Optional[float] = None):
        """The winning record, or None when nothing is correct + finite
        (or nothing satisfies the constraints).  ``rank(...)[0]``."""
        ranked = self.rank(records, power_budget_w=power_budget_w,
                           max_slowdown=max_slowdown)
        return ranked[0] if ranked else None


class HostTimePolicy(SelectionPolicy):
    name = "host-time"

    def score_parts(self, time_s, price=1.0, modeled_s=None):
        return time_s


class ModeledPolicy(SelectionPolicy):
    name = "modeled"

    def score_parts(self, time_s, price=1.0, modeled_s=None):
        return modeled_s if modeled_s is not None else time_s


class PriceWeightedPolicy(SelectionPolicy):
    name = "price-weighted"

    def score_parts(self, time_s, price=1.0, modeled_s=None):
        return time_s * price


class PowerPolicy(SelectionPolicy):
    """Rank by modeled joules per step (repro.power.EnergyModel)."""

    name = "power"

    @staticmethod
    def _fallback_joules(record) -> float:
        """Joule-scale charge for a record nothing charged (not produced by
        this build's plan_offload): the generic envelope at peak over the
        modeled-or-host time.  Keeping the unit in joules matters — a
        seconds-scale proxy would let every *unknown* draw outrank every
        modeled one in a mixed record set."""
        from repro.power import GENERIC
        t = getattr(record, "mesh_time_s", None)
        if t is None:
            t = record.best_time_s
        return GENERIC.peak_w * t

    def score(self, record):
        e = getattr(record, "energy_j", None)
        return e if e is not None else self._fallback_joules(record)

    def score_parts(self, time_s, price=1.0, modeled_s=None):
        # joule-scale like every other path of this policy: generic peak
        # draw, scaled by the relative price as a machine-size stand-in
        from repro.power import GENERIC
        t = modeled_s if modeled_s is not None else time_s
        return GENERIC.peak_w * t * price

    def score_cell(self, step_time_s, price=1.0, energy=None):
        if energy is not None:
            return energy["energy_j"]
        # same unit rule as _fallback_joules, scaled by the cell's price
        # (chip count): an unmodelled big slice must not under-score a
        # modeled one
        from repro.power import GENERIC
        return GENERIC.peak_w * step_time_s * price


class EdpPolicy(SelectionPolicy):
    """Rank by the energy-delay product (joules × seconds per step)."""

    name = "edp"

    def _delay(self, record):
        m = getattr(record, "mesh_time_s", None)
        return m if m is not None else record.best_time_s

    def score(self, record):
        e = getattr(record, "energy_j", None)
        if e is None:
            e = PowerPolicy._fallback_joules(record)
        return e * self._delay(record)

    def score_parts(self, time_s, price=1.0, modeled_s=None):
        from repro.power import GENERIC
        t = modeled_s if modeled_s is not None else time_s
        return GENERIC.peak_w * t * t * price

    def score_cell(self, step_time_s, price=1.0, energy=None):
        if energy is not None:
            return energy["edp"]
        from repro.power import GENERIC
        return GENERIC.peak_w * step_time_s * step_time_s * price


POLICIES: Dict[str, SelectionPolicy] = {}


def register_policy(policy: SelectionPolicy) -> SelectionPolicy:
    POLICIES[policy.name] = policy
    return policy


for _p in (HostTimePolicy(), ModeledPolicy(), PriceWeightedPolicy(),
           PowerPolicy(), EdpPolicy()):
    register_policy(_p)

DEFAULT_POLICY = "host-time"


def get_policy(policy: Union[str, SelectionPolicy, None]) -> SelectionPolicy:
    """Resolve a policy name (or pass an instance through)."""
    if policy is None:
        return POLICIES[DEFAULT_POLICY]
    if isinstance(policy, SelectionPolicy):
        return policy
    try:
        return POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown selection policy {policy!r}; "
            f"known: {sorted(POLICIES)}") from None
