"""Selection policies: how the planner ranks verified destinations.

The paper selects the fastest correct pattern by measured host wall-clock
(``host-time``).  Yamato's follow-ups change the *objective* without
changing the pipeline — power-efficient selection (arXiv 2110.11520), cost
awareness — so the objective is a pluggable :class:`SelectionPolicy`:

  * ``host-time``       — today's behavior: min measured ``best_time_s``.
  * ``modeled``         — min ``mesh_time_s`` when a mesh verification
    recorded one (so dp/tp candidates are ranked by the compiled-artifact
    roofline, communication cost included), host time as fallback for
    destinations without a mesh analogue.
  * ``price-weighted``  — min ``best_time_s × price``: throughput per
    relative dollar, using the paper's price ordering.
  * ``power``           — stub for the power-objective follow-up: energy is
    proxied as ``price × time`` (device price tracks its power envelope),
    preferring the modeled time when present.

Every policy ranks only *correct, finite* records — a penalized wrong
result can never be the chosen destination, whatever the objective.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Union


class SelectionPolicy:
    """Rank verification records; lower ``score`` wins."""

    name: str = "base"

    def score_parts(self, time_s: float, price: float = 1.0,
                    modeled_s: Optional[float] = None) -> float:
        """Ranking key from raw parts (also used by repro.launch.dryrun to
        rank mesh cells, where ``price`` is the chip count)."""
        raise NotImplementedError

    def score(self, record) -> float:
        """Ranking key for a planner VerificationRecord (duck-typed:
        ``best_time_s`` / ``price`` / ``mesh_time_s``)."""
        return self.score_parts(record.best_time_s, record.price,
                                getattr(record, "mesh_time_s", None))

    def select(self, records: List):
        """The winning record, or None when nothing is correct + finite."""
        done = [r for r in records
                if r.correct and r.best_time_s < float("inf")]
        return min(done, key=self.score) if done else None


class HostTimePolicy(SelectionPolicy):
    name = "host-time"

    def score_parts(self, time_s, price=1.0, modeled_s=None):
        return time_s


class ModeledPolicy(SelectionPolicy):
    name = "modeled"

    def score_parts(self, time_s, price=1.0, modeled_s=None):
        return modeled_s if modeled_s is not None else time_s


class PriceWeightedPolicy(SelectionPolicy):
    name = "price-weighted"

    def score_parts(self, time_s, price=1.0, modeled_s=None):
        return time_s * price


class PowerPolicy(SelectionPolicy):
    name = "power"

    def score_parts(self, time_s, price=1.0, modeled_s=None):
        t = modeled_s if modeled_s is not None else time_s
        return t * price


POLICIES: Dict[str, SelectionPolicy] = {}


def register_policy(policy: SelectionPolicy) -> SelectionPolicy:
    POLICIES[policy.name] = policy
    return policy


for _p in (HostTimePolicy(), ModeledPolicy(), PriceWeightedPolicy(),
           PowerPolicy()):
    register_policy(_p)

DEFAULT_POLICY = "host-time"


def get_policy(policy: Union[str, SelectionPolicy, None]) -> SelectionPolicy:
    """Resolve a policy name (or pass an instance through)."""
    if policy is None:
        return POLICIES[DEFAULT_POLICY]
    if isinstance(policy, SelectionPolicy):
        return policy
    try:
        return POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown selection policy {policy!r}; "
            f"known: {sorted(POLICIES)}") from None
