"""Built-in backends: the TPU-native mapping of the paper's {many-core CPU,
GPU, FPGA} mixed destination environment (DESIGN.md §2).

Price ordering follows the paper ("the central price range is the ascending
order of GPU, many core CPU and FPGA") and verification-time ordering too
("many core CPU, GPU and FPGA"); both are declared per backend and consumed
by the registry's derived order + the planner's early-stop logic, not their
absolute values.  Each backend also declares its power envelope
(repro.power): the planner charges every correct record's energy against
it, so the ``power`` / ``edp`` selection policies rank real modeled joules.

``GPU_LIBRARY`` is the function-blocks-only destination of Yamato's
"offloading to GPU libraries" follow-up (arXiv 2004.09883): offload
discovery happens purely by library/function-block matching, there is no
loop GA, so it declares ``methods=("function_block",)`` and the registry
slots it into the FB phase only.  It is not in ``DEFAULT_REGISTRY`` (the
paper's environment has three destinations); ``registry_with_library_
backend()`` is the example registration.
"""
from __future__ import annotations

from repro.backends.base import (Backend, METHOD_FUNCTION_BLOCK,
                                 SearchContext, SearchResult)
from repro.backends.registry import BackendRegistry
from repro.power import envelope as power_envelope


def ga_loop_search(backend: Backend, app, ctx: SearchContext) -> SearchResult:
    """Full-GA loop strategy (paper §II.B.1) — many-core CPU / GPU
    analogues."""
    from repro.core import loop_offload
    return loop_offload.ga_search(
        app, backend, ctx.runner, ctx.inputs, ctx.ref_out,
        fixed_choice=ctx.fixed_choice, ga_cfg=ctx.ga_cfg, seed=ctx.seed,
        lint_choice=ctx.lint_choice)


def intensity_loop_search(backend: Backend, app,
                          ctx: SearchContext) -> SearchResult:
    """Narrow-then-measure loop strategy (paper §II.B.3) — FPGA analogue:
    arithmetic-intensity narrowing, <= 4 measured patterns."""
    from repro.core import loop_offload
    return loop_offload.fpga_search(
        app, backend, ctx.runner, ctx.inputs, ctx.ref_out, ctx.small_state,
        fixed_choice=ctx.fixed_choice, penalty_s=ctx.penalty_s,
        lint_choice=ctx.lint_choice)


MANY_CORE = Backend(key="dp", name="xla_dp",
                    paper_analogue="many-core CPU",
                    price=1.2, verify_time=1.0, mesh_role="data",
                    power=power_envelope.MANY_CORE_XEON,
                    search_fn=ga_loop_search)
GPU = Backend(key="tp", name="sharded_tp", paper_analogue="GPU",
              price=1.0, verify_time=1.5, mesh_role="model",
              power=power_envelope.GPU_T4,
              search_fn=ga_loop_search)
FPGA = Backend(key="pallas", name="pallas_kernel",
               paper_analogue="FPGA",
               price=2.0, verify_time=10.0,
               power=power_envelope.FPGA_A10,
               search_fn=intensity_loop_search)

DEFAULT_REGISTRY = BackendRegistry([MANY_CORE, GPU, FPGA])

# Function-blocks-only destination (arXiv 2004.09883): no loop GA — the
# verification IS the library match, so verify_time sits below the GPU loop
# analogue's.  search_fn stays None: the registry never schedules it for a
# loop verification, and Backend.search raises if someone forces one.
GPU_LIBRARY = Backend(key="fb_gpu_lib", name="gpu_fb_library",
                      paper_analogue="GPU library",
                      price=1.0, verify_time=1.2,
                      methods=(METHOD_FUNCTION_BLOCK,),
                      power=power_envelope.GPU_T4)


def default_registry() -> BackendRegistry:
    return DEFAULT_REGISTRY


def registry_with_library_backend() -> BackendRegistry:
    """Example registration: the paper's three destinations plus the
    function-blocks-only GPU library backend (a fourth FB verification and
    no new loop verification — see tests/test_power.py)."""
    reg = DEFAULT_REGISTRY.copy()
    reg.register(GPU_LIBRARY)
    return reg
