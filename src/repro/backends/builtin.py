"""Built-in backends: the TPU-native mapping of the paper's {many-core CPU,
GPU, FPGA} mixed destination environment (DESIGN.md §2).

Price ordering follows the paper ("the central price range is the ascending
order of GPU, many core CPU and FPGA") and verification-time ordering too
("many core CPU, GPU and FPGA"); both are declared per backend and consumed
by the registry's derived order + the planner's early-stop logic, not their
absolute values.
"""
from __future__ import annotations

from repro.backends.base import Backend, SearchContext, SearchResult
from repro.backends.registry import BackendRegistry


def ga_loop_search(backend: Backend, app, ctx: SearchContext) -> SearchResult:
    """Full-GA loop strategy (paper §II.B.1) — many-core CPU / GPU
    analogues."""
    from repro.core import loop_offload
    return loop_offload.ga_search(
        app, backend, ctx.runner, ctx.inputs, ctx.ref_out,
        fixed_choice=ctx.fixed_choice, ga_cfg=ctx.ga_cfg, seed=ctx.seed)


def intensity_loop_search(backend: Backend, app,
                          ctx: SearchContext) -> SearchResult:
    """Narrow-then-measure loop strategy (paper §II.B.3) — FPGA analogue:
    arithmetic-intensity narrowing, <= 4 measured patterns."""
    from repro.core import loop_offload
    return loop_offload.fpga_search(
        app, backend, ctx.runner, ctx.inputs, ctx.ref_out, ctx.small_state,
        fixed_choice=ctx.fixed_choice, penalty_s=ctx.penalty_s)


MANY_CORE = Backend(key="dp", name="xla_dp",
                    paper_analogue="many-core CPU",
                    price=1.2, verify_time=1.0, mesh_role="data",
                    search_fn=ga_loop_search)
GPU = Backend(key="tp", name="sharded_tp", paper_analogue="GPU",
              price=1.0, verify_time=1.5, mesh_role="model",
              search_fn=ga_loop_search)
FPGA = Backend(key="pallas", name="pallas_kernel",
               paper_analogue="FPGA",
               price=2.0, verify_time=10.0,
               search_fn=intensity_loop_search)

DEFAULT_REGISTRY = BackendRegistry([MANY_CORE, GPU, FPGA])


def default_registry() -> BackendRegistry:
    return DEFAULT_REGISTRY
