"""Offload-backend protocol: one object per destination bundling identity,
search strategy and mesh-verification hook (paper §II.C made pluggable).

A :class:`Backend` is everything the planner needs to know about one offload
destination:

  * identity — ``key`` (impl key inside ``LoopNest.impls``), ``name``,
    ``paper_analogue``, ``price`` and ``verify_time`` (the paper's relative
    price / verification-cost orderings), ``mesh_role`` (consumed by
    ``repro.dist.bridge``);
  * ``search(app, ctx, method)`` — the verification strategy for this
    destination: a generic function-block apply+measure for
    ``method="function_block"`` and a destination-specific loop search
    (GA, intensity narrowing, …) for ``method="loop"``;
  * ``mesh_verify(cost_runner, fn, inputs)`` — optional hook compiling the
    winning candidate for a real mesh and returning a modeled
    :class:`~repro.core.ga.Evaluation` (None when the destination has no
    mesh analogue).

New destinations are *registered* (``BackendRegistry.register``), not added
to a hardcoded enum — the planner iterates whatever order the registry
derives from the declared ``verify_time`` values (repro.backends.registry).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Tuple

METHOD_FUNCTION_BLOCK = "function_block"
METHOD_LOOP = "loop"
# FB verifications run before loop verifications (paper §II.C: an FB match,
# when one exists, is usually the faster pattern and enables early stop).
METHOD_ORDER: Tuple[str, ...] = (METHOD_FUNCTION_BLOCK, METHOD_LOOP)


@dataclass
class SearchResult:
    """Outcome of one verification (field layout kept compatible with the
    pre-registry ``LoopSearchResult``)."""
    destination: str
    best_choice: Dict[str, str]
    best_time_s: float
    n_measurements: int
    verify_elapsed_s: float
    history: List[dict] = field(default_factory=list)
    note: str = ""
    best_correct: bool = True     # False: best_time_s is a penalty, not a
                                  # usable pattern (planner must not select)
    # verification-cost counters ({"measured": ..., "reused": ...} for the
    # loop GA's choice-keyed measurement memo; search-cache stats for
    # compiled paths) — observability only, never selection input
    cache_stats: Dict = field(default_factory=dict)


@dataclass
class SearchContext:
    """Verification-environment state shared by every backend in one
    ``plan_offload`` run."""
    runner: Any                            # TimedRunner-like
    inputs: Any
    ref_out: Any
    small_state: Any = None
    fixed_choice: Dict[str, str] = field(default_factory=dict)  # residual rule
    ga_cfg: Any = None                     # GAConfig | None
    penalty_s: Optional[float] = None
    seed: int = 0
    fb_matches: list = field(default_factory=list)   # function-block matches
    # static choice linter (repro.analysis): (choice dict) -> findings.
    # Loop searches reject any choice with an error-severity finding for
    # the penalty without building or measuring it (prune before compile).
    lint_choice: Optional[Callable[[Dict[str, str]], list]] = None

    def measure(self, app, choice: Dict[str, str]):
        """Measure one choice dict, stamping the run's penalty scale."""
        ev = self.runner.measure(app.build(choice), self.inputs, self.ref_out)
        if self.penalty_s is not None:
            ev.penalty_s = self.penalty_s
        return ev


def generic_fb_search(backend: "Backend", app, ctx: SearchContext
                      ) -> SearchResult:
    """Default function-block strategy: apply the registry matches for this
    backend's impl key and measure the resulting pattern (paper [41])."""
    from repro.core import function_blocks

    t0 = time.perf_counter()
    choice = function_blocks.apply_matches(app, ctx.fb_matches, backend.key)
    if choice is None:
        return SearchResult(
            destination=backend.name, best_choice={},
            best_time_s=float("inf"), n_measurements=0,
            verify_elapsed_s=time.perf_counter() - t0,
            note="no offloadable function block")
    ev = ctx.measure(app, choice)
    note = "; ".join(f"{m.entry.name}@{m.nest.name}({m.method}"
                     f":{m.score:.2f})" for m in ctx.fb_matches)
    return SearchResult(
        destination=backend.name, best_choice=dict(choice),
        best_time_s=ev.effective_time, n_measurements=1,
        verify_elapsed_s=time.perf_counter() - t0, note=note,
        best_correct=ev.correct)


def bridge_mesh_verify(backend: "Backend", cost_runner, fn, inputs):
    """Default mesh hook: delegate to the planner<->mesh bridge, which reads
    ``backend.mesh_role`` ("data" | "model" | "")."""
    from repro.dist import bridge
    return bridge.mesh_verify(cost_runner, backend, fn, inputs)


@dataclass(frozen=True)
class Backend:
    """One offload destination: identity + search strategy + mesh hook."""
    key: str              # impl key inside LoopNest.impls
    name: str
    paper_analogue: str
    price: float          # relative $ (paper ordering: GPU < many-core < FPGA)
    verify_time: float    # relative verification cost (CPU < GPU < FPGA);
                          # the registry derives the paper's order from it
    # mesh analogue consumed by repro.dist.bridge: "data" verifications
    # compile data-parallel, "model" tensor-parallel, "" has no mesh bridge
    # (the FPGA analogue is a kernel substitution, not a sharding).
    mesh_role: str = ""
    # power envelope (repro.power.PowerEnvelope) the planner charges this
    # destination's energy against; None resolves through
    # repro.power.envelope_for (built-in calibration by paper_analogue,
    # generic fallback)
    power: Optional[Any] = None
    # which verification methods this backend participates in
    methods: Tuple[str, ...] = METHOD_ORDER
    # strategies; (backend, app, ctx) -> SearchResult.  fb_search_fn defaults
    # to the generic registry apply+measure; search_fn has no default — a
    # loop-capable backend must declare how it searches.
    search_fn: Optional[Callable] = None
    fb_search_fn: Callable = generic_fb_search
    # (backend, cost_runner, fn, inputs) -> Evaluation | None
    mesh_verify_fn: Callable = bridge_mesh_verify

    def search(self, app, ctx: SearchContext,
               method: str = METHOD_LOOP) -> SearchResult:
        if method == METHOD_FUNCTION_BLOCK:
            return self.fb_search_fn(self, app, ctx)
        if method == METHOD_LOOP:
            if self.search_fn is None:
                raise NotImplementedError(
                    f"backend {self.name!r} declares no loop search strategy")
            return self.search_fn(self, app, ctx)
        raise ValueError(f"unknown verification method {method!r}")

    def mesh_verify(self, cost_runner, fn, inputs):
        if self.mesh_verify_fn is None:
            return None
        return self.mesh_verify_fn(self, cost_runner, fn, inputs)

    def with_(self, **changes) -> "Backend":
        """Frozen-dataclass convenience: a copy with fields replaced."""
        return replace(self, **changes)
