"""Backend registry: derives the paper's verification order from declared
backend metadata instead of a hardcoded list.

Paper §II.C runs the verifications function-block first, then loops, and
within each method in ascending verification-cost order (many-core CPU, GPU,
FPGA).  The registry reproduces exactly that from each backend's
``verify_time`` and ``methods`` declarations, so registering a new backend
slots it into the order automatically — no planner surgery.
"""
from __future__ import annotations

from typing import Dict, Iterable, Iterator, List, Optional, Tuple

from repro.backends.base import Backend, METHOD_ORDER


class BackendRegistry:
    def __init__(self, backends: Iterable[Backend] = ()):
        self._backends: List[Backend] = []
        for b in backends:
            self.register(b)

    # ------------------------------------------------------------ mutation
    def register(self, backend: Backend, *, replace: bool = False) -> Backend:
        """Add a backend; ``replace=True`` swaps an existing one by key."""
        existing = {b.key: i for i, b in enumerate(self._backends)}
        if backend.key in existing:
            if not replace:
                raise ValueError(
                    f"backend key {backend.key!r} already registered "
                    f"(pass replace=True to swap it)")
            self._backends[existing[backend.key]] = backend
        else:
            self._backends.append(backend)
        return backend

    def copy(self) -> "BackendRegistry":
        """A shallow copy tests can extend without mutating the default."""
        return BackendRegistry(self._backends)

    # ------------------------------------------------------------- queries
    def __iter__(self) -> Iterator[Backend]:
        return iter(self._backends)

    def __len__(self) -> int:
        return len(self._backends)

    def get(self, key: str) -> Optional[Backend]:
        return next((b for b in self._backends if b.key == key), None)

    @property
    def by_name(self) -> Dict[str, Backend]:
        return {b.name: b for b in self._backends}

    @property
    def by_analogue(self) -> Dict[str, Backend]:
        return {b.paper_analogue: b for b in self._backends}

    # ---------------------------------------------------------------- order
    def verification_order(self) -> List[Tuple[Backend, str]]:
        """(backend, method) pairs in the order the planner verifies them.

        Methods run in ``METHOD_ORDER`` (FB phase, then loop phase); within a
        phase, backends ascend by ``verify_time`` (stable: registration order
        breaks ties).  For the three built-in backends this reproduces the
        paper's six verifications exactly.
        """
        order: List[Tuple[Backend, str]] = []
        for method in METHOD_ORDER:
            phase = [b for b in self._backends if method in b.methods]
            phase.sort(key=lambda b: b.verify_time)
            order.extend((b, method) for b in phase)
        return order
