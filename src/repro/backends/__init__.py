"""Pluggable offload-backend API (paper §II.C as configuration).

Public surface (stable — later PRs build on this):

  * :mod:`repro.backends.base`     — :class:`Backend` (identity + ``search``
    strategy + ``mesh_verify`` hook), :class:`SearchContext`,
    :class:`SearchResult`.
  * :mod:`repro.backends.registry` — :class:`BackendRegistry`; its
    ``verification_order()`` derives the paper's six-verification order from
    declared ``verify_time`` / ``methods``.
  * :mod:`repro.backends.builtin`  — the three built-in backends
    (``MANY_CORE``, ``GPU``, ``FPGA``, each carrying its repro.power
    envelope), ``DEFAULT_REGISTRY``, plus the function-blocks-only
    ``GPU_LIBRARY`` example backend (arXiv 2004.09883) and
    ``registry_with_library_backend()``.
  * :mod:`repro.backends.policy`   — :class:`SelectionPolicy` and the
    built-in objectives (``host-time``, ``modeled``, ``price-weighted``,
    ``power`` — modeled joules via repro.power — and ``edp``), the
    ``power_budget_w`` / ``max_slowdown`` selection constraints;
    ``get_policy`` / ``register_policy``.

``repro.core.destinations`` remains a thin compatibility shim over this
package (``ALL`` / ``VERIFICATION_ORDER`` / ``Destination``).
"""
from repro.backends.base import (Backend, SearchContext, SearchResult,
                                 METHOD_FUNCTION_BLOCK, METHOD_LOOP,
                                 METHOD_ORDER)
from repro.backends.registry import BackendRegistry
from repro.backends.builtin import (DEFAULT_REGISTRY, FPGA, GPU, GPU_LIBRARY,
                                    MANY_CORE, default_registry,
                                    registry_with_library_backend)
from repro.backends.policy import (DEFAULT_POLICY, POLICIES, SelectionPolicy,
                                   EdpPolicy, HostTimePolicy, ModeledPolicy,
                                   PowerPolicy, PriceWeightedPolicy,
                                   get_policy, register_policy)

__all__ = [
    "Backend", "SearchContext", "SearchResult",
    "METHOD_FUNCTION_BLOCK", "METHOD_LOOP", "METHOD_ORDER",
    "BackendRegistry", "DEFAULT_REGISTRY", "default_registry",
    "MANY_CORE", "GPU", "FPGA", "GPU_LIBRARY",
    "registry_with_library_backend",
    "SelectionPolicy", "HostTimePolicy", "ModeledPolicy",
    "PriceWeightedPolicy", "PowerPolicy", "EdpPolicy",
    "POLICIES", "DEFAULT_POLICY", "get_policy", "register_policy",
]
