"""Pluggable offload-backend API (paper §II.C as configuration).

Public surface (stable — later PRs build on this):

  * :mod:`repro.backends.base`     — :class:`Backend` (identity + ``search``
    strategy + ``mesh_verify`` hook), :class:`SearchContext`,
    :class:`SearchResult`.
  * :mod:`repro.backends.registry` — :class:`BackendRegistry`; its
    ``verification_order()`` derives the paper's six-verification order from
    declared ``verify_time`` / ``methods``.
  * :mod:`repro.backends.builtin`  — the three built-in backends
    (``MANY_CORE``, ``GPU``, ``FPGA``) and ``DEFAULT_REGISTRY``.
  * :mod:`repro.backends.policy`   — :class:`SelectionPolicy` and the
    built-in objectives (``host-time``, ``modeled``, ``price-weighted``,
    ``power``); ``get_policy`` / ``register_policy``.

``repro.core.destinations`` remains a thin compatibility shim over this
package (``ALL`` / ``VERIFICATION_ORDER`` / ``Destination``).
"""
from repro.backends.base import (Backend, SearchContext, SearchResult,
                                 METHOD_FUNCTION_BLOCK, METHOD_LOOP,
                                 METHOD_ORDER)
from repro.backends.registry import BackendRegistry
from repro.backends.builtin import (DEFAULT_REGISTRY, FPGA, GPU, MANY_CORE,
                                    default_registry)
from repro.backends.policy import (DEFAULT_POLICY, POLICIES, SelectionPolicy,
                                   HostTimePolicy, ModeledPolicy,
                                   PowerPolicy, PriceWeightedPolicy,
                                   get_policy, register_policy)

__all__ = [
    "Backend", "SearchContext", "SearchResult",
    "METHOD_FUNCTION_BLOCK", "METHOD_LOOP", "METHOD_ORDER",
    "BackendRegistry", "DEFAULT_REGISTRY", "default_registry",
    "MANY_CORE", "GPU", "FPGA",
    "SelectionPolicy", "HostTimePolicy", "ModeledPolicy",
    "PriceWeightedPolicy", "PowerPolicy",
    "POLICIES", "DEFAULT_POLICY", "get_policy", "register_policy",
]
