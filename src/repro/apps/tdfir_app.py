"""HPEC tdFIR: time-domain FIR filter bank (paper §III.A: 64 filters,
4096-length vectors, complex data as planar re/im).

The FIR nest is the paper's function-block offload target: the registry
entry in ``repro.apps.registry`` matches it by name ("tdfir") and by jaxpr
similarity, and supplies the Pallas kernel (FPGA analogue) plus XLA
implementations as replacements — reproducing the tdFIR row of Fig. 3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.offloadable import LoopNest, OffloadableApp
from repro.kernels import tdfir as fir_kernel

N_FILTERS = 64
N_LEN_FULL = 4096
N_LEN_SMALL = 256
N_TAPS = 128
N_TAPS_SMALL = 16


def make_inputs(seed: int = 0, small: bool = False):
    n = N_LEN_SMALL if small else N_LEN_FULL
    taps = N_TAPS_SMALL if small else N_TAPS
    f = 8 if small else N_FILTERS
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "x_re": jax.random.normal(k1, (f, n), jnp.float32),
        "x_im": jax.random.normal(k2, (f, n), jnp.float32),
        "h_re": jax.random.normal(k3, (f, taps), jnp.float32) * 0.1,
        "h_im": jax.random.normal(k4, (f, taps), jnp.float32) * 0.1,
    }


def _fir_seq_1(x, h):
    """Single-filter FIR as the C loop nest: output-sample loop."""
    n = x.shape[0]
    k = h.shape[0]
    xp = jnp.pad(x, (k - 1, 0))

    def sample(_, i):
        window = jax.lax.dynamic_slice(xp, (i,), (k,))
        return None, jnp.dot(window, h[::-1])

    _, y = jax.lax.scan(sample, None, jnp.arange(n))
    return y


def _complex_fir(fn):
    def run(state):
        rr = fn(state["x_re"], state["h_re"])
        ii = fn(state["x_im"], state["h_im"])
        ri = fn(state["x_re"], state["h_im"])
        ir = fn(state["x_im"], state["h_re"])
        return dict(state, y_re=rr - ii, y_im=ri + ir)
    return run


def _fir_xla(x, h):
    """Vectorized causal FIR via conv (the parallelized XLA path)."""
    k = h.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0)))[:, None, :]   # [F,1,N+K-1]
    hf = h[:, None, ::-1]                               # [F,1,K]
    out = jax.lax.conv_general_dilated(
        xp, hf, window_strides=(1,), padding="VALID",
        feature_group_count=x.shape[0],
        dimension_numbers=("CNH", "OIH", "CNH"))
    return out[:, 0, :]


def _fir_pallas(x, h):
    return fir_kernel.tdfir(x, h, block_n=max(128, h.shape[1]),
                            interpret=True)


def _fir_nest():
    def seq(state):
        return _complex_fir(
            lambda x, h: jax.vmap(_fir_seq_1)(x, h))(state)

    # NOTE: seq here still vmaps across filters (a C loop over 64 filters
    # adds nothing on one core); the sequential structure is the
    # per-output-sample loop, faithful to the C kernel.
    return LoopNest(
        name="tdfir_filter_bank",
        impls={"seq": seq,
               "dp": _complex_fir(_fir_xla),
               "tp": _complex_fir(_fir_xla),
               "pallas": _complex_fir(_fir_pallas)},
        trip_count=2, doc="time-domain FIR: the FB offload target")


def _scale_nest():
    def seq(state):
        def row(_, i):
            return None, (state["y_re"][i] * 0.5, state["y_im"][i] * 0.5)
        _, (yr, yi) = jax.lax.scan(row, None,
                                   jnp.arange(state["y_re"].shape[0]))
        return dict(state, y_re=yr, y_im=yi)

    def dp(state):
        return dict(state, y_re=state["y_re"] * 0.5,
                    y_im=state["y_im"] * 0.5)

    return LoopNest(name="scale_output", impls={"seq": seq, "dp": dp,
                                                "tp": dp},
                    trip_count=2, doc="output scaling loop")


def _energy_nest():
    def seq(state):
        def row(acc, i):
            return acc + jnp.sum(state["y_re"][i] ** 2
                                 + state["y_im"][i] ** 2), None
        acc, _ = jax.lax.scan(row, jnp.float32(0.0),
                              jnp.arange(state["y_re"].shape[0]))
        return dict(state, out=jnp.concatenate(
            [state["y_re"], state["y_im"],
             jnp.full((1, state["y_re"].shape[1]), acc)]))

    def dp(state):
        acc = jnp.sum(state["y_re"] ** 2 + state["y_im"] ** 2)
        return dict(state, out=jnp.concatenate(
            [state["y_re"], state["y_im"],
             jnp.full((1, state["y_re"].shape[1]), acc)]))

    return LoopNest(name="energy_check", impls={"seq": seq, "dp": dp,
                                                "tp": dp},
                    trip_count=2, doc="verification energy sum")


def build_app() -> OffloadableApp:
    return OffloadableApp(
        name="tdFIR",
        nests=[_fir_nest(), _scale_nest(), _energy_nest()],
        make_inputs=make_inputs,
        doc="HPEC time-domain FIR filter bank")
