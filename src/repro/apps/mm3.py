"""polybench 3mm: G = (A·B)·(C·D)  (paper §III.A, STANDARD_DATASET 1000^3;
reduced default here so GA measurement loops stay tractable on one core).

Loop nests mirror the C benchmark: four init loops + three matmul triple
nests.  ``seq`` runs each matmul as a lax.scan over output rows (the
single-core loop structure); ``dp`` is the parallelized XLA dot; ``tp`` adds
model-axis-style reduction splitting with an explicit partial-sum combine
(the transfer-disciplined GPU-analogue); ``pallas`` is the MXU-tiled kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.offloadable import LoopNest, OffloadableApp
from repro.kernels import matmul as mm_kernel

N_FULL = 512
N_SMALL = 64


def _seq_matmul(a, b):
    def row(_, r):
        return None, jnp.dot(r, b)
    _, rows = jax.lax.scan(row, None, a)
    return rows


def _tp_matmul(a, b, parts: int = 4):
    k = a.shape[1]
    assert k % parts == 0
    aa = a.reshape(a.shape[0], parts, k // parts)
    bb = b.reshape(parts, k // parts, b.shape[1])
    partial = jnp.einsum("mpk,pkn->pmn", aa, bb)   # p partial products
    return partial.sum(axis=0)                     # explicit combine


def _pallas_matmul(a, b):
    return mm_kernel.matmul(a, b, interpret=True)


def _init_nest(name, key_idx):
    def seq(state):
        iv = state["iv"]                       # [n] float index vector
        def row(c, i):
            return c, jnp.sin(i * 0.37 + key_idx) * jnp.cos(iv * 0.11
                                                            + key_idx)
        _, m = jax.lax.scan(row, None, iv)
        return dict(state, **{name.split("_")[1]: m})

    def dp(state):
        iv = state["iv"]
        m = (jnp.sin(iv * 0.37 + key_idx)[:, None]
             * jnp.cos(iv * 0.11 + key_idx)[None, :])
        return dict(state, **{name.split("_")[1]: m})

    return LoopNest(name=name, impls={"seq": seq, "dp": dp, "tp": dp},
                    trip_count=2, doc="matrix init double loop")


def _mm_nest(name, lhs, rhs, out):
    def seq(state):
        return dict(state, **{out: _seq_matmul(state[lhs], state[rhs])})

    def dp(state):
        return dict(state, **{out: jnp.dot(state[lhs], state[rhs])})

    def tp(state):
        return dict(state, **{out: _tp_matmul(state[lhs], state[rhs])})

    def pallas(state):
        return dict(state, **{out: _pallas_matmul(state[lhs], state[rhs])})

    return LoopNest(name=name,
                    impls={"seq": seq, "dp": dp, "tp": tp,
                           "pallas": pallas},
                    trip_count=3, doc="matmul triple nest")


def make_inputs(seed: int = 0, small: bool = False):
    n = N_SMALL if small else N_FULL
    return {"iv": jnp.arange(n, dtype=jnp.float32)}


def build_app() -> OffloadableApp:
    nests = [
        _init_nest("init_A", 1),
        _init_nest("init_B", 2),
        _init_nest("init_C", 3),
        _init_nest("init_D", 4),
        _mm_nest("mm1_E_AB", "A", "B", "E"),
        _mm_nest("mm2_F_CD", "C", "D", "F"),
        _mm_nest("mm3_G_EF", "E", "F", "out"),
    ]
    return OffloadableApp(name="3mm", nests=nests, make_inputs=make_inputs,
                          doc="polybench 3mm (3 chained matmuls)")
