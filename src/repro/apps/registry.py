"""Function-block registry ("DB") for the paper apps.

Paper-faithful: one FB offload target — tdFIR (paper §III.A prepared exactly
one "because I only need to confirm appropriate device and method
selection").  The entry carries per-destination replacements; the Pallas
kernel is the FPGA analogue (Intel OpenCL sample in the paper).

A second, framework-side entry (attention) demonstrates the same machinery
against model jaxprs; it is exercised by tests/examples, not by the paper
benchmark.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.function_blocks import FunctionBlockEntry, REGISTRY
from repro.apps import tdfir_app


def _tdfir_ref_example():
    st = tdfir_app.make_inputs(seed=0, small=True)
    return (st,)


def _tdfir_ref_fn(state):
    import jax
    return jax.vmap(tdfir_app._fir_seq_1)(state["x_re"], state["h_re"])


TDFIR_ENTRY = REGISTRY.register(FunctionBlockEntry(
    name="tdfir",
    match_names=("tdfir", "time_domain_fir"),
    ref_fn=_tdfir_ref_fn,
    example_args=_tdfir_ref_example,
    impls={
        "dp": tdfir_app._complex_fir(tdfir_app._fir_xla),
        "tp": tdfir_app._complex_fir(tdfir_app._fir_xla),
        "pallas": tdfir_app._complex_fir(tdfir_app._fir_pallas),
    },
    doc="HPEC time-domain FIR bank (paper's single FB target)",
))


# --- framework-side demo entry: attention -> flash kernel -----------------

def _attn_example():
    import jax
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 32, 16), jnp.float32)
    return (q, q, q)


def _attn_ref(q, k, v):
    from repro.kernels import ref
    return ref.mha_ref(q, k, v, causal=True)


ATTENTION_ENTRY = REGISTRY.register(FunctionBlockEntry(
    name="attention",
    match_names=("attention", "mha", "sdpa"),
    ref_fn=_attn_ref,
    example_args=_attn_example,
    impls={},          # replacement handled at the model layer (plan flag)
    doc="softmax(QK^T)V block; flash-kernel replacement via Plan.use_pallas",
))
