"""The paper's three evaluated applications as offloadable JAX apps."""
from repro.apps.mm3 import build_app as build_mm3
from repro.apps.nasbt import build_app as build_nasbt
from repro.apps.tdfir_app import build_app as build_tdfir
from repro.apps import registry  # populates the FB registry on import

APPS = {"3mm": build_mm3, "NAS.BT": build_nasbt, "tdFIR": build_tdfir}

__all__ = ["build_mm3", "build_nasbt", "build_tdfir", "APPS", "registry"]
