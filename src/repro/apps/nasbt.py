"""NAS.BT-style block tridiagonal solver (paper §III.A: CLASS A 64^3 grid;
reduced grid by default so GA measurement stays tractable on one core).

Structure follows BT's ADI factorization: RHS stencil computation, then
tridiagonal solves along x, y, z (Thomas algorithm — sequential *along* each
line, parallel *across* lines), a Gauss-Seidel smoother, and the solution
update.

The smoother is the paper's many-core hazard made concrete: its ``dp``/``tp``
implementations parallelize a loop-carried sweep Jacobi-style, which runs
fast but computes a DIFFERENT result — exactly the "OpenMP compiles wrong
parallelizations without error" failure mode.  Only the measured
result-equality check can reject it, so the GA must learn to leave that gene
at 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.offloadable import LoopNest, OffloadableApp

GRID_FULL = 48
GRID_SMALL = 12


def make_inputs(seed: int = 0, small: bool = False):
    n = GRID_SMALL if small else GRID_FULL
    key = jax.random.PRNGKey(seed)
    u = jax.random.normal(key, (n, n, n), jnp.float32)
    return {"u": u}


def _stencil_rhs(axis):
    def seq(state):
        u = state["u"]

        def plane(_, i):
            # 1D 3-point stencil applied plane-by-plane (sequential outer
            # loop, like the C triple nest)
            um = jnp.roll(u, 1, axis)
            up = jnp.roll(u, -1, axis)
            sl = [slice(None)] * 3
            sl[(axis + 1) % 3] = i
            return None, (0.5 * u[tuple(sl)] - 0.25 * um[tuple(sl)]
                          - 0.25 * up[tuple(sl)])

        n = u.shape[(axis + 1) % 3]
        _, planes = jax.lax.scan(plane, None, jnp.arange(n))
        rhs = jnp.moveaxis(planes, 0, (axis + 1) % 3)
        return dict(state, **{f"rhs{axis}": rhs})

    def dp(state):
        u = state["u"]
        um = jnp.roll(u, 1, axis)
        up = jnp.roll(u, -1, axis)
        return dict(state, **{f"rhs{axis}": 0.5 * u - 0.25 * um - 0.25 * up})

    return LoopNest(name=f"compute_rhs_{'xyz'[axis]}",
                    impls={"seq": seq, "dp": dp, "tp": dp},
                    trip_count=3, doc="RHS stencil triple nest")


def _thomas_line(d, rhs):
    """Thomas algorithm for tridiag(-1, d, -1) along the LAST axis."""
    n = rhs.shape[-1]

    def fwd(carry, i):
        cp_prev, dp_prev = carry
        denom = d - (-1.0) * cp_prev
        cp = -1.0 / denom
        dp = (rhs[..., i] - (-1.0) * dp_prev) / denom
        return (cp, dp), (cp, dp)

    (_, _), (cps, dps) = jax.lax.scan(
        fwd, (jnp.zeros(rhs.shape[:-1]), jnp.zeros(rhs.shape[:-1])),
        jnp.arange(n))
    cps = jnp.moveaxis(cps, 0, -1)
    dps = jnp.moveaxis(dps, 0, -1)

    def bwd(x_next, i):
        x = dps[..., i] - cps[..., i] * x_next
        return x, x

    _, xs = jax.lax.scan(bwd, jnp.zeros(rhs.shape[:-1]),
                         jnp.arange(n - 1, -1, -1))
    return jnp.moveaxis(xs[::-1], 0, -1)


def _solve_nest(axis):
    diag = 2.5

    def seq(state):
        rhs = jnp.moveaxis(state[f"rhs{axis}"], axis, -1)
        n_lines = rhs.shape[0]

        def line(_, i):
            return None, _thomas_line(diag, rhs[i])

        _, sol = jax.lax.scan(line, None, jnp.arange(n_lines))
        sol = jnp.moveaxis(sol, -1, axis)
        return dict(state, **{f"sol{axis}": sol})

    def dp(state):
        rhs = jnp.moveaxis(state[f"rhs{axis}"], axis, -1)
        sol = _thomas_line(diag, rhs)       # vectorized across all lines
        sol = jnp.moveaxis(sol, -1, axis)
        return dict(state, **{f"sol{axis}": sol})

    return LoopNest(name=f"{'xyz'[axis]}_solve",
                    impls={"seq": seq, "dp": dp, "tp": dp},
                    trip_count=4,
                    doc="Thomas solve: sequential along line, parallel "
                        "across lines")


def _seidel_nest():
    sweeps = 2

    def seq(state):
        u = state["u"]

        def sweep(u, _):
            def row(u, i):
                prev = jnp.where(i > 0, u[i - 1], u[0])
                new_row = 0.5 * u[i] + 0.25 * prev
                return u.at[i].set(new_row), None
            u, _ = jax.lax.scan(row, u, jnp.arange(u.shape[0]))
            return u, None

        u, _ = jax.lax.scan(sweep, u, None, length=sweeps)
        return dict(state, u_smooth=u)

    def dp(state):
        # WRONG parallelization: Jacobi instead of Gauss-Seidel — fast,
        # compiles fine, different answer (the paper's OpenMP hazard).
        u = state["u"]
        for _ in range(sweeps):
            prev = jnp.concatenate([u[:1], u[:-1]], axis=0)
            u = 0.5 * u + 0.25 * prev
        return dict(state, u_smooth=u)

    return LoopNest(name="seidel_relax", impls={"seq": seq, "dp": dp,
                                                "tp": dp},
                    parallel_safe=False, trip_count=3,
                    doc="Gauss-Seidel sweep (loop-carried!)")


def _update_nest():
    def seq(state):
        def comb(_, i):
            return None, (state["u_smooth"][i] + state["sol0"][i]
                          + state["sol1"][i] + state["sol2"][i])
        _, out = jax.lax.scan(comb, None,
                              jnp.arange(state["u"].shape[0]))
        return dict(state, out=out)

    def dp(state):
        return dict(state, out=state["u_smooth"] + state["sol0"]
                    + state["sol1"] + state["sol2"])

    return LoopNest(name="add_update", impls={"seq": seq, "dp": dp,
                                              "tp": dp},
                    trip_count=3, doc="solution update")


def build_app() -> OffloadableApp:
    nests = [
        _stencil_rhs(0), _stencil_rhs(1), _stencil_rhs(2),
        _solve_nest(0), _solve_nest(1), _solve_nest(2),
        _seidel_nest(),
        _update_nest(),
    ]
    return OffloadableApp(name="NAS.BT", nests=nests,
                          make_inputs=make_inputs,
                          doc="block-tridiagonal ADI solver")
