"""Mixed-offloading-destination planner (paper §II.C) — the paper's main
contribution.

Runs the six verifications in the paper's order:
  ① FB→many-core  ② FB→GPU  ③ FB→FPGA  ④ loops→many-core  ⑤ loops→GPU
  ⑥ loops→FPGA
with:
  * early stop as soon as a pattern meets the user's performance and price
    targets,
  * the residual rule — once a function block is offloaded, the loop
    verifications search only the remaining nests,
  * the FPGA-analogue loop search using intensity narrowing instead of a GA.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core import function_blocks, loop_offload
from repro.core.destinations import (Destination, VERIFICATION_ORDER)
from repro.core.ga import GAConfig
from repro.core.measure import TimedRunner


@dataclass
class UserTarget:
    target_speedup: Optional[float] = None     # vs single-core reference
    target_time_s: Optional[float] = None
    max_price: Optional[float] = None

    def met(self, time_s: float, ref_time_s: float, price: float) -> bool:
        perf_ok = True
        if self.target_speedup is not None:
            perf_ok = perf_ok and (ref_time_s / max(time_s, 1e-12)
                                   >= self.target_speedup)
        if self.target_time_s is not None:
            perf_ok = perf_ok and time_s <= self.target_time_s
        if self.target_speedup is None and self.target_time_s is None:
            perf_ok = False     # nothing requested => never early-stop
        price_ok = self.max_price is None or price <= self.max_price
        return perf_ok and price_ok


@dataclass
class VerificationRecord:
    order: int
    destination: str
    paper_analogue: str
    method: str                     # function_block | loop
    best_time_s: float
    improvement: float              # ref_time / best_time
    price: float
    n_measurements: int
    verify_elapsed_s: float
    met_target: bool
    choice: Dict[str, str] = field(default_factory=dict)
    note: str = ""


@dataclass
class PlanReport:
    app: str
    ref_time_s: float
    records: List[VerificationRecord]
    selected: Optional[VerificationRecord]
    early_stopped: bool

    def summary_rows(self):
        rows = []
        for r in self.records:
            rows.append({
                "app": self.app, "order": r.order,
                "destination": r.paper_analogue, "method": r.method,
                "time_s": round(r.best_time_s, 6),
                "improvement": round(r.improvement, 2),
                "price": r.price, "n_meas": r.n_measurements,
                "selected": self.selected is r,
            })
        return rows


def plan_offload(app, targets: UserTarget, *, seed: int = 0,
                 runner: Optional[TimedRunner] = None,
                 ga_cfg: Optional[GAConfig] = None,
                 small_state=None, inputs=None,
                 registry=None) -> PlanReport:
    runner = runner or TimedRunner()
    if inputs is None:
        inputs = app.make_inputs(seed=seed)
    if small_state is None:
        small_state = app.make_inputs(seed=seed, small=True)

    # single-core reference (paper's "processing time by a single core")
    ref_fn = app.reference_fn()
    ref_eval = runner.measure(ref_fn, inputs, None)
    import jax
    ref_out = jax.jit(ref_fn)(inputs)
    ref_time = ref_eval.time_s

    # FB discovery once (name match + similarity), per paper [41]
    matches = function_blocks.detect(
        app, small_state, registry=registry or function_blocks.REGISTRY)

    records: List[VerificationRecord] = []
    fb_fixed: Dict[str, str] = {}       # residual rule state
    early = False

    for order, (dest, method) in enumerate(VERIFICATION_ORDER, start=1):
        t0 = time.perf_counter()
        if method == "function_block":
            choice = function_blocks.apply_matches(app, matches, dest.key)
            if choice is None:
                records.append(VerificationRecord(
                    order=order, destination=dest.name,
                    paper_analogue=dest.paper_analogue, method=method,
                    best_time_s=float("inf"), improvement=0.0,
                    price=dest.price, n_measurements=0,
                    verify_elapsed_s=time.perf_counter() - t0,
                    met_target=False, note="no offloadable function block"))
                continue
            ev = runner.measure(app.build(choice), inputs, ref_out)
            rec = VerificationRecord(
                order=order, destination=dest.name,
                paper_analogue=dest.paper_analogue, method=method,
                best_time_s=ev.effective_time,
                improvement=ref_time / max(ev.effective_time, 1e-12),
                price=dest.price, n_measurements=1,
                verify_elapsed_s=time.perf_counter() - t0,
                met_target=targets.met(ev.effective_time, ref_time,
                                       dest.price),
                choice=dict(choice),
                note="; ".join(f"{m.entry.name}@{m.nest.name}({m.method}"
                               f":{m.score:.2f})" for m in matches))
            records.append(rec)
        else:
            if dest.key == "pallas":
                res = loop_offload.fpga_search(
                    app, dest, runner, inputs, ref_out, small_state,
                    fixed_choice=fb_fixed)
            else:
                res = loop_offload.ga_search(
                    app, dest, runner, inputs, ref_out,
                    fixed_choice=fb_fixed, ga_cfg=ga_cfg, seed=seed)
            rec = VerificationRecord(
                order=order, destination=dest.name,
                paper_analogue=dest.paper_analogue, method=method,
                best_time_s=res.best_time_s,
                improvement=ref_time / max(res.best_time_s, 1e-12),
                price=dest.price, n_measurements=res.n_measurements,
                verify_elapsed_s=res.verify_elapsed_s,
                met_target=targets.met(res.best_time_s, ref_time,
                                       dest.price),
                choice=dict(res.best_choice), note=res.note)
            records.append(rec)

        if rec.met_target:
            early = True
            break

        # residual rule: after the FB verifications (first three), pin the
        # best FB pattern before loop searches begin.
        if order == 3:
            fb_recs = [r for r in records
                       if r.method == "function_block"
                       and r.best_time_s < float("inf")]
            if fb_recs:
                best_fb = min(fb_recs, key=lambda r: r.best_time_s)
                if best_fb.best_time_s < ref_time:
                    fb_fixed = dict(best_fb.choice)

    done = [r for r in records if r.best_time_s < float("inf")]
    selected = min(done, key=lambda r: r.best_time_s) if done else None
    return PlanReport(app=app.name, ref_time_s=ref_time, records=records,
                      selected=selected, early_stopped=early)
