"""Mixed-offloading-destination planner (paper §II.C) — the paper's main
contribution, on top of the pluggable backend API (repro.backends).

The planner no longer knows the destinations: it iterates the verification
order a :class:`~repro.backends.BackendRegistry` derives from each backend's
declared ``verify_time`` / ``methods`` (for the built-in registry this is
exactly the paper's six verifications:
  ① FB→many-core  ② FB→GPU  ③ FB→FPGA  ④ loops→many-core  ⑤ loops→GPU
  ⑥ loops→FPGA),
delegates each verification to ``backend.search(app, ctx, method)``, and
keeps:
  * early stop as soon as a pattern meets the user's performance and price
    targets,
  * the residual rule — once a function block is offloaded, the loop
    verifications search only the remaining nests.

Final selection is a pluggable :class:`~repro.backends.SelectionPolicy`
(``policy=``): ``host-time`` reproduces the paper's fastest-correct-pattern
rule; ``modeled`` ranks by the mesh-verified roofline time when a
``cost_runner`` recorded one; ``price-weighted`` weights by the
destination's relative price; ``power`` / ``edp`` rank by the modeled
energy the planner charges each correct record (repro.power: roofline
utilization × the backend's power envelope, envelope × host-time as
fallback).  ``power_budget_w`` / ``max_slowdown`` constrain any policy's
selection — the power follow-up's "fastest within the power budget" and
"lowest energy within the allowed slowdown" evaluations.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

from repro.backends import (BackendRegistry, SearchContext, SelectionPolicy,
                            default_registry, get_policy)
from repro.core import function_blocks
from repro.core.ga import GAConfig
from repro.core.measure import TimedRunner
from repro.obs import get_tracer


@dataclass
class UserTarget:
    target_speedup: Optional[float] = None     # vs single-core reference
    target_time_s: Optional[float] = None
    max_price: Optional[float] = None

    def met(self, time_s: float, ref_time_s: float, price: float) -> bool:
        perf_ok = True
        if self.target_speedup is not None:
            perf_ok = perf_ok and (ref_time_s / max(time_s, 1e-12)
                                   >= self.target_speedup)
        if self.target_time_s is not None:
            perf_ok = perf_ok and time_s <= self.target_time_s
        if self.target_speedup is None and self.target_time_s is None:
            perf_ok = False     # nothing requested => never early-stop
        price_ok = self.max_price is None or price <= self.max_price
        return perf_ok and price_ok


@dataclass
class VerificationRecord:
    order: int
    destination: str
    paper_analogue: str
    method: str                     # function_block | loop
    best_time_s: float
    improvement: float              # ref_time / best_time
    price: float
    n_measurements: int
    verify_elapsed_s: float
    met_target: bool
    choice: Dict[str, str] = field(default_factory=dict)
    note: str = ""
    # False: best_time_s is the configured penalty for a wrong result /
    # timeout — kept as evidence but never pinned, selected or early-stopped
    correct: bool = True
    # set when a CompiledCostRunner mesh-verified the winning candidate
    # (repro.dist.bridge): the modeled step time under the destination's
    # sharding, and the roofline breakdown behind it
    mesh_time_s: Optional[float] = None
    mesh_info: Dict = field(default_factory=dict)
    # verification-cost counters from the search (e.g. the loop GA's
    # choice-keyed measurement memo: measured / reused)
    cache_stats: Dict = field(default_factory=dict)
    # modeled energy of this destination's step (repro.power): charged from
    # the mesh roofline when one was recorded, envelope × host-time
    # otherwise; None on incorrect / infinite records
    energy_j: Optional[float] = None
    avg_watts: Optional[float] = None
    energy_info: Dict = field(default_factory=dict)


@dataclass
class PlanReport:
    app: str
    ref_time_s: float
    records: List[VerificationRecord]
    selected: Optional[VerificationRecord]
    early_stopped: bool
    policy: str = "host-time"       # name of the selection policy applied

    def summary_rows(self):
        rows = []
        for r in self.records:
            rows.append({
                "app": self.app, "order": r.order,
                "destination": r.paper_analogue, "method": r.method,
                "time_s": round(r.best_time_s, 6),
                "mesh_time_s": (None if r.mesh_time_s is None
                                else round(r.mesh_time_s, 6)),
                "improvement": round(r.improvement, 2),
                "price": r.price, "n_meas": r.n_measurements,
                "correct": r.correct,
                "energy_j": (None if r.energy_j is None
                             else round(r.energy_j, 6)),
                "avg_watts": (None if r.avg_watts is None
                              else round(r.avg_watts, 3)),
                "selected": self.selected is r,
            })
        return rows


def _pin_best_fb(records: List[VerificationRecord],
                 ref_time: float) -> Dict[str, str]:
    """Residual rule state: the winning FB pattern, or {} if none won."""
    fb_recs = [r for r in records
               if r.method == "function_block" and r.correct
               and r.best_time_s < float("inf")]
    if not fb_recs:
        return {}
    best_fb = min(fb_recs, key=lambda r: r.best_time_s)
    if best_fb.best_time_s < ref_time:
        return dict(best_fb.choice)
    return {}


def plan_offload(app, targets: UserTarget, *, seed: int = 0,
                 runner: Optional[TimedRunner] = None,
                 ga_cfg: Optional[GAConfig] = None,
                 small_state=None, inputs=None,
                 registry=None, cost_runner=None,
                 backends: Optional[BackendRegistry] = None,
                 policy: Union[str, SelectionPolicy, None] = None,
                 power_budget_w: Optional[float] = None,
                 max_slowdown: Optional[float] = None,
                 lint_choice=None,
                 publish=None
                 ) -> PlanReport:
    """Run the registry's verifications and select a destination.

    ``backends`` (a :class:`repro.backends.BackendRegistry`) supplies the
    destinations and their search strategies; the default registry holds the
    paper's three.  ``registry`` stays the *function-block* registry
    (paper's DB).

    ``cost_runner`` (a :class:`repro.core.measure.CompiledCostRunner`)
    additionally compiles each dp / tp winner for the runner's mesh under
    the destination's sharding (each backend's ``mesh_verify`` hook) and
    records the modeled step time on the VerificationRecord — the
    mixed-destination decision then sees communication cost, not only
    unsharded host timing.

    ``policy`` names the :class:`~repro.backends.SelectionPolicy` ranking
    the verified destinations (default ``host-time``, the paper's rule;
    ``modeled`` consumes the recorded ``mesh_time_s``; ``power`` / ``edp``
    consume the modeled ``energy_j`` this function charges every correct
    record via repro.power).

    ``lint_choice`` (repro.analysis) statically rejects loop-offload
    choices before any trace/compile: a callable mapping a choice dict to
    a list of :class:`~repro.analysis.Finding`; choices with an
    error-severity finding are charged the penalty without measurement.

    ``power_budget_w`` restricts selection to destinations whose modeled
    average draw fits the budget; ``max_slowdown`` restricts it to
    destinations within the factor of the fastest correct one — so the
    power follow-up's "power saving within allowed slowdown" evaluation is
    ``plan_offload(policy="power", max_slowdown=1.3)``.

    ``publish`` (a :class:`repro.core.plan_lookup.PlanLookup`) is the write
    half of the search/lookup split: every mesh-verified record's roofline
    analysis — and every incorrect record, as a recorded failure — is
    registered under ``serve_key(backend, app)`` so a serve-time router
    (repro.serve) can score destinations per request without ever tracing
    or compiling.  Search stays the slow offline path; the lookup is the
    hot one.
    """
    runner = runner or TimedRunner()
    backends = backends if backends is not None else default_registry()
    pol = get_policy(policy)
    if inputs is None:
        inputs = app.make_inputs(seed=seed)
    if small_state is None:
        small_state = app.make_inputs(seed=seed, small=True)

    # single-core reference (paper's "processing time by a single core");
    # the measurement already ran the function — reuse its output instead of
    # compiling and executing the reference a second time
    ref_fn = app.reference_fn()
    ref_eval = runner.measure(ref_fn, inputs, None)
    ref_out = ref_eval.info.get("output")
    if ref_out is None:
        import jax
        ref_out = jax.jit(ref_fn)(inputs)
    ref_time = ref_eval.time_s

    # FB discovery once (name match + similarity), per paper [41]
    matches = function_blocks.detect(
        app, small_state, registry=registry or function_blocks.REGISTRY)

    ctx = SearchContext(
        runner=runner, inputs=inputs, ref_out=ref_out,
        small_state=small_state, ga_cfg=ga_cfg,
        # one penalty scale for every verification in this run (GA-internal
        # evaluations get it via run_ga; direct measurements get it stamped)
        penalty_s=ga_cfg.penalty_s if ga_cfg is not None else None,
        seed=seed, fb_matches=matches, lint_choice=lint_choice)

    records: List[VerificationRecord] = []
    fb_pinned = False                   # residual rule state
    early = False
    plan_span = get_tracer().span("offload", cat="plan", track="planner",
                                  app=app.name, ref_time_s=ref_time)

    for order, (backend, method) in enumerate(backends.verification_order(),
                                              start=1):
        # residual rule: before the FIRST loop verification, pin the best
        # FB pattern found by the FB verifications — regardless of how they
        # exited (a no-match FPGA FB verification must not skip the pinning
        # of a many-core / GPU FB win).
        if method == "loop" and not fb_pinned:
            fb_pinned = True
            ctx.fixed_choice = _pin_best_fb(records, ref_time)

        with get_tracer().span("verify", cat="plan",
                               track=f"backend:{backend.name}",
                               backend=backend.name, method=method,
                               order=order) as vspan:
            res = backend.search(app, ctx, method=method)
            rec = VerificationRecord(
                order=order, destination=backend.name,
                paper_analogue=backend.paper_analogue, method=method,
                best_time_s=res.best_time_s,
                improvement=ref_time / max(res.best_time_s, 1e-12)
                if res.best_time_s < float("inf") else 0.0,
                price=backend.price, n_measurements=res.n_measurements,
                verify_elapsed_s=res.verify_elapsed_s,
                met_target=res.best_correct and targets.met(
                    res.best_time_s, ref_time, backend.price),
                correct=res.best_correct,
                choice=dict(res.best_choice), note=res.note,
                cache_stats=dict(getattr(res, "cache_stats", {}) or {}))
            records.append(rec)

            # mesh bridge: compile the winner for an actual mesh through
            # the backend's hook and record the modeled (roofline) step
            # time next to the host timing
            if (cost_runner is not None and rec.correct
                    and rec.best_time_s < float("inf")):
                mesh_ev = backend.mesh_verify(
                    cost_runner, app.build(dict(rec.choice)), inputs)
                if mesh_ev is not None and mesh_ev.correct:
                    rec.mesh_time_s = mesh_ev.time_s
                    rec.mesh_info = dict(mesh_ev.info)

            # energy charge (repro.power): every correct finite record gets
            # the modeled joules/watts the power/edp policies and the
            # power_budget_w constraint consume — from the mesh roofline
            # when the bridge recorded one, envelope × host-time otherwise
            if rec.correct and rec.best_time_s < float("inf"):
                from repro.power import energy_for_record, envelope_for
                e_rep = energy_for_record(rec, envelope_for(backend))
                if e_rep is not None:
                    rec.energy_j = e_rep.energy_j
                    rec.avg_watts = e_rep.avg_watts
                    rec.energy_info = e_rep.to_dict()

            # search/lookup split: publish this verification into the
            # serve-time lookup (correct mesh-verified records warm it;
            # incorrect ones are recorded failures the router statically
            # refuses)
            if publish is not None:
                from repro.core.plan_lookup import publish_record
                publish_record(publish, rec, backend, app.name)

            stats = rec.cache_stats
            vspan.set(best_time_s=rec.best_time_s, correct=rec.correct,
                      compile_s=float(stats.get("compile_s",
                                                rec.verify_elapsed_s)),
                      cache_hit=bool(stats.get("reused")
                                     or stats.get("hits")
                                     or stats.get("disk_hits")),
                      energy_j=rec.energy_j,
                      n_measurements=rec.n_measurements,
                      met_target=rec.met_target)

        if rec.met_target:
            early = True
            break

    # selection: delegated to the policy via the Candidate contract
    # (repro.core.candidates); every policy ranks correct patterns only — a
    # penalized wrong result is never the chosen destination (it stays in
    # records as evidence).  Candidates quack like records and delegate
    # unknown reads to the wrapped record, so a custom policy written
    # against record fields ranks them unchanged; unwrap() maps the winner
    # back to the actual VerificationRecord (PlanReport.summary_rows
    # compares by identity).  The constraint kwargs are only passed when
    # set: a custom policy written against the pre-constraint
    # select(records) signature keeps working until someone actually asks
    # it for a constrained selection.
    from repro.core.candidates import candidates_from_records, unwrap
    cands = candidates_from_records(records, arch=app.name)
    if power_budget_w is not None or max_slowdown is not None:
        selected = unwrap(pol.select(cands, power_budget_w=power_budget_w,
                                     max_slowdown=max_slowdown))
    else:
        selected = unwrap(pol.select(cands))
    plan_span.set(policy=pol.name, early_stopped=early,
                  n_verifications=len(records),
                  selected=selected.destination
                  if selected is not None else None)
    plan_span.finish()
    return PlanReport(app=app.name, ref_time_s=ref_time, records=records,
                      selected=selected, early_stopped=early,
                      policy=pol.name)
