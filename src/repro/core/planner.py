"""Mixed-offloading-destination planner (paper §II.C) — the paper's main
contribution.

Runs the six verifications in the paper's order:
  ① FB→many-core  ② FB→GPU  ③ FB→FPGA  ④ loops→many-core  ⑤ loops→GPU
  ⑥ loops→FPGA
with:
  * early stop as soon as a pattern meets the user's performance and price
    targets,
  * the residual rule — once a function block is offloaded, the loop
    verifications search only the remaining nests,
  * the FPGA-analogue loop search using intensity narrowing instead of a GA.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core import function_blocks, loop_offload
from repro.core.destinations import (Destination, VERIFICATION_ORDER)
from repro.core.ga import GAConfig
from repro.core.measure import TimedRunner


@dataclass
class UserTarget:
    target_speedup: Optional[float] = None     # vs single-core reference
    target_time_s: Optional[float] = None
    max_price: Optional[float] = None

    def met(self, time_s: float, ref_time_s: float, price: float) -> bool:
        perf_ok = True
        if self.target_speedup is not None:
            perf_ok = perf_ok and (ref_time_s / max(time_s, 1e-12)
                                   >= self.target_speedup)
        if self.target_time_s is not None:
            perf_ok = perf_ok and time_s <= self.target_time_s
        if self.target_speedup is None and self.target_time_s is None:
            perf_ok = False     # nothing requested => never early-stop
        price_ok = self.max_price is None or price <= self.max_price
        return perf_ok and price_ok


@dataclass
class VerificationRecord:
    order: int
    destination: str
    paper_analogue: str
    method: str                     # function_block | loop
    best_time_s: float
    improvement: float              # ref_time / best_time
    price: float
    n_measurements: int
    verify_elapsed_s: float
    met_target: bool
    choice: Dict[str, str] = field(default_factory=dict)
    note: str = ""
    # False: best_time_s is the configured penalty for a wrong result /
    # timeout — kept as evidence but never pinned, selected or early-stopped
    correct: bool = True
    # set when a CompiledCostRunner mesh-verified the winning candidate
    # (repro.dist.bridge): the modeled step time under the destination's
    # sharding, and the roofline breakdown behind it
    mesh_time_s: Optional[float] = None
    mesh_info: Dict = field(default_factory=dict)


@dataclass
class PlanReport:
    app: str
    ref_time_s: float
    records: List[VerificationRecord]
    selected: Optional[VerificationRecord]
    early_stopped: bool

    def summary_rows(self):
        rows = []
        for r in self.records:
            rows.append({
                "app": self.app, "order": r.order,
                "destination": r.paper_analogue, "method": r.method,
                "time_s": round(r.best_time_s, 6),
                "improvement": round(r.improvement, 2),
                "price": r.price, "n_meas": r.n_measurements,
                "selected": self.selected is r,
            })
        return rows


def _pin_best_fb(records: List[VerificationRecord],
                 ref_time: float) -> Dict[str, str]:
    """Residual rule state: the winning FB pattern, or {} if none won."""
    fb_recs = [r for r in records
               if r.method == "function_block" and r.correct
               and r.best_time_s < float("inf")]
    if not fb_recs:
        return {}
    best_fb = min(fb_recs, key=lambda r: r.best_time_s)
    if best_fb.best_time_s < ref_time:
        return dict(best_fb.choice)
    return {}


def plan_offload(app, targets: UserTarget, *, seed: int = 0,
                 runner: Optional[TimedRunner] = None,
                 ga_cfg: Optional[GAConfig] = None,
                 small_state=None, inputs=None,
                 registry=None, cost_runner=None) -> PlanReport:
    """Run the six verifications and select a destination.

    ``cost_runner`` (a :class:`repro.core.measure.CompiledCostRunner`)
    additionally compiles each dp / tp winner for the runner's mesh under
    the destination's sharding (repro.dist.bridge) and records the modeled
    step time on the VerificationRecord — the mixed-destination decision
    then sees communication cost, not only unsharded host timing.
    """
    runner = runner or TimedRunner()
    if inputs is None:
        inputs = app.make_inputs(seed=seed)
    if small_state is None:
        small_state = app.make_inputs(seed=seed, small=True)

    # single-core reference (paper's "processing time by a single core");
    # the measurement already ran the function — reuse its output instead of
    # compiling and executing the reference a second time
    ref_fn = app.reference_fn()
    ref_eval = runner.measure(ref_fn, inputs, None)
    ref_out = ref_eval.info.get("output")
    if ref_out is None:
        import jax
        ref_out = jax.jit(ref_fn)(inputs)
    ref_time = ref_eval.time_s

    # FB discovery once (name match + similarity), per paper [41]
    matches = function_blocks.detect(
        app, small_state, registry=registry or function_blocks.REGISTRY)

    records: List[VerificationRecord] = []
    fb_fixed: Dict[str, str] = {}       # residual rule state
    fb_pinned = False
    early = False
    # one penalty scale for every verification in this run (GA-internal
    # evaluations get it via run_ga; direct measurements get it stamped)
    penalty_s = ga_cfg.penalty_s if ga_cfg is not None else None

    for order, (dest, method) in enumerate(VERIFICATION_ORDER, start=1):
        # residual rule: before the FIRST loop verification, pin the best
        # FB pattern found by verifications 1-3 — regardless of how the
        # FB verifications exited (a no-match FPGA FB verification must not
        # skip the pinning of a many-core / GPU FB win).
        if method == "loop" and not fb_pinned:
            fb_pinned = True
            fb_fixed = _pin_best_fb(records, ref_time)

        t0 = time.perf_counter()
        if method == "function_block":
            choice = function_blocks.apply_matches(app, matches, dest.key)
            if choice is None:
                records.append(VerificationRecord(
                    order=order, destination=dest.name,
                    paper_analogue=dest.paper_analogue, method=method,
                    best_time_s=float("inf"), improvement=0.0,
                    price=dest.price, n_measurements=0,
                    verify_elapsed_s=time.perf_counter() - t0,
                    met_target=False, note="no offloadable function block"))
                continue
            ev = runner.measure(app.build(choice), inputs, ref_out)
            if penalty_s is not None:
                ev.penalty_s = penalty_s
            rec = VerificationRecord(
                order=order, destination=dest.name,
                paper_analogue=dest.paper_analogue, method=method,
                best_time_s=ev.effective_time,
                improvement=ref_time / max(ev.effective_time, 1e-12),
                price=dest.price, n_measurements=1,
                verify_elapsed_s=time.perf_counter() - t0,
                met_target=ev.correct and targets.met(
                    ev.effective_time, ref_time, dest.price),
                correct=ev.correct,
                choice=dict(choice),
                note="; ".join(f"{m.entry.name}@{m.nest.name}({m.method}"
                               f":{m.score:.2f})" for m in matches))
            records.append(rec)
        else:
            if dest.key == "pallas":
                res = loop_offload.fpga_search(
                    app, dest, runner, inputs, ref_out, small_state,
                    fixed_choice=fb_fixed, penalty_s=penalty_s)
            else:
                res = loop_offload.ga_search(
                    app, dest, runner, inputs, ref_out,
                    fixed_choice=fb_fixed, ga_cfg=ga_cfg, seed=seed)
            rec = VerificationRecord(
                order=order, destination=dest.name,
                paper_analogue=dest.paper_analogue, method=method,
                best_time_s=res.best_time_s,
                improvement=ref_time / max(res.best_time_s, 1e-12),
                price=dest.price, n_measurements=res.n_measurements,
                verify_elapsed_s=res.verify_elapsed_s,
                met_target=res.best_correct and targets.met(
                    res.best_time_s, ref_time, dest.price),
                correct=res.best_correct,
                choice=dict(res.best_choice), note=res.note)
            records.append(rec)

        # mesh bridge: compile the dp / tp winner for an actual mesh and
        # record the modeled (roofline) step time next to the host timing
        if (cost_runner is not None and rec.correct
                and rec.best_time_s < float("inf")):
            from repro.dist import bridge
            mesh_ev = bridge.mesh_verify(cost_runner, dest,
                                         app.build(dict(rec.choice)), inputs)
            if mesh_ev is not None and mesh_ev.correct:
                rec.mesh_time_s = mesh_ev.time_s
                rec.mesh_info = dict(mesh_ev.info)

        if rec.met_target:
            early = True
            break

    # selection: correct patterns only; a penalized wrong result is never
    # the chosen destination (it stays in records as evidence)
    done = [r for r in records
            if r.correct and r.best_time_s < float("inf")]
    selected = min(done, key=lambda r: r.best_time_s) if done else None
    return PlanReport(app=app.name, ref_time_s=ref_time, records=records,
                      selected=selected, early_stopped=early)
