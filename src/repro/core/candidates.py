"""One candidate datatype for every destination-selection decision.

Seven PRs in, "score a (destination, plan) candidate" had been re-derived
four times — ``plan_offload``'s record selection, ``Router._score_endpoint``,
dryrun's cell ranking and the autoplan rerank — each with its own ad-hoc
duck type feeding a different :class:`~repro.backends.SelectionPolicy` face
(``score`` / ``score_parts`` / ``score_cell``).  This module is the one
abstraction behind all of them:

  * :class:`Candidate` carries everything a policy may rank on — backend
    identity, plan structural key, modeled-or-measured time, price, energy
    charge, correctness verdict — plus ``ref``, the underlying object the
    caller gets back after ranking (a ``VerificationRecord``, an
    ``Endpoint``, a dryrun cell dict, a GA evaluation ...).
  * The constructors encode the four source shapes exactly once:
    ``from_record`` (planner verification records), ``from_analysis``
    (warm :class:`~repro.core.plan_lookup.PlanLookup` payloads — the
    router's and the fleet planner's zero-compile path), ``from_cell``
    (dryrun mesh cells) and ``from_roofline`` (autoplan GA candidates).
  * :meth:`SelectionPolicy.rank(candidates, power_budget_w=,
    max_slowdown=) <repro.backends.policy.SelectionPolicy.rank>` is the
    single selection entry point; the legacy per-shape ``score*`` faces are
    deprecation shims over :meth:`~repro.backends.policy.SelectionPolicy.
    score_candidate`.

Everything here is pure arithmetic over dicts and dataclasses: building a
Candidate from a warm analysis never traces or compiles (the router's and
the fleet planner's jit-poisoned tests pin that).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class Candidate:
    """One rankable (destination, plan) option.

    The scoring fields mirror the planner's ``VerificationRecord`` duck
    type, so a policy written against records ranks Candidates unchanged
    (and vice versa).  Unknown attribute reads fall through to ``ref`` —
    a custom policy that inspects e.g. ``record.destination`` keeps
    working when handed the Candidate wrapping that record.
    """
    backend: str = ""                       # destination / backend name
    arch: str = ""                          # app or model architecture
    plan_key: Optional[tuple] = None        # Plan.structural_key()
    best_time_s: float = math.inf           # measured-or-modeled seconds
    price: float = 1.0                      # paper's relative price
    correct: bool = True                    # correctness verdict
    mesh_time_s: Optional[float] = None     # modeled (roofline) seconds
    energy_j: Optional[float] = None        # modeled joules (repro.power)
    avg_watts: Optional[float] = None       # modeled draw while serving
    source: str = ""                        # record|analysis|cell|roofline
    info: Dict = field(default_factory=dict)
    ref: object = None                      # the wrapped original object

    def __getattr__(self, name):
        # only reached when normal attribute lookup fails: delegate to the
        # wrapped object so legacy policies can read its extra fields
        ref = self.__dict__.get("ref")
        if ref is not None and not name.startswith("_"):
            return getattr(ref, name)
        raise AttributeError(name)

    # ------------------------------------------------------- constructors
    @classmethod
    def from_record(cls, record, arch: str = "") -> "Candidate":
        """Lift a planner ``VerificationRecord`` (repro.core.planner)."""
        return cls(
            backend=getattr(record, "destination", ""),
            arch=arch,
            best_time_s=getattr(record, "best_time_s", math.inf),
            price=getattr(record, "price", 1.0),
            correct=getattr(record, "correct", True),
            mesh_time_s=getattr(record, "mesh_time_s", None),
            energy_j=getattr(record, "energy_j", None),
            avg_watts=getattr(record, "avg_watts", None),
            source="record", ref=record)

    @classmethod
    def from_analysis(cls, analysis: Dict[str, float], *, backend,
                      arch: str = "", n_chips: int = 1,
                      price: Optional[float] = None,
                      envelope=None, scale: float = 1.0,
                      bubble_fraction: float = 0.0,
                      plan_key: Optional[tuple] = None,
                      ref: object = None) -> Optional["Candidate"]:
        """Score one warm analysis payload — the zero-compile path shared
        by ``repro.serve.Router`` and ``repro.fleet``.

        ``analysis`` is the dict a :class:`~repro.core.plan_lookup.
        PlanLookup` publishes (flops / bytes / collective_bytes per
        device); ``scale`` multiplies the modeled step time into a
        service time (a request's ``max_gen + prompt_len/8`` decode
        steps, a fleet app's tokens-per-request).  ``backend`` may be a
        ``repro.backends.Backend`` or a name; the energy charge uses
        ``envelope`` (default ``envelope_for(backend)``).  Returns None
        when the analysis cannot be scored — pure arithmetic either way.
        """
        from repro.core.measure import CompiledCostRunner
        runner = CompiledCostRunner(n_chips=n_chips)
        ev = runner.score_analysis(dict(analysis),
                                   bubble_fraction=bubble_fraction,
                                   cache_hit=True)
        if not ev.correct or ev.time_s == math.inf:
            return None
        service_s = ev.time_s * scale
        rl = ev.info.get("roofline", {})
        name = getattr(backend, "name", None) or str(backend)
        if price is None:
            price = getattr(backend, "price", 1.0)
        cand = cls(backend=name, arch=arch, plan_key=plan_key,
                   best_time_s=service_s,
                   price=float(price),
                   mesh_time_s=service_s, source="analysis",
                   info={"roofline": rl, "step_time_s": ev.time_s},
                   ref=ref)
        from repro.power import EnergyModel, envelope_for
        env = envelope if envelope is not None else envelope_for(backend)
        rep = EnergyModel(env).from_roofline(rl) if rl else None
        if rep is not None:
            cand.avg_watts = rep.avg_watts
            cand.energy_j = rep.avg_watts * service_s
        return cand

    @classmethod
    def from_cell(cls, step_time_s: float, *, n_chips: float = 1.0,
                  energy: Optional[Dict] = None, backend: str = "cell",
                  arch: str = "", ref: object = None) -> "Candidate":
        """Lift one compiled mesh cell (repro.launch.dryrun): modeled step
        time, chip count as the relative price, and — when the cell was
        charged — its ``EnergyReport.to_dict()`` block."""
        cand = cls(backend=backend, arch=arch,
                   best_time_s=step_time_s, mesh_time_s=step_time_s,
                   price=float(n_chips), source="cell", ref=ref)
        if energy:
            cand.energy_j = energy.get("energy_j")
            cand.avg_watts = energy.get("avg_watts")
            cand.info = {"energy": dict(energy)}
        return cand

    @classmethod
    def from_roofline(cls, rl, *, n_chips: float, price: float = 1.0,
                      time_s: Optional[float] = None, backend: str = "mesh",
                      arch: str = "", ref: object = None) -> "Candidate":
        """Lift one roofline-scored GA candidate (examples/autoplan):
        charged via the shared TPU-cell rule (``repro.power.cell_energy``)
        so the energy policies rerank the GA front consistently with
        dryrun cells."""
        from repro.power import cell_energy
        rep = cell_energy(rl, n_chips)
        step = time_s
        if step is None:
            step = rl.get("step_time_s") if isinstance(rl, dict) \
                else getattr(rl, "step_time_s", math.inf)
        cand = cls(backend=backend, arch=arch, best_time_s=float(step),
                   mesh_time_s=float(step), price=float(price),
                   source="roofline",
                   info={"roofline": rl if isinstance(rl, dict)
                         else rl.to_dict()},
                   ref=ref)
        if rep is not None:
            cand.energy_j = rep.energy_j
            cand.avg_watts = rep.avg_watts
            cand.info["energy"] = rep.to_dict()
        return cand


def candidates_from_records(records: List, arch: str = "") -> List[Candidate]:
    """Wrap a planner report's records for ``SelectionPolicy.rank``."""
    return [Candidate.from_record(r, arch=arch) for r in records]


def unwrap(selected):
    """The underlying object behind a ranked winner (``Candidate.ref``),
    passing non-Candidates through — callers that hand records straight to
    a legacy policy's ``select`` get whatever it returned."""
    if selected is None:
        return None
    if isinstance(selected, Candidate) and selected.ref is not None:
        return selected.ref
    return selected
