"""Paper core: GA-driven automatic offloading to a mixed destination
environment (Yamato 2020), adapted to TPU execution strategies."""
from repro.backends import (Backend, BackendRegistry, SearchContext,
                            SearchResult, SelectionPolicy, get_policy,
                            register_policy)
from repro.core.ga import GAConfig, GAResult, Evaluation, run_ga
from repro.core.destinations import (Destination, MANY_CORE, GPU, FPGA,
                                     VERIFICATION_ORDER)
from repro.core.offloadable import LoopNest, OffloadableApp
from repro.core.measure import TimedRunner, CompiledCostRunner
from repro.core.planner import UserTarget, PlanReport, plan_offload
from repro.core import (cost_model, function_blocks, hlo_analysis, intensity,
                        jaxpr_tools, loop_offload)

__all__ = [
    "GAConfig", "GAResult", "Evaluation", "run_ga",
    "Backend", "BackendRegistry", "SearchContext", "SearchResult",
    "SelectionPolicy", "get_policy", "register_policy",
    "Destination", "MANY_CORE", "GPU", "FPGA", "VERIFICATION_ORDER",
    "LoopNest", "OffloadableApp",
    "TimedRunner", "CompiledCostRunner",
    "UserTarget", "PlanReport", "plan_offload",
    "cost_model", "function_blocks", "hlo_analysis", "intensity",
    "jaxpr_tools", "loop_offload",
]
