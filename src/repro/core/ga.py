"""Paper-exact genetic algorithm for offload-pattern search (§II.B.1, §III.A).

Encoding: one gene per loop statement; 1 = offload/parallelize, 0 = keep on
the single-core path.  (The framework side reuses the same engine with small
categorical genes — see ``repro.dist.plan.Plan.GENE_SPACE``.)

Paper-faithful settings:
  * goodness of fit = (processing time)^(-1/2)
  * timeout or wrong calculation result  =>  time := 1000 s
  * selection: roulette + 1-elite; crossover Pc = 0.9; mutation Pm = 0.05
  * individuals M and generations T no more than the gene length
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

PENALTY_TIME_S = 1000.0


@dataclass
class GAConfig:
    population: int
    generations: int
    crossover_rate: float = 0.9
    mutation_rate: float = 0.05
    timeout_s: float = 180.0
    penalty_s: float = PENALTY_TIME_S
    seed: int = 0
    # cardinality per gene; default binary
    cardinalities: Optional[Sequence[int]] = None

    @classmethod
    def for_gene_length(cls, n: int, **kw) -> "GAConfig":
        """Paper rule: M, T <= gene length (paper used 16/16, 20/20, 6/6)."""
        m = min(max(n, 2), 20)
        return cls(population=m, generations=m, **kw)


@dataclass
class Evaluation:
    time_s: float
    correct: bool
    timed_out: bool = False
    info: dict = field(default_factory=dict)
    # paper's "wrong result or timeout => 1000 s"; configurable through
    # GAConfig.penalty_s (run_ga stamps it onto every evaluation it makes)
    penalty_s: float = PENALTY_TIME_S

    @property
    def effective_time(self) -> float:
        if not self.correct or self.timed_out:
            return self.penalty_s
        return self.time_s

    @property
    def fitness(self) -> float:
        return self.effective_time ** -0.5


@dataclass
class GAResult:
    best_genes: Tuple[int, ...]
    best_eval: Evaluation
    history: List[dict]                     # per-generation stats
    evaluations: Dict[Tuple[int, ...], Evaluation]

    @property
    def n_measurements(self) -> int:
        return len(self.evaluations)


def run_ga(gene_length: int,
           evaluate: Callable[[Tuple[int, ...]], Evaluation],
           cfg: GAConfig,
           evaluate_batch: Optional[
               Callable[[List[Tuple[int, ...]]], List[Evaluation]]] = None,
           seed_population: Optional[Sequence[Tuple[int, ...]]] = None
           ) -> GAResult:
    """``evaluate_batch``, when given, scores a whole generation's unseen
    individuals in one call (e.g. batching XLA lowering/compilation across
    the population); ``evaluate`` remains the per-individual fallback.

    ``seed_population`` injects known-good individuals ahead of the random
    fill (after the all-zeros baseline) — e.g. a greedy bin-packing
    solution the GA should start from rather than rediscover.  Individuals
    beyond ``cfg.population`` are ignored; omitted -> identical behavior
    to before the parameter existed."""
    rng = random.Random(cfg.seed)
    cards = list(cfg.cardinalities or [2] * gene_length)
    assert len(cards) == gene_length

    def rand_genes() -> Tuple[int, ...]:
        return tuple(rng.randrange(c) for c in cards)

    cache: Dict[Tuple[int, ...], Evaluation] = {}

    def ev(genes: Tuple[int, ...]) -> Evaluation:
        if genes not in cache:
            e = evaluate(genes)
            e.penalty_s = cfg.penalty_s
            cache[genes] = e
        return cache[genes]

    def ev_population(pop: List[Tuple[int, ...]]
                      ) -> Tuple[List[Evaluation], int]:
        """Evaluations for pop plus how many were fresh (not yet cached) —
        the per-generation verification cost, recorded in history."""
        fresh = [g for g in dict.fromkeys(pop) if g not in cache]
        if fresh and evaluate_batch is not None:
            evs = evaluate_batch(fresh)
            assert len(evs) == len(fresh), \
                "evaluate_batch must return one Evaluation per individual"
            for g, e in zip(fresh, evs):
                e.penalty_s = cfg.penalty_s
                cache[g] = e
        return [ev(g) for g in pop], len(fresh)

    # initial population: all-zeros (the no-offload baseline is always a
    # candidate) + caller-seeded individuals + random fill, de-duplicated
    # when possible
    pop: List[Tuple[int, ...]] = [tuple([0] * gene_length)]
    for g in (seed_population or ()):
        g = tuple(int(v) for v in g)
        assert len(g) == gene_length, \
            f"seed individual has {len(g)} genes, expected {gene_length}"
        if g not in pop and len(pop) < cfg.population:
            pop.append(g)
    guard = 0
    while len(pop) < cfg.population:
        g = rand_genes()
        guard += 1
        if g not in pop or guard > 50 * cfg.population:
            pop.append(g)

    from repro.obs import get_tracer

    history: List[dict] = []
    for gen in range(cfg.generations):
        evals, n_fresh = ev_population(pop)
        fits = [e.fitness for e in evals]
        best_i = max(range(len(pop)), key=lambda i: fits[i])
        history.append({
            "generation": gen,
            "best_time_s": evals[best_i].effective_time,
            "best_genes": pop[best_i],
            "mean_fitness": sum(fits) / len(fits),
            "n_correct": sum(e.correct for e in evals),
            "n_fresh": n_fresh,
            # individuals a static linter rejected without any measurement
            # (repro.analysis via the batch evaluator / loop-GA lint hooks)
            "n_pruned": sum(bool(e.info.get("static_pruned"))
                            for e in evals),
        })
        row = history[-1]
        get_tracer().event(
            "generation", cat="ga", track="search", generation=gen,
            best_time_s=row["best_time_s"],
            mean_fitness=row["mean_fitness"], n_correct=row["n_correct"],
            n_fresh=row["n_fresh"], n_pruned=row["n_pruned"])

        if gen == cfg.generations - 1:
            break

        # --- next generation ---
        new_pop: List[Tuple[int, ...]] = [pop[best_i]]        # elite
        total_fit = sum(fits)

        def roulette() -> Tuple[int, ...]:
            r = rng.uniform(0, total_fit)
            acc = 0.0
            for g, f in zip(pop, fits):
                acc += f
                if acc >= r:
                    return g
            return pop[-1]

        while len(new_pop) < cfg.population:
            p1, p2 = roulette(), roulette()
            if rng.random() < cfg.crossover_rate and gene_length > 1:
                cut = rng.randrange(1, gene_length)
                c1 = p1[:cut] + p2[cut:]
                c2 = p2[:cut] + p1[cut:]
            else:
                c1, c2 = p1, p2
            for child in (c1, c2):
                child = tuple(
                    (rng.randrange(cards[i]) if rng.random() < cfg.mutation_rate
                     else v)
                    for i, v in enumerate(child))
                new_pop.append(child)
                if len(new_pop) >= cfg.population:
                    break
        pop = new_pop

    # final selection: a wrong result must never *win* the search, no
    # matter how small the configured penalty is — the penalty shapes
    # selection pressure inside the GA, not the returned pattern.  Fall
    # back to raw effective_time only when nothing was correct.
    valid = [kv for kv in cache.items()
             if kv[1].correct and not kv[1].timed_out]
    pool = valid or list(cache.items())
    best = min(pool, key=lambda kv: kv[1].effective_time)
    return GAResult(best_genes=best[0], best_eval=best[1], history=history,
                    evaluations=cache)
