"""FPGA-analogue narrowing (paper [40], §III.A): before any expensive
kernel "synthesis", candidates are narrowed by arithmetic intensity and loop
count, then by resource efficiency; only a handful of patterns are measured.

Resource budget is the TPU adaptation: VMEM working set instead of FPGA
LUT/DSP count (16 MiB VMEM per v5e core).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

from repro.core import jaxpr_tools
from repro.core.offloadable import LoopNest, OffloadableApp

VMEM_BUDGET_BYTES = 16 * 1024 * 1024


@dataclass
class NestProfile:
    nest: LoopNest
    flops: float
    bytes: float
    intensity: float        # FLOPs / byte
    resource: float         # working-set bytes (VMEM proxy)
    efficiency: float       # intensity / resource
    fits_vmem: bool


def profile_nests(app: OffloadableApp, small_state) -> List[NestProfile]:
    """Profile each nest on the state it actually receives (nests are a
    chain: run upstream seq impls to materialize intermediate state)."""
    import jax
    out = []
    state = dict(small_state)
    for nest in app.nests:
        try:
            fl = jaxpr_tools.flop_estimate(nest.impls["seq"], state)
            by = jaxpr_tools.byte_estimate(nest.impls["seq"], state)
            state = jax.jit(nest.impls["seq"])(state)
        except Exception:
            fl, by = 0.0, 1.0
        by = max(by, 1.0)
        inten = fl / by
        res = by
        out.append(NestProfile(
            nest=nest, flops=fl, bytes=by, intensity=inten, resource=res,
            efficiency=inten / max(res, 1.0),
            fits_vmem=res <= VMEM_BUDGET_BYTES))
    return out


def narrow(app: OffloadableApp, small_state, top_intensity: int = 5,
           top_efficiency: int = 3) -> List[NestProfile]:
    """Paper's two-stage narrowing: arithmetic intensity + loop count first,
    then resource efficiency — returns <= top_efficiency candidates."""
    profiles = profile_nests(app, small_state)
    # stage 1: intensity * loop-count ranking (paper: "arithmetic intensity
    # and loop count with ROSE and gcov")
    stage1 = sorted(profiles,
                    key=lambda p: p.intensity * max(p.nest.trip_count, 1),
                    reverse=True)[:top_intensity]
    # stage 2: resource efficiency
    stage2 = sorted(stage1, key=lambda p: p.efficiency,
                    reverse=True)[:top_efficiency]
    return stage2


def fpga_patterns(candidates: List[NestProfile]) -> List[tuple]:
    """Paper §III.A: measure the top-3 single-nest patterns, then one combo
    of the two best performers => at most 4 measured patterns.

    Returns a list of tuples of nest names; the combo is appended by the
    caller after the singles are measured.
    """
    return [(p.nest.name,) for p in candidates]
