"""Offloadable-application abstraction: named loop nests with per-destination
implementations.

An app is a chain of :class:`LoopNest` stages over a state dict.  Each nest
carries a ``seq`` implementation (the single-core reference path) and
optional destination implementations:

  * ``dp``     — data-parallel / vectorized (many-core-CPU analogue)
  * ``tp``     — model-axis sharded with explicit transfer discipline (GPU
                 analogue)
  * ``pallas`` — Pallas TPU kernel (FPGA analogue)

``parallel_safe=False`` marks nests whose parallel implementations are
*numerically different* from the sequential semantics (loop-carried
dependence parallelized Jacobi-style).  This reproduces the paper's central
many-core hazard: the OpenMP compiler accepts wrong parallelizations without
error, so only the measured result-equality check can reject them — the GA
has to learn which loops are safe.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List

State = Dict[str, object]


@dataclass
class LoopNest:
    name: str
    impls: Dict[str, Callable[[State], State]]
    parallel_safe: bool = True
    trip_count: int = 1          # paper's "number of loops" metadata
    doc: str = ""

    def impl(self, key: str) -> Callable[[State], State]:
        return self.impls.get(key, self.impls["seq"])


@dataclass
class OffloadableApp:
    name: str
    nests: List[LoopNest]
    make_inputs: Callable[..., State]        # (seed:int, small:bool) -> state
    output_key: str = "out"
    doc: str = ""

    @property
    def gene_length(self) -> int:
        return len(self.nests)

    def run(self, choice: Dict[str, str], state: State) -> State:
        state = dict(state)
        for nest in self.nests:
            state = nest.impl(choice.get(nest.name, "seq"))(state)
        return state

    def build(self, choice: Dict[str, str]) -> Callable[[State], object]:
        def fn(state: State):
            return self.run(choice, state)[self.output_key]
        return fn

    def reference_fn(self) -> Callable[[State], object]:
        return self.build({})

    def choice_from_genes(self, genes, dest_key: str) -> Dict[str, str]:
        choice = {}
        for nest, g in zip(self.nests, genes):
            if g and dest_key in nest.impls:
                choice[nest.name] = dest_key
            else:
                choice[nest.name] = "seq"
        return choice
