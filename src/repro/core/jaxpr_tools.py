"""jaxpr walking and Deckard-style structural fingerprints.

The paper's function-block discovery [41] uses DB name matching plus Deckard
(AST clone detection).  The jaxpr analogue: a block's "AST" is its primitive
sequence (recursively flattened through pjit/scan/cond sub-jaxprs) with
shapes abstracted to ranks; fingerprints are hashed n-grams of that sequence
and similarity is Jaccard over fingerprint sets.
"""
from __future__ import annotations

from typing import List, Sequence, Set

import jax
import numpy as np


def jaxpr_of(fn, *example_args) -> jax.extend.core.Jaxpr:
    return jax.make_jaxpr(fn)(*example_args).jaxpr


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _iter_eqns(sub)


def _sub_jaxprs(v):
    from jax.extend.core import Jaxpr, ClosedJaxpr
    if isinstance(v, ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for e in v:
            yield from _sub_jaxprs(e)


def prim_sequence(jaxpr, with_shapes: bool = False) -> List[str]:
    """Flattened primitive-name sequence; shapes abstracted to ranks."""
    out = []
    for eqn in _iter_eqns(jaxpr):
        tok = eqn.primitive.name
        if with_shapes:
            ranks = ",".join(str(getattr(v.aval, "ndim", 0))
                             for v in eqn.outvars)
            tok = f"{tok}#{ranks}"
        out.append(tok)
    return out


def count_prims(jaxpr) -> dict:
    out: dict = {}
    for eqn in _iter_eqns(jaxpr):
        out[eqn.primitive.name] = out.get(eqn.primitive.name, 0) + 1
    return out


def fingerprint(seq: Sequence[str], n: int = 3) -> Set[int]:
    """Hashed n-grams of the primitive sequence (Deckard vector analogue)."""
    if len(seq) < n:
        return {hash(tuple(seq))}
    return {hash(tuple(seq[i:i + n])) for i in range(len(seq) - n + 1)}


def similarity(fp_a: Set[int], fp_b: Set[int]) -> float:
    """Jaccard similarity of two fingerprint sets in [0, 1]."""
    if not fp_a or not fp_b:
        return 0.0
    return len(fp_a & fp_b) / len(fp_a | fp_b)


def fn_fingerprint(fn, *example_args, n: int = 3) -> Set[int]:
    return fingerprint(prim_sequence(jaxpr_of(fn, *example_args),
                                     with_shapes=True), n=n)


def _eqn_trip_count(eqn) -> float:
    """Loop multiplicity of an eqn's sub-jaxprs (scan length; while=1)."""
    if eqn.primitive.name == "scan":
        return float(eqn.params.get("length", 1) or 1)
    return 1.0


def _flops_of_jaxpr(jaxpr) -> float:
    flops = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        mult = _eqn_trip_count(eqn)
        subs = [s for v in eqn.params.values() for s in _sub_jaxprs(v)]
        if subs:
            for s in subs:
                flops += mult * _flops_of_jaxpr(s)
            continue
        if name == "dot_general":
            dims = eqn.params["dimension_numbers"]
            (lc, _), _ = dims
            lhs = eqn.invars[0].aval
            out_elems = float(np.prod(eqn.outvars[0].aval.shape) or 1.0)
            k = float(np.prod([lhs.shape[i] for i in lc]) or 1.0)
            flops += 2.0 * out_elems * k
        elif name == "conv_general_dilated":
            out_elems = float(np.prod(eqn.outvars[0].aval.shape) or 1.0)
            rhs = eqn.invars[1].aval
            flops += 2.0 * out_elems * float(np.prod(rhs.shape[1:]) or 1.0)
        else:
            if eqn.outvars and hasattr(eqn.outvars[0].aval, "shape"):
                flops += float(np.prod(eqn.outvars[0].aval.shape) or 1.0)
    return flops


def flop_estimate(fn, *example_args) -> float:
    """Analytic FLOP estimate from the jaxpr — scan bodies multiplied by
    their trip count (dots dominate)."""
    return _flops_of_jaxpr(jaxpr_of(fn, *example_args))


def byte_estimate(fn, *example_args) -> float:
    """Bytes of inputs actually read + outputs written (working-set proxy).

    Unused invars (pass-through state in chained apps) are excluded.
    """
    jx = jax.make_jaxpr(fn)(*example_args)
    jaxpr = jx.jaxpr
    used = set()

    def mark(jpr):
        for eqn in jpr.eqns:
            for v in eqn.invars:
                used.add(id(v))
            for pv in eqn.params.values():
                for s in _sub_jaxprs(pv):
                    mark(s)
    mark(jaxpr)

    total = 0.0
    for v in jaxpr.invars:
        if id(v) in used and hasattr(v.aval, "shape"):
            total += float(np.prod(v.aval.shape) or 1.0) * \
                v.aval.dtype.itemsize
    invar_ids = {id(v) for v in jaxpr.invars}
    for v in jaxpr.outvars:
        if id(v) in invar_ids:
            continue                       # pass-through, not produced here
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "shape"):
            total += float(np.prod(aval.shape) or 1.0) * aval.dtype.itemsize
    return total
