"""Loop-statement offloading: GA search over per-nest offload genes for one
destination (paper §II.B.1/2/3).

For the many-core-CPU and GPU analogues the full GA runs (M, T <= gene
length).  For the FPGA analogue the candidate set is first narrowed by
arithmetic intensity / resources (repro.core.intensity) and only ~4 patterns
are measured, exactly the paper's protocol.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

from repro.backends.base import Backend as Destination, SearchResult
from repro.core import ga as ga_mod, intensity
from repro.core.ga import Evaluation, GAConfig, GAResult
from repro.core.measure import TimedRunner
from repro.core.offloadable import OffloadableApp

# pre-redesign name for the per-verification result dataclass; the canonical
# definition moved to repro.backends.base
LoopSearchResult = SearchResult


def _measure_choice(app, choice, runner, inputs, ref_out,
                    penalty_s: Optional[float] = None) -> Evaluation:
    ev = runner.measure(app.build(choice), inputs, ref_out)
    if penalty_s is not None:
        ev.penalty_s = penalty_s      # one penalty scale per planner run
    return ev


def _lint_findings(lint_choice, choice) -> Optional[list]:
    """Error-severity findings for a choice, or None when it may run."""
    if lint_choice is None:
        return None
    findings = list(lint_choice(choice) or ())
    if any(getattr(f, "severity", None) == "error" for f in findings):
        return findings
    return None


def _pruned_evaluation(findings) -> Evaluation:
    return Evaluation(
        time_s=float("inf"), correct=False,
        info={"static_pruned": True,
              "static_findings": [f.to_dict() if hasattr(f, "to_dict")
                                  else f for f in findings]})


def ga_search(app: OffloadableApp, dest: Destination, runner: TimedRunner,
              inputs, ref_out, fixed_choice: Optional[Dict[str, str]] = None,
              ga_cfg: Optional[GAConfig] = None,
              seed: int = 0, lint_choice=None) -> LoopSearchResult:
    """Full GA over the app's nests for one destination.

    ``fixed_choice`` pins nests already offloaded as function blocks (the
    paper's residual rule); their genes are excluded from the search.
    ``lint_choice(choice)`` (see :class:`repro.backends.SearchContext`)
    statically rejects choices with error-severity findings for the
    penalty — no build, no measurement, the paper's structure-analysis
    narrowing applied inside the GA loop.
    """
    fixed_choice = dict(fixed_choice or {})
    free_nests = [n for n in app.nests if n.name not in fixed_choice]
    gene_len = len(free_nests)
    cfg = ga_cfg or GAConfig.for_gene_length(gene_len, seed=seed)
    if gene_len == 0:
        ev = _measure_choice(app, fixed_choice, runner, inputs, ref_out,
                             penalty_s=cfg.penalty_s)
        return LoopSearchResult(dest.name, fixed_choice, ev.effective_time,
                                1, 0.0, note="no free loops",
                                best_correct=ev.correct)

    # structural dedupe for the verification environment: distinct gene
    # strings can build the *same* offload pattern (a gene set on a nest
    # without this destination's impl falls back to "seq"), and measuring
    # one pattern twice is pure verification cost — memoize Evaluations by
    # the canonical choice dict, the paper-side analogue of
    # repro.core.search_cache's structural key
    measured: Dict[Tuple[Tuple[str, str], ...], Evaluation] = {}
    reused = [0]
    pruned = [0]

    def evaluate(genes: Tuple[int, ...]) -> Evaluation:
        choice = dict(fixed_choice)
        for nest, g in zip(free_nests, genes):
            choice[nest.name] = dest.key if (g and dest.key in nest.impls) \
                else "seq"
        ckey = tuple(sorted(choice.items()))
        if ckey in measured:
            reused[0] += 1
            return measured[ckey]
        findings = _lint_findings(lint_choice, choice)
        if findings is not None:
            pruned[0] += 1
            ev = _pruned_evaluation(findings)
        else:
            ev = _measure_choice(app, choice, runner, inputs, ref_out)
        measured[ckey] = ev
        return ev

    t0 = time.perf_counter()
    res: GAResult = ga_mod.run_ga(gene_len, evaluate, cfg)
    elapsed = time.perf_counter() - t0
    best_choice = dict(fixed_choice)
    for nest, g in zip(free_nests, res.best_genes):
        best_choice[nest.name] = dest.key if (g and dest.key in nest.impls) \
            else "seq"
    return LoopSearchResult(
        destination=dest.name, best_choice=best_choice,
        best_time_s=res.best_eval.effective_time,
        n_measurements=res.n_measurements, verify_elapsed_s=elapsed,
        history=res.history, best_correct=res.best_eval.correct,
        cache_stats={"measured": len(measured) - pruned[0],
                     "reused": reused[0], "static_pruned": pruned[0]})


def fpga_search(app: OffloadableApp, dest: Destination, runner: TimedRunner,
                inputs, ref_out, small_state,
                fixed_choice: Optional[Dict[str, str]] = None,
                penalty_s: Optional[float] = None,
                lint_choice=None) -> LoopSearchResult:
    """Narrow-then-measure protocol (<= 4 measured patterns).

    With ``lint_choice`` the static linter narrows *before* the measured
    budget is spent: a candidate pattern with an error-severity finding is
    dropped without a measurement and the next intensity-ranked pattern
    takes its slot — every one of the <= 4 measurements goes to a
    statically feasible pattern.
    """
    fixed_choice = dict(fixed_choice or {})
    t0 = time.perf_counter()
    candidates = [p for p in intensity.narrow(app, small_state)
                  if p.nest.name not in fixed_choice
                  and dest.key in p.nest.impls]
    n_pruned = 0
    singles = []
    for p in candidates:
        if len(singles) >= 3:
            break
        choice = dict(fixed_choice)
        choice[p.nest.name] = dest.key
        if _lint_findings(lint_choice, choice) is not None:
            n_pruned += 1
            continue
        ev = _measure_choice(app, choice, runner, inputs, ref_out,
                             penalty_s=penalty_s)
        singles.append((p.nest.name, ev))
    results = list(singles)
    good = [s for s in singles if s[1].correct]
    good.sort(key=lambda s: s[1].effective_time)
    if len(good) >= 2:
        choice = dict(fixed_choice)
        choice[good[0][0]] = dest.key
        choice[good[1][0]] = dest.key
        # two individually feasible patterns may still be statically
        # contradictory in combination
        if _lint_findings(lint_choice, choice) is not None:
            n_pruned += 1
        else:
            ev = _measure_choice(app, choice, runner, inputs, ref_out,
                                 penalty_s=penalty_s)
            results.append((f"{good[0][0]}+{good[1][0]}", ev))
    elapsed = time.perf_counter() - t0

    if not results:
        ev = _measure_choice(app, fixed_choice, runner, inputs, ref_out,
                             penalty_s=penalty_s)
        note = "no pallas-capable nests" if not candidates else \
            "all candidate patterns statically pruned"
        return LoopSearchResult(dest.name, fixed_choice, ev.effective_time,
                                1, elapsed, note=note,
                                best_correct=ev.correct,
                                cache_stats={"static_pruned": n_pruned})
    # as in run_ga: a wrong result never wins the search outright
    correct_results = [r for r in results if r[1].correct]
    best_name, best_ev = min(correct_results or results,
                             key=lambda r: r[1].effective_time)
    best_choice = dict(fixed_choice)
    if best_ev.correct:
        for nm in best_name.split("+"):
            best_choice[nm] = dest.key
    history = [{"pattern": nm, "time_s": e.effective_time,
                "correct": e.correct} for nm, e in results]
    return LoopSearchResult(
        destination=dest.name, best_choice=best_choice,
        best_time_s=best_ev.effective_time, n_measurements=len(results),
        verify_elapsed_s=elapsed, history=history,
        best_correct=best_ev.correct,
        cache_stats={"static_pruned": n_pruned})
