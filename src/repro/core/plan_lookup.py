"""Plan lookup: the hot read side of the planner, split from plan search.

``plan_offload`` (repro.core.planner) is the *write* side: it searches,
measures, compiles and mesh-verifies candidates — seconds to minutes of
work, amortized by :class:`~repro.core.search_cache.SearchCache`.  Nothing
on a request path can afford any of that.  This module is the *read* side:
a :class:`PlanLookup` holds warm analysis payloads (the same dicts the
search cache persists) and scores them with pure roofline arithmetic
(:meth:`CompiledCostRunner.score_analysis`), so a serve-time router
(repro.serve.router) answers "how fast / how many watts is this backend for
this request" in microseconds, provably without tracing or compiling.

The split contract:

  * **slow path** (offline): ``plan_offload(..., publish=lookup)`` registers
    every mesh-verified record's analysis under
    ``serve_key(backend, app)`` — including *failures* for incorrect
    records, so the hot path can refuse a destination the verification
    environment proved wrong without re-measuring it.
  * **hot path** (request): :meth:`PlanLookup.lookup` /
    :meth:`PlanLookup.score` never import or call into jax; a payload miss
    is a miss (the caller skips the backend), never a compile.

``CacheStats.lookups`` counts hot-path reads; ``CacheStats.misses`` (the
compile counter) must stay flat across any number of them — pinned by
tests/test_serve_router.py.
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence, Tuple

from repro.core.measure import CompiledCostRunner
from repro.core.search_cache import SearchCache


def serve_key(backend_name: str, arch: str, plan=None,
              extra: Sequence = ()) -> Tuple:
    """Cache identity of one (backend, arch[, plan]) serving artifact.

    ``plan`` (a :class:`repro.dist.plan.Plan`) folds its ``structural_key``
    in, so two endpoints serving the same arch under different serving
    plans (e.g. ``kv_cache_quant`` on/off) hold distinct warm entries.
    """
    pk = plan.structural_key() if plan is not None else None
    return ("serve", str(backend_name), str(arch), pk, tuple(extra))


def analysis_from_roofline(rl) -> Optional[dict]:
    """Recover the cacheable analysis dict from a ``Roofline`` (or its
    ``to_dict()`` form, e.g. ``VerificationRecord.mesh_info["roofline"]``).

    The per-device flops/bytes/collective terms are exactly what
    ``roofline_from_analysis`` consumes, so a record the planner already
    mesh-verified warms the lookup without keeping the executable around.
    """
    def term(name):
        v = rl.get(name) if isinstance(rl, Mapping) else getattr(rl, name,
                                                                 None)
        return None if v is None else float(v)

    flops = term("flops_per_device")
    byts = term("bytes_per_device")
    coll = term("collective_bytes_per_device")
    if flops is None or byts is None:
        return None
    return {"flops": flops, "bytes": byts,
            "collective_bytes": coll if coll is not None else 0.0}


class PlanLookup:
    """Warm plan-analysis table with trace/compile-free scoring.

    Thin, deliberately boring wrapper over a :class:`SearchCache` analysis
    layer: registration is the only path that may cost anything; every
    read is dict lookup + roofline arithmetic.
    """

    def __init__(self, cache: Optional[SearchCache] = None):
        self.cache = cache if cache is not None else SearchCache()

    # ------------------------------------------------------------ slow side
    def register(self, key, analysis: Mapping[str, float], *,
                 compile_s: float = 0.0, extra: Optional[dict] = None):
        """Publish a warm analysis payload (offline / search-time only)."""
        return self.cache.put(key, dict(analysis), compile_s, extra=extra)

    def register_failure(self, key, error: str):
        """Publish a verification failure: the hot path must *refuse* this
        key, not retry it (an incorrect record is never dispatched to)."""
        return self.cache.put_failure(key, error)

    # ------------------------------------------------------------- hot side
    def lookup(self, key) -> Optional[dict]:
        """Warm payload for ``key`` or None.  Never compiles."""
        return self.cache.lookup(key)

    def usable(self, payload) -> bool:
        """True iff a payload can score a request (warm and not a recorded
        failure)."""
        return bool(payload) and "error" not in payload \
            and isinstance(payload.get("analysis"), dict)

    def score(self, key, *, n_chips: int = 1, model_flops: float = 0.0,
              bubble_fraction: float = 0.0):
        """Roofline :class:`~repro.core.ga.Evaluation` for a warm key, or
        None on a miss / recorded failure.  Pure arithmetic."""
        payload = self.lookup(key)
        if not self.usable(payload):
            return None
        runner = CompiledCostRunner(n_chips=n_chips, model_flops=model_flops)
        return runner.score_analysis(payload["analysis"],
                                     bubble_fraction=bubble_fraction,
                                     cache_hit=True)

    @property
    def stats(self):
        return self.cache.stats


def analysis_from_time(time_s: float) -> Optional[dict]:
    """Synthetic analysis whose roofline reproduces a host-measured time.

    Destinations verified without a mesh bridge have no HLO roofline; the
    fallback mirrors ``energy_for_record``'s convention — the destination
    is assumed compute-busy for the measured seconds (flops = time ×
    peak), so ``score_analysis`` at ``n_chips=1`` returns ``time_s`` and
    full compute utilization.
    """
    if not (time_s > 0.0) or time_s == float("inf"):
        return None
    from repro.core.cost_model import PEAK_FLOPS
    return {"flops": time_s * PEAK_FLOPS, "bytes": 0.0,
            "collective_bytes": 0.0}


def publish_record(lookup: Optional[PlanLookup], record, backend,
                   app_name: str) -> bool:
    """Planner-side publish rule (the write half of the search/lookup
    split): a correct record warms ``serve_key(backend, app)`` — from its
    mesh roofline when the bridge recorded one, from the host time
    otherwise (:func:`analysis_from_time`); an incorrect one records a
    failure so the router can statically refuse the destination.  Returns
    True when something was published.
    """
    if lookup is None:
        return False
    key = serve_key(backend.name, app_name)
    if not getattr(record, "correct", False):
        # a backend runs several verifications (FB, loop) against one key:
        # only refuse the destination when nothing has succeeded — one
        # correct verification is a serveable destination even if another
        # method's pattern was wrong
        if not lookup.usable(lookup.cache.lookup(key, count=False)):
            lookup.register_failure(key, record.note or "incorrect result")
            return True
        return False
    rl = (record.mesh_info or {}).get("roofline")
    analysis = analysis_from_roofline(rl) if rl else None
    source = "roofline"
    if analysis is None:
        analysis = analysis_from_time(getattr(record, "best_time_s",
                                              float("inf")))
        source = "host-time"
    if analysis is None:
        return False
    lookup.register(key, analysis,
                    compile_s=getattr(record, "verify_elapsed_s", 0.0),
                    extra={"destination": backend.name,
                           "paper_analogue": backend.paper_analogue,
                           "source": source})
    return True
