"""Loop-aware cost analysis of compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts each op once — a ``jax.lax.scan``
over 64 layers contributes 1/64 of its real FLOPs.  This analyzer re-derives
per-device FLOPs / HBM bytes / collective bytes from ``compiled.as_text()``,
walking the call graph (ENTRY -> while bodies -> fusions) and multiplying
each op's cost by the product of enclosing ``known_trip_count``s.

Heuristics (documented, deliberately simple — dots dominate):
  * dot: 2 * prod(result_dims) * prod(lhs contracting dim sizes)
  * elementwise/reduce: prod(shape) flops
  * bytes: counted at fusion/op boundaries only (operands + result), i.e.
    values that cross HBM; ops inside a fused computation contribute flops
    but not bytes.
  * collectives: operand bytes (= result bytes for all-reduce; result for
    all-gather overestimates by the gather factor, matching wire traffic on
    a ring within 2x).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(.+?)\s+"
    r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(([^)]*(?:\([^)]*\))?"
                      r"[^)]*)\)\s+->")


@dataclass
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.elems * _DTYPE_BYTES.get(self.dtype, 4)


def parse_shapes(text: str) -> List[Shape]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append(Shape(dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


@dataclass
class Op:
    name: str
    opcode: str
    result: List[Shape]
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    ops: Dict[str, Op] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)


_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "power", "negate",
    "compare", "select", "and", "or", "xor", "not", "convert", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "sign", "cosine",
    "sine", "clamp", "abs", "atan2", "expm1", "log1p", "logistic",
    "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "popcnt", "cbrt", "erf", "is-finite", "tan",
}
_ZERO_COST = {
    "bitcast", "tuple", "get-tuple-element", "parameter", "constant",
    "after-all", "partition-id", "replica-id", "iota", "bitcast-convert",
    "opt-barrier",
}
_DATA_MOVE = {"copy", "transpose", "reshape", "broadcast", "slice",
              "dynamic-slice", "dynamic-update-slice", "concatenate", "pad",
              "reverse", "gather", "scatter", "copy-start", "copy-done",
              "all-gather-start", "all-gather-done"}


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, Computation] = {}
        self.entry: Optional[str] = None
        self._parse(text)

    def _parse(self, text: str):
        cur: Optional[Computation] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            if not stripped or stripped.startswith("//"):
                continue
            if (stripped.endswith("{") and "->" in stripped
                    and "=" not in stripped.split("(")[0]):
                m = _COMP_RE.match(stripped)
                if m:
                    name = m.group(1)
                    cur = Computation(name)
                    self.computations[name] = cur
                    if stripped.startswith("ENTRY"):
                        self.entry = name
                    continue
            if stripped == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _OP_RE.match(stripped)
            if not m:
                continue
            name, rtype, opcode = m.group(1), m.group(2), m.group(3)
            # operand names: %foo tokens inside the first paren group
            rest = stripped[m.end():]
            depth = 1
            arglist = []
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        arglist = re.findall(r"%([\w.\-]+)", rest[:i])
                        break
            op = Op(name=name, opcode=opcode, result=parse_shapes(rtype),
                    operands=arglist, line=stripped)
            cur.ops[name] = op
            cur.order.append(name)

    # ------------------------------------------------------------------
    def _result_bytes(self, op: Op) -> int:
        return sum(s.bytes for s in op.result)

    def _operand_bytes(self, comp: Computation, op: Op) -> int:
        total = 0
        for o in op.operands:
            src = comp.ops.get(o)
            if src is not None:
                total += self._result_bytes(src)
        return total

    def _dot_flops(self, comp: Computation, op: Op) -> float:
        result_elems = sum(s.elems for s in op.result)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
        lhs_shape = None
        if op.operands:
            src = comp.ops.get(op.operands[0])
            if src is not None and src.result:
                lhs_shape = src.result[0]
        if m and lhs_shape is not None:
            k = 1
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(lhs_shape.dims):
                    k *= lhs_shape.dims[int(idx)]
            return 2.0 * result_elems * k
        # fallback: assume square-ish contraction of size sqrt(lhs elems)
        if lhs_shape is not None:
            return 2.0 * result_elems * max(lhs_shape.dims[-1], 1)
        return 2.0 * result_elems

    def _callees(self, op: Op) -> List[Tuple[str, float]]:
        """(computation, multiplicity) pairs invoked by this op."""
        out = []
        if op.opcode == "while":
            trip = 1.0
            m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.line)
            if m:
                trip = float(m.group(1))
            b = re.search(r"body=%?([\w.\-]+)", op.line)
            if b:
                out.append((b.group(1), trip))
            c = re.search(r"condition=%?([\w.\-]+)", op.line)
            if c:
                out.append((c.group(1), trip))
        elif op.opcode in ("fusion", "call", "async-start", "map",
                           "reduce-window", "reduce", "scatter", "sort",
                           "select-and-scatter", "custom-call"):
            for attr in ("calls", "to_apply"):
                m = re.search(attr + r"=%?([\w.\-]+)", op.line)
                if m:
                    # reducer/comparator bodies run per element; fold into
                    # elementwise estimate instead of recursing for reduce &
                    # sort (their bodies are tiny).
                    if op.opcode in ("fusion", "call", "map",
                                     "async-start", "custom-call"):
                        out.append((m.group(1), 1.0))
        elif op.opcode == "conditional":
            for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                 r"(?:true|false)_computation=%?([\w.\-]+))",
                                 op.line):
                names = m.group(1) or m.group(2) or ""
                for n in re.findall(r"%?([\w.\-]+)", names):
                    out.append((n, 1.0))
        return out

    def analyze(self) -> Dict[str, float]:
        """Whole-module cost with loop multiplicities (per-device)."""
        flops = 0.0
        bytes_hbm = 0.0
        coll: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
        coll_counts: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
        visited_stack = set()

        def comp_cost(comp_name: str, mult: float, inside_fusion: bool):
            nonlocal flops, bytes_hbm
            comp = self.computations.get(comp_name)
            if comp is None or comp_name in visited_stack:
                return
            visited_stack.add(comp_name)
            for op_name in comp.order:
                op = comp.ops[op_name]
                oc = op.opcode
                if oc in _ZERO_COST:
                    pass
                elif oc == "dot":
                    flops += mult * self._dot_flops(comp, op)
                    if not inside_fusion:
                        bytes_hbm += mult * (self._result_bytes(op)
                                             + self._operand_bytes(comp, op))
                elif oc == "convolution":
                    # rough: 2 * result * (operand1 elems / output channels)
                    flops += mult * 2.0 * sum(s.elems for s in op.result) \
                        * 32.0
                    if not inside_fusion:
                        bytes_hbm += mult * (self._result_bytes(op)
                                             + self._operand_bytes(comp, op))
                elif oc.rstrip("-start-done") in COLLECTIVES or \
                        oc in COLLECTIVES or \
                        oc.replace("-start", "") in COLLECTIVES:
                    base = oc.replace("-start", "").replace("-done", "")
                    if base in COLLECTIVES and not oc.endswith("-done"):
                        b = self._operand_bytes(comp, op) or \
                            self._result_bytes(op)
                        coll[base] += mult * b
                        coll_counts[base] += mult
                        bytes_hbm += mult * (self._result_bytes(op)
                                             + self._operand_bytes(comp, op))
                elif oc in _ELEMENTWISE:
                    flops += mult * sum(s.elems for s in op.result)
                    if not inside_fusion:
                        bytes_hbm += mult * (self._result_bytes(op)
                                             + self._operand_bytes(comp, op))
                elif oc in ("reduce", "reduce-window"):
                    flops += mult * self._operand_elems(comp, op)
                    if not inside_fusion:
                        bytes_hbm += mult * (self._result_bytes(op)
                                             + self._operand_bytes(comp, op))
                elif oc == "sort":
                    n = sum(s.elems for s in op.result)
                    flops += mult * 10.0 * n
                    if not inside_fusion:
                        bytes_hbm += mult * (self._result_bytes(op)
                                             + self._operand_bytes(comp, op))
                elif oc in _DATA_MOVE or oc in ("fusion", "call",
                                                "custom-call", "while",
                                                "conditional", "map",
                                                "rng", "rng-bit-generator"):
                    if not inside_fusion and oc != "while":
                        bytes_hbm += mult * (self._result_bytes(op)
                                             + self._operand_bytes(comp, op))
                else:
                    if not inside_fusion:
                        bytes_hbm += mult * self._result_bytes(op)
                # recurse
                for callee, m2 in self._callees(op):
                    comp_cost(callee, mult * m2,
                              inside_fusion or op.opcode == "fusion")
            visited_stack.discard(comp_name)

        if self.entry:
            comp_cost(self.entry, 1.0, False)
        out = {"flops": flops, "bytes": bytes_hbm}
        out.update({f"coll_{k}": v for k, v in coll.items()})
        out.update({f"count_{k}": v for k, v in coll_counts.items()})
        out["collective_bytes"] = sum(coll.values())
        return out

    def _operand_elems(self, comp: Computation, op: Op) -> int:
        total = 0
        for o in op.operands:
            src = comp.ops.get(o)
            if src is not None:
                total += sum(s.elems for s in src.result)
        return total


def analyze_hlo(text: str) -> Dict[str, float]:
    return HloModule(text).analyze()
