"""Verification environment: dynamic measurement of candidate patterns.

Two runners (DESIGN.md §2 "verification environment"):

  * :class:`TimedRunner` — actually executes the candidate on this machine,
    times it (best-of-k after a compile warmup), and applies the paper's
    result-equality check: a result differing from the un-offloaded
    reference, or a timeout, sets processing time to 1000 s so the pattern
    dies out of the GA.

  * :class:`CompiledCostRunner` — lowers + compiles the candidate for a
    production mesh and scores it with the three-term roofline from the
    loop-aware HLO analysis.  Dynamic in the paper's sense (the measured
    object is the artifact the toolchain actually produced), used where the
    workload cannot run on the verification machine (pod-scale models).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.core.ga import Evaluation
from repro.core import cost_model
from repro.core.search_cache import analyze_compiled


def outputs_close(a, b, rtol=1e-2, atol=1e-2) -> bool:
    try:
        la = jax.tree.leaves(a)
        lb = jax.tree.leaves(b)
        if len(la) != len(lb):
            return False
        for x, y in zip(la, lb):
            x = np.asarray(x)
            y = np.asarray(y)
            if x.shape != y.shape:
                return False
            if x.dtype.kind in "biu" and y.dtype.kind in "biu":
                # integer/bool results compare exactly — a float64 round
                # trip is silently lossy above 2**53
                if not np.array_equal(x, y):
                    return False
                continue
            x = x.astype(np.float64)
            y = y.astype(np.float64)
            if not np.allclose(x, y, rtol=rtol, atol=atol, equal_nan=False):
                return False
            if not np.isfinite(x).all():
                return False
        return True
    except Exception:
        return False


class TimedRunner:
    def __init__(self, timeout_s: float = 180.0, rtol: float = 1e-2,
                 atol: float = 1e-2, repeats: int = 3):
        self.timeout_s = timeout_s
        self.rtol = rtol
        self.atol = atol
        self.repeats = repeats

    def measure(self, fn: Callable, inputs, reference_out) -> Evaluation:
        """Time fn(inputs) and check it against reference_out.

        ``reference_out=None`` means "this IS the reference run": the result
        is trivially correct and callers reuse ``info["output"]`` instead of
        executing the reference a second time (see planner.plan_offload).
        """
        jfn = jax.jit(fn)
        try:
            t0 = time.perf_counter()
            out = jax.block_until_ready(jfn(inputs))      # compile + run
            first = time.perf_counter() - t0
            if first > self.timeout_s:
                return Evaluation(time_s=first, correct=False,
                                  timed_out=True)
            times = []
            for _ in range(self.repeats):
                # every call gets the budget, not only the first: a
                # candidate whose steady-state repeats hang must die
                # through the paper's penalty path instead of running
                # unbounded (per-call, so a legitimately slow-but-correct
                # candidate under timeout_s per run is still measured)
                t0 = time.perf_counter()
                out = jax.block_until_ready(jfn(inputs))
                dt = time.perf_counter() - t0
                if dt > self.timeout_s:
                    return Evaluation(time_s=dt, correct=False,
                                      timed_out=True)
                times.append(dt)
            if reference_out is None:
                # reference run: keep the output for reuse; candidate runs
                # drop it (the GA cache would otherwise pin one output-sized
                # array per evaluated gene string)
                return Evaluation(time_s=min(times), correct=True,
                                  info={"first_call_s": first,
                                        "output": out})
            correct = outputs_close(out, reference_out, self.rtol, self.atol)
            return Evaluation(time_s=min(times), correct=correct,
                              info={"first_call_s": first})
        except Exception as e:   # compile error == paper's "conversion fails"
            return Evaluation(time_s=float("inf"), correct=False,
                              info={"error": repr(e)[:500]})


class CompiledCostRunner:
    def __init__(self, mesh=None, n_chips: Optional[int] = None,
                 model_flops: float = 0.0):
        self.mesh = mesh
        self.n_chips = n_chips or (mesh.size if mesh is not None else 1)
        self.model_flops = model_flops

    def score_analysis(self, analyzed: dict, verify_s: float = 0.0, *,
                       bubble_fraction: float = 0.0,
                       cache_hit: Optional[bool] = None) -> Evaluation:
        """Roofline-score an ``analyze_hlo`` result dict — pure arithmetic.

        This is the cache-hit scoring path (repro.core.search_cache): the
        analysis dict stands in for the compiled artifact, so re-scoring
        the same artifact under a different ``bubble_fraction`` or
        selection policy never touches HLO text.
        """
        try:
            rl = cost_model.roofline_from_analysis(
                analyzed, n_chips=self.n_chips,
                model_flops=self.model_flops,
                bubble_fraction=bubble_fraction)
            info = {"roofline": rl.to_dict(), "verify_s": verify_s}
            if cache_hit is not None:
                info["cache_hit"] = cache_hit
            return Evaluation(time_s=rl.step_time_s, correct=True,
                              info=info)
        except Exception as e:
            return Evaluation(time_s=float("inf"), correct=False,
                              info={"error": repr(e)[:500]})

    def score_compiled(self, compiled, verify_s: float = 0.0, *,
                       bubble_fraction: float = 0.0) -> Evaluation:
        """Roofline-score an already-compiled executable.

        Split from :meth:`measure_lowered` so callers that batch the XLA
        lowering/compilation across a GA population (examples/
        autoplan_model.py) can score the artifacts afterwards.
        ``bubble_fraction`` folds a pipeline schedule's idle fraction into
        the modeled step time (``cost_model.plan_bubble_fraction``), so the
        ``modeled`` policy ranks schedule genes correctly.  The HLO
        analysis is memoized per artifact (search_cache.analyze_compiled):
        scoring the same executable twice parses its text once.
        """
        try:
            analyzed = analyze_compiled(compiled)
        except Exception as e:
            return Evaluation(time_s=float("inf"), correct=False,
                              info={"error": repr(e)[:500]})
        return self.score_analysis(analyzed, verify_s,
                                   bubble_fraction=bubble_fraction)

    def measure_lowered(self, jitted, *args_sds,
                        bubble_fraction: float = 0.0) -> Evaluation:
        try:
            t0 = time.perf_counter()
            compiled = jitted.lower(*args_sds).compile()
            verify_s = time.perf_counter() - t0
        except Exception as e:
            return Evaluation(time_s=float("inf"), correct=False,
                              info={"error": repr(e)[:500]})
        return self.score_compiled(compiled, verify_s,
                                   bubble_fraction=bubble_fraction)

    def measure(self, fn: Callable, inputs_sds, in_shardings=None, *,
                bubble_fraction: float = 0.0) -> Evaluation:
        jitted = (jax.jit(fn, in_shardings=in_shardings)
                  if in_shardings is not None else jax.jit(fn))
        return self.measure_lowered(jitted, inputs_sds,
                                    bubble_fraction=bubble_fraction)
