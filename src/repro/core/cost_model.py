"""Three-term roofline model from a compiled XLA artifact (TPU v5e targets).

``cost_analysis()`` on a post-SPMD executable reports *per-device* FLOPs and
bytes, so the terms divide by per-chip peak numbers directly (equivalent to
the global/chips formulation in the task spec).  Collective bytes are parsed
from the compiled HLO text — XLA's cost model does not expose them.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link (task-spec constant)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in compiled HLO text.

    Returns {op_kind: bytes, ..., "_total": total}.  Operand shapes are the
    dtype[dims] patterns inside the op's argument list; if none parse (e.g.
    variadic formatting), the result shape before '=' is used as fallback.
    """
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(
            r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*))\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(-start|-done)?\(", stripped)
        if not m:
            continue
        kind, phase = m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start
        args = stripped[m.end():]
        # strip trailing metadata (replica_groups etc.) — operands come first
        paren = 0
        for i, ch in enumerate(args):
            if ch == "(":
                paren += 1
            elif ch == ")":
                if paren == 0:
                    args = args[:i]
                    break
                paren -= 1
        shapes = _SHAPE_RE.findall(args)
        if not shapes:
            shapes = _SHAPE_RE.findall(m.group(1))
        total = sum(_shape_bytes(dt, dims) for dt, dims in shapes
                    if dt in _DTYPE_BYTES)
        out[kind] += total
        counts[kind] += 1
    out["_total"] = sum(out[k] for k in COLLECTIVE_OPS)
    out["_counts"] = counts  # type: ignore
    return out


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0          # 6*N*D (active params for MoE)
    useful_flops_ratio: float = 0.0   # MODEL_FLOPS / (HLO_FLOPs * chips)
    step_time_s: float = 0.0          # max of the three terms / (1 - bubble)
    roofline_fraction: float = 0.0    # useful compute time / step time
    bubble_fraction: float = 0.0      # pipeline-schedule idle fraction
    pipeline_s: float = 0.0           # extra step time the bubble costs
    # utilization terms (each roofline term / step time, so bubbles shrink
    # them) — the inputs repro.power.EnergyModel turns into watts
    compute_util: float = 0.0
    memory_util: float = 0.0
    collective_util: float = 0.0

    def to_dict(self):
        return asdict(self)


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float, *, n_chips: int,
                   model_flops: float = 0.0,
                   bubble_fraction: float = 0.0) -> Roofline:
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = collective_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    busy = max(compute_s, memory_s, collective_s)
    # a pipeline schedule idles each rank for bubble_fraction of the step:
    # the busy roofline time is only (1 - bubble) of the wall clock
    bubble = min(max(bubble_fraction, 0.0), 0.999)
    step = busy / (1.0 - bubble)
    useful = model_flops / (flops * n_chips) if flops else 0.0
    useful_time = (model_flops / n_chips) / PEAK_FLOPS
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        collective_bytes_per_device=collective_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_flops_ratio=useful,
        step_time_s=step,
        roofline_fraction=(useful_time / step) if step else 0.0,
        bubble_fraction=bubble,
        pipeline_s=step - busy,
        compute_util=(compute_s / step) if step else 0.0,
        memory_util=(memory_s / step) if step else 0.0,
        collective_util=(collective_s / step) if step else 0.0,
    )


def roofline_from_analysis(analyzed: Dict[str, float], *, n_chips: int,
                           model_flops: float = 0.0,
                           bubble_fraction: float = 0.0) -> Roofline:
    """Roofline from an ``analyze_hlo`` result dict.

    The analysis dict is the cacheable face of a compiled artifact
    (repro.core.search_cache stores exactly this), so re-scoring under a
    different bubble fraction / policy is pure arithmetic — no HLO reparse.
    """
    return roofline_terms(analyzed["flops"], analyzed["bytes"],
                          analyzed["collective_bytes"], n_chips=n_chips,
                          model_flops=model_flops,
                          bubble_fraction=bubble_fraction)


# --------------------------------------------------------------------------
# Pipeline-schedule terms (closed forms; repro.dist.schedules builds the
# matching tick plans and tests pin the two together).
# --------------------------------------------------------------------------

KNOWN_SCHEDULES = ("gpipe", "one_f_one_b", "interleaved")


def _schedule_virtual(schedule: str, virtual_stages: int) -> int:
    """gpipe / one_f_one_b run one chunk per rank whatever the plan says."""
    return virtual_stages if schedule == "interleaved" else 1


def pipeline_bubble_fraction(schedule: str, n_ranks: int, microbatches: int,
                             virtual_stages: int = 1) -> float:
    """Idle-tick fraction of the schedule's static plan.

    With stride = max(m, R) and V recirculation passes the plan runs
    (V-1)*stride + m + R - 1 ticks of which V*m do work per rank —
    gpipe/1F1B (V=1): bubble (R-1)/(m+R-1); interleaved with m >= R:
    (R-1)/(V*m + R - 1).  A name outside these closed forms is asked for
    its own tick plan (custom ``register_schedule`` entries know their
    bubble); a name nothing knows models as bubble 0 — the sequential
    fallback ``pipeline_apply`` would actually run — never as gpipe.
    """
    if n_ranks <= 1:
        return 0.0
    m = max(microbatches, 1)
    if schedule not in KNOWN_SCHEDULES:
        try:
            from repro.dist.schedules import get_schedule
            sched = get_schedule(schedule)
        except Exception:
            sched = None
        if sched is None:
            return 0.0
        v = max(virtual_stages, 1)
        built = sched.build(n_stages=n_ranks * v, n_ranks=n_ranks,
                            microbatches=m, virtual_stages=v)
        return built.bubble_fraction if built is not None else 0.0
    v = max(_schedule_virtual(schedule, virtual_stages), 1)
    total = (v - 1) * max(m, n_ranks) + m + n_ranks - 1
    return (total - v * m) / total


def pipeline_in_flight(schedule: str, n_ranks: int, microbatches: int,
                       virtual_stages: int = 1) -> int:
    """Per-rank live microbatch activations the schedule's backward keeps.

    gpipe holds all m; 1F1B caps at min(R, m); interleaved adds V-1 chunk
    activations awaiting recirculation on top of the 1F1B cap.
    """
    m = max(microbatches, 1)
    if n_ranks <= 1:
        return m
    if schedule == "one_f_one_b":
        return min(n_ranks, m)
    if schedule == "interleaved":
        v = max(virtual_stages, 1)
        return min(m * v, min(n_ranks, m) + v - 1)
    return m


def plan_bubble_fraction(plan, n_ranks: int) -> float:
    """Bubble fraction a Plan's pipeline genes imply on an n_ranks pipeline
    axis (0.0 when there is no such axis)."""
    return pipeline_bubble_fraction(
        getattr(plan, "pipeline_schedule", "gpipe"), n_ranks,
        max(getattr(plan, "microbatches", 1), 1),
        getattr(plan, "virtual_stages", 1))


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens processed.

    decode shapes process global_batch tokens per step; train/prefill process
    global_batch*seq_len.  Training includes the backward pass (the 6 factor
    already assumes fwd+bwd: 2 fwd + 4 bwd per param per token); for pure
    inference (prefill/decode) the right factor is 2.
    """
    n = cfg.active_params() if cfg.moe is not None else cfg.n_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch
    return 2.0 * n * tokens
