"""Function-block offloading: discovery by name matching + Deckard-style
jaxpr similarity, replacement from a per-destination registry (paper [41]).

The registry is the paper's "DB": each entry names a known algorithmic block
(time-domain FIR, matmul chain, attention) together with destination-
optimized implementations — the FPGA-analogue entries are the Pallas kernels
in ``repro.kernels``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.core import jaxpr_tools
from repro.core.offloadable import LoopNest, OffloadableApp

SIMILARITY_THRESHOLD = 0.55


@dataclass
class FunctionBlockEntry:
    name: str
    match_names: tuple                     # DB name-matching tokens
    ref_fn: Callable                       # canonical implementation
    example_args: Callable[[], tuple]      # small example inputs for ref_fn
    impls: Dict[str, Callable]             # dest.key -> replacement nest impl
    doc: str = ""

    def fingerprint(self):
        if not hasattr(self, "_fp"):
            self._fp = jaxpr_tools.fn_fingerprint(self.ref_fn,
                                                  *self.example_args())
        return self._fp


class Registry:
    def __init__(self):
        self.entries: List[FunctionBlockEntry] = []

    def register(self, entry: FunctionBlockEntry):
        self.entries.append(entry)
        return entry

    def __iter__(self):
        return iter(self.entries)


REGISTRY = Registry()


@dataclass
class FBMatch:
    nest: LoopNest
    entry: FunctionBlockEntry
    method: str            # "name" | "similarity"
    score: float


def detect(app: OffloadableApp, small_state=None,
           registry: Registry = REGISTRY,
           threshold: float = SIMILARITY_THRESHOLD) -> List[FBMatch]:
    """Find registry blocks inside the app's nests.

    Name matching first (paper's DB name match); nests that don't match by
    name are fingerprinted against every registry entry (Deckard analogue).
    """
    matches: List[FBMatch] = []
    for nest in app.nests:
        by_name = None
        for entry in registry:
            if any(tok in nest.name.lower() for tok in entry.match_names):
                by_name = FBMatch(nest, entry, "name", 1.0)
                break
        if by_name is not None:
            matches.append(by_name)
            continue
        if small_state is None:
            continue
        try:
            fp = jaxpr_tools.fn_fingerprint(nest.impls["seq"], small_state)
        except Exception:
            continue
        best: Optional[FBMatch] = None
        for entry in registry:
            s = jaxpr_tools.similarity(fp, entry.fingerprint())
            if s >= threshold and (best is None or s > best.score):
                best = FBMatch(nest, entry, "similarity", s)
        if best is not None:
            matches.append(best)
    return matches


def apply_matches(app: OffloadableApp, matches: List[FBMatch],
                  dest_key: str) -> Optional[Dict[str, str]]:
    """Choice dict running matched nests on the destination's FB impl.

    Returns None if no matched entry provides an implementation for this
    destination (paper: "no offloadable function block").
    """
    choice: Dict[str, str] = {}
    found = False
    for m in matches:
        impl = m.entry.impls.get(dest_key)
        if impl is None:
            continue
        key = f"fb_{m.entry.name}_{dest_key}"
        m.nest.impls[key] = impl
        choice[m.nest.name] = key
        found = True
    return choice if found else None
