"""Structure-keyed search cache: plan search at O(unique artifacts).

The plan GA's bottleneck is the verification environment: every candidate
must be traced, lowered, XLA-compiled and its HLO re-analyzed — yet many
candidates share the *identical* compiled artifact (the model-only
pipeline-schedule genes differ only in the modeled bubble term, see
``repro.dist.plan.Gene.structural``), and repeated invocations recompile
artifacts an earlier run already measured.  This module collapses the
per-candidate cost to per-unique-artifact cost with three layers:

  * an in-memory **artifact layer** (``get_compiled`` / ``put_compiled``)
    holding live compiled executables for the current process;
  * a memory + on-disk **analysis layer** (``lookup`` / ``put``): a JSON
    file mapping ``sha256(structural key + run identity)`` to the
    ``analyze_hlo`` result, the compile seconds it cost, and arbitrary
    caller extras — a warm cache scores candidates with pure roofline
    arithmetic, zero compiles;
  * a per-artifact ``analyze_hlo`` memo (:func:`analyze_compiled`) so an
    executable's HLO text is parsed at most once no matter how many
    policies / bubble fractions re-score it.

:func:`make_cached_batch_evaluator` packages the layers as a
``run_ga(evaluate_batch=...)`` callback: a generation is deduped by
``Plan.structural_key()`` *before* tracing, unique keys are traced +
compiled on a thread pool, and every candidate is scored from the shared
analysis with its own ``bubble_fraction``.

Disk entries that are corrupted, truncated, or from an incompatible cache
version are ignored (the key recompiles); a cache failure is never an
error.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading
import time
import weakref
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.hlo_analysis import analyze_hlo

CACHE_VERSION = 1
# an analysis payload must feed cost_model.roofline_from_analysis
REQUIRED_ANALYSIS_KEYS = ("flops", "bytes", "collective_bytes")


# --------------------------------------------------------------------- keys
def _jsonable(obj):
    if isinstance(obj, (list, tuple)):
        return [_jsonable(x) for x in obj]
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def canonical_key(key) -> str:
    """Stable JSON string for an arbitrarily nested key structure."""
    return json.dumps(_jsonable(key), sort_keys=True, separators=(",", ":"))


def hash_key(key) -> str:
    return hashlib.sha256(canonical_key(key).encode()).hexdigest()[:32]


def runtime_fingerprint() -> str:
    """Compiler identity stamped into the disk layer.

    An analysis payload describes what *this* jax/XLA on *this* platform
    lowered — a different jax version or device kind produces different
    HLO, so a file written by another runtime must read as cold, not as
    hits serving stale rooflines.
    """
    try:
        import jax
        return f"jax-{jax.__version__}-{jax.default_backend()}"
    except Exception:
        return "nojax"


def mesh_fingerprint(mesh) -> tuple:
    """Cache-key identity of a mesh: axis names/sizes + device count.

    Structural keys must distinguish artifacts compiled for different
    meshes; the axis layout and device count are what SPMD partitioning
    sees.
    """
    if mesh is None:
        return ("nomesh",)
    try:
        return tuple((str(a), int(s)) for a, s in mesh.shape.items())
    except Exception:
        return (repr(mesh),)


# -------------------------------------------------------------- statistics
@dataclass
class CacheStats:
    """Counters for search observability (hit/miss are per candidate)."""
    candidates: int = 0      # candidates scored through the cache
    hits: int = 0            # scored without a fresh compile
    disk_hits: int = 0       # subset of hits served by the on-disk layer
    misses: int = 0          # fresh lower+compile (== unique artifacts)
    compile_s: float = 0.0   # wall seconds spent in fresh lower+compile
    # hot-path reads through lookup() (repro.core.plan_lookup / the serve
    # router): after warm-up these grow while ``misses`` stays flat — the
    # trace/compile-free routing guarantee is exactly that invariant
    lookups: int = 0
    # candidates rejected by the static linter (repro.analysis) before any
    # tracing — they count in ``candidates`` but in neither hits nor misses
    static_pruned: int = 0

    @property
    def unique_compiles(self) -> int:
        return self.misses

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def to_dict(self) -> dict:
        return {"candidates": self.candidates, "hits": self.hits,
                "disk_hits": self.disk_hits,
                "unique_compiles": self.unique_compiles,
                "hit_rate": round(self.hit_rate, 4),
                "compile_s": round(self.compile_s, 3),
                "static_pruned": self.static_pruned,
                "lookups": self.lookups}


# ------------------------------------------------------------------- cache
class SearchCache:
    """Two-layer structure-keyed cache (see module docstring).

    ``path=None`` keeps everything in memory; with a path, valid entries
    are loaded eagerly and every ``put`` autosaves (atomic replace), so
    concurrent / aborted runs leave at worst a complete older file.
    """

    def __init__(self, path: Optional[os.PathLike] = None, *,
                 autosave: bool = True, artifact_capacity: int = 16):
        self.path = Path(path) if path is not None else None
        self.autosave = autosave
        self.artifact_capacity = artifact_capacity
        self._lock = threading.RLock()
        self._entries: Dict[str, dict] = {}
        self._from_disk: set = set()
        self._failed: Dict[str, dict] = {}      # memory-only failure memo
        # memory-only executables, FIFO-bounded: an XLA executable can be
        # huge and the analysis layer is all that scoring ever needs again
        self._compiled: Dict[str, Any] = {}
        self.stats = CacheStats()
        if self.path is not None:
            self._load()

    # ---------------------------------------------------------- disk layer
    @staticmethod
    def valid_payload(payload) -> bool:
        """True iff a payload can score candidates without recompiling."""
        if not isinstance(payload, dict):
            return False
        analysis = payload.get("analysis")
        if not isinstance(analysis, dict):
            return False
        return all(isinstance(analysis.get(k), (int, float))
                   for k in REQUIRED_ANALYSIS_KEYS)

    def _load(self):
        try:
            raw = json.loads(self.path.read_text())
        except Exception:
            return                   # missing/corrupted file == cold cache
        if not isinstance(raw, dict) or raw.get("version") != CACHE_VERSION:
            return
        if raw.get("runtime") != runtime_fingerprint():
            return               # another jax/XLA/platform wrote this file
        entries = raw.get("entries")
        if not isinstance(entries, dict):
            return
        for h, payload in entries.items():
            if self.valid_payload(payload):      # stale/partial entry: skip
                self._entries[h] = payload
                self._from_disk.add(h)

    def save(self):
        if self.path is None:
            return
        with self._lock:
            data = {"version": CACHE_VERSION,
                    "runtime": runtime_fingerprint(),
                    "entries": self._entries}
            self.path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                       prefix=self.path.name, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(data, f)
                os.replace(tmp, self.path)
            except Exception:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass

    # ------------------------------------------------------ analysis layer
    def lookup(self, key, *, count: bool = True) -> Optional[dict]:
        """Analysis payload for ``key`` or None (a miss is not counted —
        the subsequent :meth:`put` / :meth:`put_failure` counts it)."""
        h = hash_key(key)
        with self._lock:
            payload = self._entries.get(h)
            if payload is None:
                payload = self._failed.get(h)
            if count:
                self.stats.lookups += 1
            if payload is not None and count:
                self.stats.hits += 1
                if h in self._from_disk:
                    self.stats.disk_hits += 1
            return payload

    def put(self, key, analysis: Dict[str, float], compile_s: float,
            extra: Optional[dict] = None) -> dict:
        payload = {"analysis": {k: float(v) for k, v in analysis.items()},
                   "compile_s": float(compile_s)}
        if extra:
            payload["extra"] = extra
        with self._lock:
            self._entries[hash_key(key)] = payload
            self.stats.misses += 1
            self.stats.compile_s += float(compile_s)
        if self.autosave:
            self.save()
        return payload

    def put_failure(self, key, error: str) -> dict:
        """Memoize a lower/compile failure (memory only: a failure may be
        environmental, so it must not poison the disk layer).

        A failure supersedes any earlier success for the same key — the
        latest verification verdict wins, so a serve-time lookup can never
        dispatch to a destination the planner has since proven wrong."""
        payload = {"error": error}
        h = hash_key(key)
        with self._lock:
            self._entries.pop(h, None)
            self._from_disk.discard(h)
            self._failed[h] = payload
            self.stats.misses += 1
        return payload

    def from_disk(self, key) -> bool:
        return hash_key(key) in self._from_disk

    # ------------------------------------------------------ artifact layer
    def get_compiled(self, key):
        return self._compiled.get(hash_key(key))

    def put_compiled(self, key, compiled):
        with self._lock:
            while len(self._compiled) >= max(self.artifact_capacity, 1):
                self._compiled.pop(next(iter(self._compiled)))
            self._compiled[hash_key(key)] = compiled


# ----------------------------------------------- analyze_hlo memoization
_analysis_memo: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
# id-keyed fallback for non-weakref-able executables; holding a strong ref
# pins the id, bounded FIFO so it cannot grow without limit
_analysis_memo_strong: Dict[int, Tuple[Any, Dict[str, float]]] = {}
_ANALYSIS_MEMO_STRONG_MAX = 64
_analysis_lock = threading.Lock()


def analyze_compiled(compiled) -> Dict[str, float]:
    """Memoized ``analyze_hlo(compiled.as_text())``.

    ``as_text()`` (an executable-sized string build) and the multi-regex
    HLO walk run at most once per artifact — re-scoring the same executable
    under a different bubble fraction or selection policy is free.

    The parse itself runs outside the memo lock (double-checked) so the
    batch evaluator's worker pool analyzes distinct artifacts
    concurrently; two threads racing on the *same* artifact may parse it
    twice, which is merely the cost this memo usually saves.
    """
    def _get():
        try:
            return _analysis_memo.get(compiled)
        except TypeError:                        # not weakref-able
            entry = _analysis_memo_strong.get(id(compiled))
            return entry[1] if entry is not None \
                and entry[0] is compiled else None

    with _analysis_lock:
        cached = _get()
    if cached is not None:
        return cached
    analysis = analyze_hlo(compiled.as_text())
    with _analysis_lock:
        cached = _get()
        if cached is not None:
            return cached
        try:
            _analysis_memo[compiled] = analysis
        except TypeError:
            while len(_analysis_memo_strong) >= _ANALYSIS_MEMO_STRONG_MAX:
                _analysis_memo_strong.pop(next(iter(_analysis_memo_strong)))
            _analysis_memo_strong[id(compiled)] = (compiled, analysis)
        return analysis


# ------------------------------------------------------- batch evaluator
def make_cached_batch_evaluator(
        lower_plan: Callable[[Any], Any],
        runner,
        cache: Optional[SearchCache] = None,
        *,
        key_extra: Sequence = (),
        pipe_ranks: int = 1,
        workers: int = 4,
        from_genes: Optional[Callable[[Tuple[int, ...]], Any]] = None,
        lint: Optional[Callable[[Any], Sequence]] = None,
) -> Callable[[List[Tuple[int, ...]]], List[Any]]:
    """Build a ``run_ga(evaluate_batch=...)`` callback over the cache.

    ``lower_plan(plan)`` traces + lowers one candidate and returns a jax
    ``Lowered`` (it runs on the worker pool, so tracing is no longer a
    serial prefix of the generation); ``runner`` is a
    :class:`repro.core.measure.CompiledCostRunner`; ``key_extra`` names the
    run identity ((arch, shape, mesh fingerprint, ...)) baked into every
    cache key; ``pipe_ranks`` sizes the pipeline axis the model-only
    schedule genes are charged against.

    Per generation: candidates are deduped by ``plan.structural_key()``
    *before* any tracing, unique missing keys are traced/compiled/analyzed
    concurrently, and each candidate is scored from its key's analysis with
    its own bubble fraction — at most one XLA compile per unique structural
    key, ever.  The callback exposes ``.cache`` (the :class:`SearchCache`)
    and ``.evaluate`` (a per-individual fallback for ``run_ga``).

    ``lint(plan)`` (e.g. a closure over
    :func:`repro.analysis.lint_plan`) returns static findings for one
    candidate; any error-severity finding rejects it with the GA penalty
    *before* tracing — it never reaches the worker pool or XLA, and
    ``stats.static_pruned`` counts it.  Lint verdicts are memoized per
    structural key, so a plan family is linted once per generation no
    matter how many schedule variants the GA breeds.
    """
    from concurrent.futures import ThreadPoolExecutor

    from repro.core import cost_model
    from repro.core.ga import Evaluation
    from repro.obs import get_tracer

    if cache is None:
        cache = SearchCache()
    if from_genes is None:
        from repro.dist.plan import Plan

        def from_genes(genes):
            return Plan.from_genes(list(genes))

    key_prefix = tuple(key_extra)
    lint_memo: Dict[Tuple[int, ...], list] = {}

    def evaluate_batch(generation: List[Tuple[int, ...]]) -> List[Any]:
        gen_span = get_tracer().span("evaluate_batch", cat="search",
                                     track="search",
                                     candidates=len(generation))
        plans = [from_genes(g) for g in generation]
        keys = [(key_prefix, p.structural_key()) for p in plans]
        hashes = [hash_key(k) for k in keys]
        cache.stats.candidates += len(generation)

        # static pruning: error-severity lint findings reject a candidate
        # before it can reach the trace/compile pool (memoized per gene
        # tuple — findings may depend on model-only genes, so the memo key
        # is the full individual, not the structural key)
        pruned: Dict[int, list] = {}             # generation idx -> findings
        if lint is not None:
            for i, (genes, plan) in enumerate(zip(generation, plans)):
                gk = tuple(genes)
                findings = lint_memo.get(gk)
                if findings is None:
                    findings = list(lint(plan) or ())
                    lint_memo[gk] = findings
                if any(getattr(f, "severity", None) == "error"
                       for f in findings):
                    pruned[i] = findings
            cache.stats.static_pruned += len(pruned)

        payloads: Dict[str, dict] = {}
        todo: Dict[str, tuple] = {}              # hash -> (key, plan)
        for i, (h, key, plan) in enumerate(zip(hashes, keys, plans)):
            if i in pruned or h in payloads or h in todo:
                continue
            payload = cache.lookup(key, count=False)
            if payload is not None:
                payloads[h] = payload
            else:
                todo[h] = (key, plan)

        def build(item):
            key, plan = item
            with get_tracer().span("compile", cat="search",
                                   track="search") as csp:
                try:
                    t0 = time.perf_counter()
                    compiled = lower_plan(plan).compile()
                    dt = time.perf_counter() - t0
                    analysis = analyze_compiled(compiled)
                    cache.put_compiled(key, compiled)
                    csp.set(ok=True, compile_s=dt)
                    return cache.put(key, analysis, dt)
                except Exception as e:  # compile error == conversion fails
                    csp.set(ok=False)
                    return cache.put_failure(key, repr(e)[:500])

        if todo:
            n = max(1, min(workers, len(todo)))
            with ThreadPoolExecutor(max_workers=n) as ex:
                for h, payload in zip(todo, ex.map(build, todo.values())):
                    payloads[h] = payload
        # per-candidate accounting: every non-pruned candidate that did not
        # pay for its own compile is a hit (put/put_failure counted the
        # misses; statically pruned candidates never enter the cache)
        cache.stats.hits += len(generation) - len(pruned) - len(todo)
        for i, (h, key) in enumerate(zip(hashes, keys)):
            if i not in pruned and h not in todo and cache.from_disk(key):
                cache.stats.disk_hits += 1

        out = []
        for i, (h, key, plan) in enumerate(zip(hashes, keys, plans)):
            if i in pruned:
                out.append(Evaluation(
                    time_s=float("inf"), correct=False,
                    info={"static_pruned": True,
                          "static_findings": [
                              f.to_dict() if hasattr(f, "to_dict") else f
                              for f in pruned[i]]}))
                continue
            payload = payloads[h]
            if "error" in payload:
                out.append(Evaluation(time_s=float("inf"), correct=False,
                                      info={"error": payload["error"]}))
                continue
            bubble = cost_model.plan_bubble_fraction(plan, pipe_ranks)
            fresh = h in todo
            out.append(runner.score_analysis(
                payload["analysis"],
                payload.get("compile_s", 0.0) if fresh else 0.0,
                bubble_fraction=bubble, cache_hit=not fresh))
        gen_span.set(n_pruned=len(pruned), compiles=len(todo),
                     n_fresh=len(todo),
                     hits=len(generation) - len(pruned) - len(todo))
        gen_span.finish()
        return out

    def evaluate(genes):
        return evaluate_batch([genes])[0]

    evaluate_batch.cache = cache
    evaluate_batch.evaluate = evaluate
    return evaluate_batch
