"""Offloading destinations: the TPU-native mapping of {many-core CPU, GPU,
FPGA} (DESIGN.md §2).

Price ordering follows the paper ("the central price range is the ascending
order of GPU, many core CPU and FPGA") and verification-time ordering too
("many core CPU, GPU and FPGA"); both are configurable because the planner's
early-stop logic consumes them, not their absolute values.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List


@dataclass(frozen=True)
class Destination:
    key: str              # impl key inside LoopNest.impls
    name: str
    paper_analogue: str
    price: float          # relative $ (paper ordering: GPU < many-core < FPGA)
    verify_time: float    # relative verification cost (CPU < GPU < FPGA)
    # mesh analogue consumed by repro.dist.bridge: "data" verifications
    # compile data-parallel, "model" tensor-parallel, "" has no mesh bridge
    # (the FPGA analogue is a kernel substitution, not a sharding).
    mesh_role: str = ""


MANY_CORE = Destination(key="dp", name="xla_dp",
                        paper_analogue="many-core CPU",
                        price=1.2, verify_time=1.0, mesh_role="data")
GPU = Destination(key="tp", name="sharded_tp", paper_analogue="GPU",
                  price=1.0, verify_time=1.5, mesh_role="model")
FPGA = Destination(key="pallas", name="pallas_kernel",
                   paper_analogue="FPGA",
                   price=2.0, verify_time=10.0)

ALL: List[Destination] = [MANY_CORE, GPU, FPGA]
BY_NAME: Dict[str, Destination] = {d.name: d for d in ALL}
BY_ANALOGUE: Dict[str, Destination] = {d.paper_analogue: d for d in ALL}

# Paper §II.C verification order: FB first (can be faster when a match
# exists), FPGA last (slowest to verify); within each method: many-core CPU,
# GPU, FPGA.
VERIFICATION_ORDER = [
    (MANY_CORE, "function_block"),
    (GPU, "function_block"),
    (FPGA, "function_block"),
    (MANY_CORE, "loop"),
    (GPU, "loop"),
    (FPGA, "loop"),
]
