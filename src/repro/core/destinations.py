"""Compatibility shim over :mod:`repro.backends`.

The destination layer was redesigned into the pluggable backend API
(``repro.backends``): identity + search strategy + mesh hook live on
:class:`repro.backends.Backend`, and the paper's §II.C verification order is
*derived* by ``BackendRegistry.verification_order()`` from each backend's
declared ``verify_time`` / ``methods`` instead of a hardcoded list.

The pre-redesign names keep working:

  * ``Destination``        — alias of :class:`repro.backends.Backend`;
  * ``MANY_CORE / GPU / FPGA`` — the built-in backend instances;
  * ``ALL / BY_NAME / BY_ANALOGUE`` — snapshots of the default registry,
    taken at import time;
  * ``VERIFICATION_ORDER`` — the derived order of the default registry at
    import time (still exactly the paper's six verifications).

Backends registered on ``DEFAULT_REGISTRY`` *after* this module is imported
appear in the planner's live ``verification_order()`` but not in these
snapshots — new code should consume :mod:`repro.backends` directly.
"""
from __future__ import annotations

from typing import Dict, List

from repro.backends.base import Backend as Destination
from repro.backends.builtin import DEFAULT_REGISTRY, FPGA, GPU, MANY_CORE

ALL: List[Destination] = list(DEFAULT_REGISTRY)
BY_NAME: Dict[str, Destination] = DEFAULT_REGISTRY.by_name
BY_ANALOGUE: Dict[str, Destination] = DEFAULT_REGISTRY.by_analogue

# Paper §II.C verification order — derived, no longer hardcoded: FB first
# (can be faster when a match exists), FPGA last (slowest to verify); within
# each method: many-core CPU, GPU, FPGA.
VERIFICATION_ORDER = DEFAULT_REGISTRY.verification_order()

__all__ = ["Destination", "MANY_CORE", "GPU", "FPGA",
           "ALL", "BY_NAME", "BY_ANALOGUE", "VERIFICATION_ORDER"]
