"""Trace exporters: JSONL, Chrome trace-event JSON, text summary.

JSONL is the canonical archive format: one record per line, keys sorted,
compact separators and deterministic float repr — so two traces of the
same deterministic scenario are **byte-identical** files (the control
loop's replay pin, extended to observability in tests/test_control.py).

The Chrome export targets the trace-event format Perfetto and
``chrome://tracing`` load: spans become ``ph:"X"`` complete events, instant
events ``ph:"i"``, and each distinct ``track`` string becomes a named
thread via ``ph:"M"`` ``thread_name`` metadata — so endpoints, backends and
the control plane render as separate swim-lanes.  Timestamps are
microseconds (the serve tick clock's seconds scale up cleanly).
"""
from __future__ import annotations

import json
from typing import Iterable, List


def jsonl_line(record: dict) -> str:
    """The canonical byte-stable encoding of one record."""
    return json.dumps(record, sort_keys=True, separators=(",", ":"))


def write_jsonl(records: Iterable[dict], path) -> str:
    path = str(path)
    with open(path, "w") as f:
        for rec in records:
            f.write(jsonl_line(rec))
            f.write("\n")
    return path


def read_jsonl(path) -> List[dict]:
    out = []
    with open(str(path)) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# ------------------------------------------------------------ chrome trace
_US = 1e6          # record times are seconds; trace-event ts/dur are µs


def chrome_trace(records: Iterable[dict]) -> dict:
    """Render records as a Chrome trace-event JSON object.

    Tracks map to tids in first-appearance order (deterministic for a
    deterministic record stream); everything runs under one pid.
    """
    tids = {}

    def tid_for(track: str) -> int:
        t = tids.get(track)
        if t is None:
            t = tids[track] = len(tids) + 1
        return t

    events = []
    for rec in records:
        track = rec.get("track") or "main"
        tid = tid_for(track)
        args = dict(rec.get("attrs") or {})
        if rec.get("type") == "span":
            t0, t1 = rec["t0"], rec["t1"]
            events.append({
                "ph": "X", "name": rec["name"], "cat": rec.get("cat") or "",
                "pid": 1, "tid": tid, "ts": t0 * _US,
                "dur": max(t1 - t0, 0.0) * _US, "args": args})
        elif rec.get("type") == "event":
            events.append({
                "ph": "i", "name": rec["name"], "cat": rec.get("cat") or "",
                "pid": 1, "tid": tid, "ts": rec["t"] * _US, "s": "t",
                "args": args})
    meta = [{"ph": "M", "name": "thread_name", "pid": 1, "tid": tid,
             "args": {"name": track}} for track, tid in tids.items()]
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def write_chrome_trace(records: Iterable[dict], path) -> str:
    path = str(path)
    with open(path, "w") as f:
        json.dump(chrome_trace(records), f, sort_keys=True)
    return path


# ------------------------------------------------------------ text summary
def text_summary(records: Iterable[dict]) -> str:
    """Per-(category, name) span/event counts and total span time — the
    at-a-glance answer to "where did the time go"."""
    spans = {}
    events = {}
    for rec in records:
        key = (rec.get("cat") or "", rec["name"])
        if rec.get("type") == "span":
            n, tot = spans.get(key, (0, 0.0))
            spans[key] = (n + 1, tot + max(rec["t1"] - rec["t0"], 0.0))
        elif rec.get("type") == "event":
            events[key] = events.get(key, 0) + 1
    lines = ["trace summary",
             f"  {sum(n for n, _ in spans.values())} spans, "
             f"{sum(events.values())} events"]
    if spans:
        lines.append("  spans (count, total_s):")
        for (cat, name), (n, tot) in sorted(
                spans.items(), key=lambda kv: -kv[1][1]):
            label = f"{cat}/{name}" if cat else name
            lines.append(f"    {label:<40} {n:>6}  {tot:10.4f}")
    if events:
        lines.append("  events (count):")
        for (cat, name), n in sorted(events.items(), key=lambda kv: -kv[1]):
            label = f"{cat}/{name}" if cat else name
            lines.append(f"    {label:<40} {n:>6}")
    return "\n".join(lines)
