"""Post-mortem report over a JSONL trace: ``python -m repro.obs.report``.

Reads the event log a :class:`repro.obs.Tracer` archived (``to_jsonl``)
and renders the operator's four questions as text tables:

  * **routing refusals** — why were requests refused, and what verdict did
    each endpoint get per routing decision (lint-pruned / cold-lookup /
    quarantined / draining / scored)?
  * **verification times per backend** — the paper's order-derivation
    table: each destination's verification cost, cache-hit rate,
    correctness and energy, from the ``plan/verify`` spans;
  * **health timeline** — every quarantine / probe / recovery transition
    with the observation that triggered it (``health/transition`` events);
  * **trends** — cache hit-rate and joules-per-request over the run,
    quartered on the ``loop/tick`` events' cumulative counters.

Usage::

    python -m repro.obs.report events.jsonl [--section all]
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Optional

from repro.obs.export import read_jsonl, text_summary


def _spans(records, cat: str, name: str) -> List[dict]:
    return [r for r in records if r.get("type") == "span"
            and r.get("cat") == cat and r.get("name") == name]


def _events(records, cat: str, name: str) -> List[dict]:
    return [r for r in records if r.get("type") == "event"
            and r.get("cat") == cat and r.get("name") == name]


# ----------------------------------------------------------- section: route
def refusal_report(records) -> str:
    routes = _spans(records, "serve", "route")
    if not routes:
        return "routing: no route spans in this trace"
    refused: Dict[str, int] = {}
    verdicts: Dict[str, Dict[str, int]] = {}
    accepted = 0
    for r in routes:
        attrs = r.get("attrs") or {}
        reason = attrs.get("reason", "")
        if reason == "ok":
            accepted += 1
        else:
            refused[reason] = refused.get(reason, 0) + 1
        for ex in attrs.get("explain") or ():
            per = verdicts.setdefault(ex.get("endpoint", "?"), {})
            v = ex.get("verdict", "?")
            per[v] = per.get(v, 0) + 1
    lines = [f"routing: {len(routes)} decisions, {accepted} accepted, "
             f"{len(routes) - accepted} refused"]
    if refused:
        lines.append("  refusals by reason:")
        for reason, n in sorted(refused.items(), key=lambda kv: -kv[1]):
            lines.append(f"    {reason:<28} {n:>6}")
    if verdicts:
        lines.append("  per-endpoint verdicts (endpoint: verdict xN):")
        for ep, per in sorted(verdicts.items()):
            parts = ", ".join(f"{v} x{n}" for v, n in
                              sorted(per.items(), key=lambda kv: -kv[1]))
            lines.append(f"    {ep:<16} {parts}")
    return "\n".join(lines)


# ---------------------------------------------------------- section: verify
def verification_report(records) -> str:
    """Per-backend verification-time table (the paper's §II.C order is
    derived from exactly these measured verification costs)."""
    verifies = _spans(records, "plan", "verify")
    if not verifies:
        return "verification: no plan/verify spans in this trace"
    rows: Dict[str, dict] = {}
    for sp in verifies:
        a = sp.get("attrs") or {}
        b = a.get("backend", "?")
        row = rows.setdefault(b, {"n": 0, "verify_s": 0.0, "compile_s": 0.0,
                                  "hits": 0, "correct": 0, "energy": [],
                                  "best": []})
        row["n"] += 1
        row["verify_s"] += max(sp["t1"] - sp["t0"], 0.0)
        row["compile_s"] += float(a.get("compile_s") or 0.0)
        row["hits"] += bool(a.get("cache_hit"))
        row["correct"] += bool(a.get("correct"))
        if a.get("energy_j") is not None:
            row["energy"].append(float(a["energy_j"]))
        if a.get("best_time_s") is not None:
            row["best"].append(float(a["best_time_s"]))
    lines = ["verification times per backend (order mirrors the paper's "
             "cheapest-first derivation):",
             f"  {'backend':<14}{'n':>4}{'verify_s':>10}{'compile_s':>11}"
             f"{'hit%':>6}{'ok%':>6}{'best_s':>10}{'energy_j':>10}"]
    for b, row in sorted(rows.items(), key=lambda kv: kv[1]["verify_s"]):
        mean_best = (sum(row["best"]) / len(row["best"])
                     if row["best"] else None)
        mean_e = (sum(row["energy"]) / len(row["energy"])
                  if row["energy"] else None)
        lines.append(
            f"  {b:<14}{row['n']:>4}{row['verify_s']:>10.4f}"
            f"{row['compile_s']:>11.4f}"
            f"{100.0 * row['hits'] / row['n']:>6.0f}"
            f"{100.0 * row['correct'] / row['n']:>6.0f}"
            f"{mean_best if mean_best is not None else float('nan'):>10.4g}"
            f"{mean_e if mean_e is not None else float('nan'):>10.4g}")
    return "\n".join(lines)


# ---------------------------------------------------------- section: health
def health_report(records) -> str:
    transitions = _events(records, "health", "transition")
    if not transitions:
        return "health: no transitions in this trace"
    lines = [f"health timeline ({len(transitions)} transitions):"]
    for ev in sorted(transitions, key=lambda e: (e["t"], e["id"])):
        a = ev.get("attrs") or {}
        obs = a.get("observed") or {}
        obs_s = ", ".join(f"{k}={v}" for k, v in sorted(obs.items()))
        lines.append(
            f"  t={ev['t']:<10.4g} {a.get('endpoint', '?'):<12} "
            f"{a.get('from', '?'):>11} -> {a.get('to', '?'):<11} "
            f"[{a.get('reason', '')}]" + (f" ({obs_s})" if obs_s else ""))
    return "\n".join(lines)


# ---------------------------------------------------------- section: trends
def _quarter(ticks: List[dict], frac: float) -> dict:
    return (ticks[min(int(frac * len(ticks)), len(ticks) - 1)]
            .get("attrs") or {})


def trends_report(records) -> str:
    ticks = sorted(_events(records, "loop", "tick"),
                   key=lambda e: (e["t"], e["id"]))
    if len(ticks) < 2:
        return "trends: no loop/tick events in this trace"
    lines = ["trends over the run (cumulative counters, quartered):",
             f"  {'quarter':<9}{'tick':>7}{'lookup hit%':>13}"
             f"{'J/request':>11}{'draw_w':>9}"]
    prev = {"lookups": 0.0, "lookup_hits": 0.0, "energy_j": 0.0,
            "completed": 0.0}
    for qi, frac in enumerate((0.25, 0.5, 0.75, 1.0)):
        a = _quarter(ticks, frac if frac < 1.0 else 0.999999)
        d_lk = float(a.get("lookups") or 0) - prev["lookups"]
        d_h = float(a.get("lookup_hits") or 0) - prev["lookup_hits"]
        d_e = float(a.get("energy_j") or 0.0) - prev["energy_j"]
        d_c = float(a.get("completed") or 0) - prev["completed"]
        hit = 100.0 * d_h / d_lk if d_lk > 0 else float("nan")
        jpr = d_e / d_c if d_c > 0 else float("nan")
        lines.append(f"  Q{qi + 1:<8}{a.get('tick', '?'):>7}"
                     f"{hit:>13.1f}{jpr:>11.4g}"
                     f"{float(a.get('draw_w') or 0.0):>9.1f}")
        prev = {"lookups": float(a.get("lookups") or 0),
                "lookup_hits": float(a.get("lookup_hits") or 0),
                "energy_j": float(a.get("energy_j") or 0.0),
                "completed": float(a.get("completed") or 0)}
    return "\n".join(lines)


SECTIONS = {
    "summary": text_summary,
    "routing": refusal_report,
    "verification": verification_report,
    "health": health_report,
    "trends": trends_report,
}


def render(records, sections: Optional[List[str]] = None) -> str:
    names = sections or list(SECTIONS)
    return "\n\n".join(SECTIONS[name](records) for name in names)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a post-mortem from a repro.obs JSONL trace.")
    ap.add_argument("events", help="path to an events.jsonl written by "
                                   "Tracer.to_jsonl")
    ap.add_argument("--section", action="append", choices=list(SECTIONS),
                    help="render only these sections (repeatable; "
                         "default: all)")
    args = ap.parse_args(argv)
    records = read_jsonl(args.events)
    print(render(records, args.section))
    return 0


if __name__ == "__main__":
    sys.exit(main())
