"""Metrics registry: one ``snapshot()`` over the repo's scattered counters.

PRs 1-9 grew ad-hoc counters in three places — ``CacheStats``
(plan/search side), ``ServeMetrics`` (request side) and
``EndpointHealth.transitions`` (control side) — each with its own
``to_dict()``/``summary()`` face.  :class:`MetricsRegistry` consolidates
them behind one nested snapshot **without breaking those public faces**:
first-class :class:`Counter`/:class:`Gauge`/:class:`Histogram` instruments
for new measurements, plus *collectors* — callables polled at snapshot
time — that adapt the existing objects in place.

Zero dependencies; never imports jax.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional


class Counter:
    """Monotonic count (events, tokens, joules...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0):
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        self.value += amount

    def snapshot(self):
        return self.value


class Gauge:
    """Last-written value (queue depth, live slots, power draw...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None

    def set(self, value: float):
        self.value = value

    def snapshot(self):
        return self.value


class Histogram:
    """Streaming distribution: count/sum/min/max plus a bounded reservoir
    for percentiles (first ``cap`` observations — deterministic, no
    sampling RNG; the serve paths this instruments are tick-bounded)."""

    __slots__ = ("name", "count", "total", "lo", "hi", "cap", "_values")

    def __init__(self, name: str, cap: int = 4096):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.lo = math.inf
        self.hi = -math.inf
        self.cap = cap
        self._values: List[float] = []

    def observe(self, value: float):
        v = float(value)
        self.count += 1
        self.total += v
        self.lo = min(self.lo, v)
        self.hi = max(self.hi, v)
        if len(self._values) < self.cap:
            self._values.append(v)

    def percentile(self, p: float) -> Optional[float]:
        from repro.serve.metrics import percentile
        return percentile(self._values, p)

    def snapshot(self):
        if not self.count:
            return {"count": 0}
        return {"count": self.count, "sum": self.total,
                "mean": self.total / self.count,
                "min": self.lo, "max": self.hi,
                "p50": self.percentile(50), "p95": self.percentile(95)}


class MetricsRegistry:
    """Get-or-create instrument registry + snapshot-time collectors.

    ``counter``/``gauge``/``histogram`` return the named instrument,
    creating it on first use — call sites don't coordinate registration.
    :meth:`register_collector` adds a named callable polled by
    :meth:`snapshot`; the ``attach_*`` helpers wire up the repo's existing
    counter objects that way, leaving their own APIs untouched.
    """

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._collectors: Dict[str, Callable[[], Any]] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, cap: int = 4096) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, cap=cap)
        return h

    def register_collector(self, name: str, fn: Callable[[], Any]):
        """Poll ``fn()`` at snapshot time under key ``name`` (an adapter
        for pre-existing counter objects; last registration wins)."""
        self._collectors[name] = fn

    # ------------------------------------------------- existing-face adapters
    def attach_cache_stats(self, name: str, stats):
        """Adapt a :class:`repro.core.search_cache.CacheStats`."""
        self.register_collector(name, stats.to_dict)

    def attach_serve_metrics(self, name: str, metrics):
        """Adapt a :class:`repro.serve.metrics.ServeMetrics` (summary keys
        only — per-request detail stays on the object)."""
        self.register_collector(name, metrics.summary)

    def attach_health(self, name: str, health_map):
        """Adapt a ``{endpoint: EndpointHealth}`` map to per-endpoint
        state + transition counts."""
        def collect():
            out = {}
            for ep, h in sorted(health_map.items()):
                out[ep] = {"state": h.state,
                           "transitions": len(h.transitions),
                           "errors": h.errors}
            return out
        self.register_collector(name, collect)

    # ------------------------------------------------------------- snapshot
    def snapshot(self) -> dict:
        """One nested dict over everything: first-class instruments under
        ``counters``/``gauges``/``histograms``, collectors under
        ``collected``."""
        out: Dict[str, Any] = {
            "counters": {k: c.snapshot()
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.snapshot()
                       for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.snapshot()
                           for k, h in sorted(self._histograms.items())},
            "collected": {},
        }
        for name, fn in sorted(self._collectors.items()):
            try:
                out["collected"][name] = fn()
            except Exception as e:      # a dead collector must not sink
                out["collected"][name] = {"error": repr(e)[:200]}
        return out
