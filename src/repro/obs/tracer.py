"""Span tracer: one timeline for plan -> publish -> serve -> control.

The paper's method is a measure-everything loop — per-destination
verification times decide both the verification order and the final
selection — yet the repro's measurements were scattered (``CacheStats``,
``ServeMetrics``, health transitions, dryrun cell JSONs).  This module is
the common timeline: nested :class:`Span`s and instant events recorded by
a :class:`Tracer`, exported as JSONL / Chrome trace / text summary
(:mod:`repro.obs.export`) and post-mortemed by ``python -m
repro.obs.report``.

Design constraints, all load-bearing:

  * **zero dependencies** — importing :mod:`repro.obs` never pulls jax
    (the serve hot path must stay jax-free);
  * **null-object disabled state** — the ambient tracer defaults to
    :data:`NULL_TRACER`; every instrumented call site writes
    ``with get_tracer().span(...) as sp: sp.set(...)`` unconditionally and
    pays only a no-op context manager when tracing is off (no conditional
    sprawl, pinned by a <=2%% overhead guard in
    ``benchmarks/search_throughput.py``);
  * **caller-supplied clocks** — offline search spans stamp wall time; the
    serve/control loop pins the tracer to its virtual tick clock
    (:meth:`Tracer.set_time`), so a :class:`~repro.runtime.control
    .ControlLoop` replay produces a **byte-identical** JSONL log — the
    same determinism the control loop itself guarantees (pinned in
    tests/test_control.py).
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, List, Optional


def _jsonable(obj):
    """Clamp attribute values to JSON-representable structures."""
    if isinstance(obj, (str, int, bool)) or obj is None:
        return obj
    if isinstance(obj, float):
        return obj
    if isinstance(obj, (list, tuple)):
        return [_jsonable(x) for x in obj]
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    return repr(obj)


class Span:
    """One timed, attributed operation on a track.

    Context-manager use stamps ``t1`` at exit; :meth:`set` attaches
    attributes at any point before the span is recorded.  Spans nest: the
    tracer keeps a per-thread stack, and each span records its parent's
    id, so exporters can reconstruct the tree.
    """

    __slots__ = ("tracer", "id", "parent", "name", "cat", "track",
                 "t0", "t1", "attrs")

    def __init__(self, tracer: "Tracer", sid: int, parent: Optional[int],
                 name: str, cat: str, track: str, t0: float,
                 attrs: Dict[str, Any]):
        self.tracer = tracer
        self.id = sid
        self.parent = parent
        self.name = name
        self.cat = cat
        self.track = track
        self.t0 = t0
        self.t1: Optional[float] = None
        self.attrs = attrs

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def finish(self, t: Optional[float] = None):
        if self.t1 is not None:
            return                       # already recorded
        self.t1 = float(t) if t is not None else self.tracer.now()
        self.tracer._record_span(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self.attrs.setdefault("error", repr(exc)[:200])
        self.finish()
        return False


class _NullSpan:
    """The disabled tracer's span: accepts everything, records nothing."""

    __slots__ = ()

    def set(self, **attrs) -> "_NullSpan":
        return self

    def finish(self, t=None):
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


NULL_SPAN = _NullSpan()


class NullTracer:
    """Null-object tracer: the ambient default when tracing is disabled.

    Every method is a cheap no-op, so instrumented call sites need no
    conditionals — ``get_tracer().span(...)`` costs one attribute lookup
    and one singleton return.
    """

    enabled = False

    def span(self, name, cat="", track="", t0=None, **attrs):
        return NULL_SPAN

    def complete_span(self, name, t0, t1, cat="", track="", **attrs):
        return None

    def event(self, name, cat="", track="", t=None, **attrs):
        return None

    def set_time(self, t):
        pass

    def clear_time(self):
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """Recording tracer (see module docstring).

    ``clock`` supplies timestamps (default ``time.perf_counter``);
    :meth:`set_time` overrides it with a pinned virtual time — the
    serve/control loop pins each tick, so replays are byte-identical.
    Records accumulate in memory in completion order; export them with
    :meth:`to_jsonl` / :meth:`to_chrome` / :meth:`summary`.
    """

    enabled = True

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.clock = clock if clock is not None else time.perf_counter
        self.records: List[dict] = []
        self._lock = threading.Lock()
        self._seq = 0
        self._pinned: Optional[float] = None
        self._local = threading.local()

    # --------------------------------------------------------------- clock
    def now(self) -> float:
        return self._pinned if self._pinned is not None else self.clock()

    def set_time(self, t: float):
        """Pin the current time (virtual tick clocks; deterministic)."""
        self._pinned = float(t)

    def clear_time(self):
        self._pinned = None

    # --------------------------------------------------------------- spans
    def _stack(self) -> List[int]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _next_id(self) -> int:
        with self._lock:
            self._seq += 1
            return self._seq

    def span(self, name: str, cat: str = "", track: str = "",
             t0: Optional[float] = None, **attrs) -> Span:
        """Open a span; close it via context manager or ``finish()``."""
        stack = self._stack()
        parent = stack[-1] if stack else None
        sp = Span(self, self._next_id(), parent, name, cat, track,
                  float(t0) if t0 is not None else self.now(),
                  dict(attrs))
        stack.append(sp.id)
        return sp

    def _record_span(self, sp: Span):
        stack = self._stack()
        if stack and stack[-1] == sp.id:
            stack.pop()
        elif sp.id in stack:             # out-of-order finish: unwind to it
            del stack[stack.index(sp.id):]
        with self._lock:
            self.records.append({
                "type": "span", "id": sp.id, "parent": sp.parent,
                "name": sp.name, "cat": sp.cat, "track": sp.track,
                "t0": sp.t0, "t1": sp.t1,
                "attrs": _jsonable(sp.attrs)})

    def complete_span(self, name: str, t0: float, t1: float, cat: str = "",
                      track: str = "", **attrs) -> dict:
        """Record an already-finished span with explicit timestamps (e.g. a
        request's dispatch->completion window on the tick clock)."""
        rec = {"type": "span", "id": self._next_id(), "parent": None,
               "name": name, "cat": cat, "track": track,
               "t0": float(t0), "t1": float(t1), "attrs": _jsonable(attrs)}
        with self._lock:
            self.records.append(rec)
        return rec

    def event(self, name: str, cat: str = "", track: str = "",
              t: Optional[float] = None, **attrs) -> dict:
        """Record an instant event."""
        rec = {"type": "event", "id": self._next_id(), "name": name,
               "cat": cat, "track": track,
               "t": float(t) if t is not None else self.now(),
               "attrs": _jsonable(attrs)}
        with self._lock:
            self.records.append(rec)
        return rec

    # ------------------------------------------------------------- exports
    def to_jsonl(self, path) -> str:
        from repro.obs.export import write_jsonl
        return write_jsonl(self.records, path)

    def to_chrome(self, path) -> str:
        from repro.obs.export import write_chrome_trace
        return write_chrome_trace(self.records, path)

    def summary(self) -> str:
        from repro.obs.export import text_summary
        return text_summary(self.records)


# ------------------------------------------------------- the ambient tracer
_current: object = NULL_TRACER


def get_tracer():
    """The ambient tracer every instrumented call site records through
    (:data:`NULL_TRACER` unless :func:`set_tracer`/:func:`use_tracer`
    installed a recording one)."""
    return _current


def set_tracer(tracer) -> object:
    """Install ``tracer`` as the ambient tracer (None restores the null
    tracer).  Returns the installed tracer."""
    global _current
    _current = tracer if tracer is not None else NULL_TRACER
    return _current


@contextmanager
def use_tracer(tracer):
    """Scoped :func:`set_tracer`: restores the previous tracer on exit."""
    global _current
    prev = _current
    _current = tracer if tracer is not None else NULL_TRACER
    try:
        yield _current
    finally:
        _current = prev
