"""repro.obs — unified tracing, metrics and post-mortem reporting.

One observability layer over plan -> publish -> serve -> control:

  * :class:`Tracer` / :class:`Span` — nested spans + instant events on
    caller-supplied clocks (wall for offline search, the virtual tick
    clock for serve/control via :meth:`Tracer.set_time`); the ambient
    tracer (:func:`get_tracer`) defaults to the no-op
    :data:`NULL_TRACER`, so instrumentation costs nothing when disabled;
  * :class:`MetricsRegistry` — counters/gauges/histograms plus adapters
    over the repo's existing ``CacheStats`` / ``ServeMetrics`` / health
    counters, behind one ``snapshot()``;
  * exporters — byte-stable JSONL (:func:`write_jsonl`), Perfetto-loadable
    Chrome trace JSON (:func:`write_chrome_trace`), text summary
    (:func:`text_summary`); and ``python -m repro.obs.report`` rendering
    the post-mortem (see :mod:`repro.obs.report`).

Zero dependencies: importing this package never pulls jax or numpy.
"""
from repro.obs.export import (chrome_trace, jsonl_line, read_jsonl,
                              text_summary, write_chrome_trace, write_jsonl)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.tracer import (NULL_SPAN, NULL_TRACER, NullTracer, Span,
                              Tracer, get_tracer, set_tracer, use_tracer)

__all__ = [
    "Tracer", "Span", "NullTracer", "NULL_TRACER", "NULL_SPAN",
    "get_tracer", "set_tracer", "use_tracer",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "write_jsonl", "read_jsonl", "jsonl_line",
    "chrome_trace", "write_chrome_trace", "text_summary",
]
