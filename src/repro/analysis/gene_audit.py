"""Gene-contract audit: prove the ``structural=False`` flags in
``Plan.GENE_SPACE`` against the traced artifact.

``repro.core.search_cache`` dedupes the GA's compiles by
``Plan.structural_key()``, which *excludes* every gene flagged
``structural=False`` (model-only): the contract is that flipping such a gene
never changes the lowered artifact, only the analytic cost model on top of
it.  ROADMAP: "a wrong model-only flag poisons the cache" — two genuinely
different artifacts would share one cache entry and every search would score
one of them with the other's roofline.  Until now that contract was a
comment; this pass proves it.

Method: trace a base plan and, for each audited gene, every flipped value;
compare the full jaxpr pretty-print (shapes included — a gene that only
changes a block size still moves dimensions).  Any nonzero diff on a
model-only gene is a ``G001`` error finding.  The default trace is a tiny
dense train step on CPU (no mesh), deliberately sensitive to the structural
genes that have train-step reach (remat, microbatches, vocab_chunk) — the
pinned test injects a mislabeled gene space and asserts the audit catches
it.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

from repro.analysis.findings import ERROR, INFO, Finding


@dataclass(frozen=True)
class GeneAudit:
    """Verdict for one audited gene."""
    field: str
    declared_model_only: bool
    artifact_invariant: bool
    base_value: object
    checked_values: Tuple
    detail: str = ""            # first divergence, "" when invariant

    @property
    def violation(self) -> bool:
        """True when the cache identity is unsound for this gene."""
        return self.declared_model_only and not self.artifact_invariant


def default_trace_fn() -> Callable[[object], str]:
    """(plan) -> artifact text for a tiny dense train step, no mesh.

    Small enough to trace on CPU in well under a second, but routed through
    the real ``Model`` / ``make_train_step`` stack so every train-reaching
    gene (remat, microbatches, vocab_chunk, opt_state_dtype, ...) shows in
    the jaxpr if and only if it shows in production lowering.
    """
    import jax
    import jax.numpy as jnp
    from jax import ShapeDtypeStruct as SDS

    from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
    from repro.dist.sharding import NullRules
    from repro.launch import specs
    from repro.models.lm import Model
    from repro.train import optimizer, train_step as ts

    cfg = ModelConfig(name="audit-tiny", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=256, d_head=16, vocab_pad_multiple=16,
                      dtype="float32", param_dtype="float32")
    shape = ShapeConfig("audit-train", seq_len=32, global_batch=8,
                        kind="train")

    def trace(plan) -> str:
        model = Model(cfg, plan, NullRules())
        tcfg = TrainConfig(microbatches=plan.microbatches,
                           master_dtype=plan.opt_state_dtype)
        key_sds = SDS((2,), jnp.uint32)
        params_sds = jax.eval_shape(lambda k: model.init(k), key_sds)
        opt_sds = jax.eval_shape(lambda p: optimizer.init(p, tcfg),
                                 params_sds)
        batch_sds = specs.batch_specs(cfg, shape)
        fn = ts.make_train_step(model, tcfg)
        closed = jax.make_jaxpr(fn)(params_sds, opt_sds, batch_sds,
                                    SDS((), jnp.int32))
        return str(closed)

    return trace


def _diff_summary(base: str, flipped: str) -> str:
    """First differing line of two artifact texts (compact evidence)."""
    for i, (a, b) in enumerate(zip(base.splitlines(),
                                   flipped.splitlines())):
        if a != b:
            return (f"first diff at jaxpr line {i}: "
                    f"{a.strip()[:80]!r} != {b.strip()[:80]!r}")
    return (f"jaxpr length differs: {len(base.splitlines())} vs "
            f"{len(flipped.splitlines())} lines")


def audit_gene_space(trace_fn: Optional[Callable[[object], str]] = None,
                     gene_space: Optional[Sequence] = None,
                     base_plan=None,
                     fields: Optional[Sequence[str]] = None
                     ) -> List[GeneAudit]:
    """Audit genes against the traced artifact.

    By default only the ``structural=False`` (model-only) genes are audited
    — those are the ones whose flag, if wrong, silently poisons
    ``Plan.structural_key()``.  Pass ``fields`` to audit specific genes
    (e.g. the test's deliberately mislabeled one), or a modified
    ``gene_space`` to audit a hypothetical contract before adopting it.
    """
    from repro.dist.plan import Plan

    if gene_space is None:
        gene_space = Plan.GENE_SPACE
    if trace_fn is None:
        trace_fn = default_trace_fn()
    if base_plan is None:
        base_plan = Plan(name="gene-audit-base")

    todo = [g for g in gene_space
            if (g.field in fields if fields is not None else not g.structural)]
    base_text = trace_fn(base_plan) if todo else ""

    audits: List[GeneAudit] = []
    for gene in todo:
        base_value = getattr(base_plan, gene.field)
        flips = tuple(c for c in gene.choices if c != base_value)
        detail = ""
        invariant = True
        for choice in flips:
            flipped = dataclasses.replace(base_plan, **{gene.field: choice})
            text = trace_fn(flipped)
            if text != base_text:
                invariant = False
                detail = (f"{gene.field}={choice!r} changes the artifact "
                          f"vs {base_value!r}: "
                          + _diff_summary(base_text, text))
                break
        audits.append(GeneAudit(
            field=gene.field, declared_model_only=not gene.structural,
            artifact_invariant=invariant, base_value=base_value,
            checked_values=flips, detail=detail))
    return audits


def audit_findings(audits: Sequence[GeneAudit]) -> List[Finding]:
    """Finding records for an audit run (G001 = contract violation)."""
    out: List[Finding] = []
    for a in audits:
        if a.violation:
            out.append(Finding(
                "G001", ERROR,
                f"gene {a.field!r} is flagged structural=False but flipping "
                f"it changes the lowered artifact — Plan.structural_key() "
                f"would alias distinct compiles ({a.detail})",
                plan_field=a.field, subject="gene-audit"))
        elif a.declared_model_only:
            out.append(Finding(
                "G002", INFO,
                f"gene {a.field!r}: artifact-invariant over "
                f"{list(a.checked_values)!r} — model-only flag verified",
                plan_field=a.field, subject="gene-audit"))
        elif not a.artifact_invariant:
            out.append(Finding(
                "G003", INFO,
                f"gene {a.field!r} is structural and indeed changes the "
                f"artifact ({a.detail})",
                plan_field=a.field, subject="gene-audit"))
        else:
            out.append(Finding(
                "G004", INFO,
                f"gene {a.field!r} is flagged structural but produced no "
                "artifact diff under this trace — either inert on the audit "
                "model or a candidate for structural=False",
                plan_field=a.field, subject="gene-audit"))
    return out
