"""Static plan feasibility lint: pure arithmetic over Plan × mesh × arch.

The paper's pipeline opens with *static* structure analysis (Clang loop /
function-block parsing) before any measurement is spent; this is the
framework-side analogue: every check here replicates, in closed form, a
decision the runtime stack makes while tracing / lowering / modeling a
:class:`repro.dist.plan.Plan` — so an infeasible or self-contradictory
candidate is rejected for the GA's penalty without paying for a trace or an
XLA compile (see ``make_cached_batch_evaluator(lint=...)``).

What "error" means here is narrow: the artifact provably cannot be built
(the ``batch % microbatches`` assert in ``repro.train.train_step``, an
unknown pipeline schedule on an explicitly pipelined cell, parameters that
overflow the mesh's aggregate HBM even perfectly sharded).  Everything the
runtime *survives by silently degrading* — ``Rules`` prefix-sharding
falling back to replication, ``chunked_softmax_xent`` disabling itself on a
non-dividing sequence, ``pipeline_apply``'s sequential fallback — is a
warning: the plan lowers, but not to what its genes claim.

No jax import is required: ``mesh`` may be a ``jax.sharding.Mesh`` or a
plain ``{axis: size}`` dict (the CLI uses dicts so linting 512-chip meshes
never instantiates 512 fake devices).
"""
from __future__ import annotations

from typing import Dict, List

from repro.analysis.findings import ERROR, INFO, WARNING, Finding

GiB = 1024 ** 3
# per-chip HBM capacity the dry-run's fits_16GiB verdict assumes
DEVICE_MEMORY_BYTES = 16 * GiB

_DTYPE_BYTES = {"float32": 4, "float16": 2, "bfloat16": 2, "int8": 1}

# mirror of repro.dist.sharding.BASE_RULES for the dims the lint reasons
# about (kv_seq joins under Plan.decode_kv_seq_shard, as in Rules.__init__)
_BATCH_AXES = ("pod", "data")
_MODEL_DIMS = ("heads", "kv_heads", "ff", "vocab")


def _axis_sizes(mesh) -> Dict[str, int]:
    """Axis-name -> size for a jax Mesh, a {axis: size} dict, or None."""
    if mesh is None:
        return {}
    if isinstance(mesh, dict):
        return {str(a): int(s) for a, s in mesh.items()}
    shape = getattr(mesh, "shape", None)
    if shape is not None and hasattr(shape, "items"):
        return {str(a): int(s) for a, s in shape.items()}
    raise TypeError(f"mesh must be a Mesh, dict or None: {type(mesh)!r}")


def _prefix_take(dim: int, axes, sizes: Dict[str, int]) -> int:
    """How many leading axes Rules._assign would shard ``dim`` over."""
    size, take = 1, 0
    for a in axes:
        if a not in sizes or dim % (size * sizes[a]) != 0:
            break
        size *= sizes[a]
        take += 1
    return take


def _dtype_bytes(name: str) -> int:
    return _DTYPE_BYTES.get(str(name), 4)


def _serve_attr(serve, name, default=None):
    """Serve-context field: ``serve`` may be a dict or any object carrying
    n_slots / cache_len / prompt_len / max_gen (e.g. an Endpoint)."""
    if isinstance(serve, dict):
        v = serve.get(name, default)
    else:
        v = getattr(serve, name, default)
    return default if v is None else int(v)


def serve_kv_bytes(cfg, cache_len: int, *, quant: bool = False) -> int:
    """Closed-form per-slot decode-cache footprint estimate.

    Mirrors ``models.lm.init_cache`` shapes: attention layers hold K+V of
    ``[cache_len, n_kv_heads, head_dim]`` each (window rings cap the length
    at ``cfg.window``); recurrent families hold O(1) state per layer.
    ``quant`` is the ``Plan.kv_cache_quant`` gene (int8 + fp32 scale).
    """
    hd = cfg.head_dim
    per_tok = 2 * cfg.n_kv_heads * hd          # K + V elements per token
    el = 1 if quant else _dtype_bytes(getattr(cfg, "dtype", "bfloat16"))
    if cfg.family == "ssm":
        s = cfg.ssm
        return cfg.n_layers * s.d_inner(cfg.d_model) * s.d_state * 4
    eff = min(cache_len, cfg.window) if getattr(cfg, "window", 0) \
        else cache_len
    if cfg.family == "hybrid":
        h = cfg.hybrid
        n_att = sum(1 for i in range(cfg.n_layers)
                    if h.pattern[i % len(h.pattern)] != "recurrent")
        w = h.lru_width or cfg.d_model
        rec_state = (cfg.n_layers - n_att) * w * 4
        return n_att * eff * per_tok * el + rec_state
    n_att = cfg.n_layers
    if getattr(cfg, "cross_attn_every", 0):
        # cross-attn caches are context-length-sized, counted separately by
        # the caller if it matters; the self-attn share dominates
        n_att = cfg.n_layers - cfg.n_layers // (cfg.cross_attn_every + 1)
    return n_att * eff * per_tok * el


def lint_plan(plan, *, mesh=None, cfg=None, shape=None,
              pipelined: bool = False,
              device_memory_bytes: int = DEVICE_MEMORY_BYTES,
              serve=None
              ) -> List[Finding]:
    """Pure-arithmetic feasibility findings for one plan.

    ``mesh`` / ``cfg`` / ``shape`` are each optional — a check that needs a
    missing ingredient is skipped, so the linter is usable from the gene-level
    GA (mesh only) up to the full dry-run cell (all three).  ``pipelined``
    mirrors ``repro.launch.dryrun``: the pipeline-schedule genes are
    *requested* (not merely carried as model-only genes), so hostability
    failures become errors instead of modeling notes.

    ``serve`` enables the serving context (decode shapes): a dict or object
    with ``n_slots`` / ``cache_len`` / ``prompt_len`` / ``max_gen``.  The
    router (repro.serve.router) lints every candidate endpoint with it
    before scoring, so a destination whose slot pool provably cannot host
    the request is pruned statically — the same prune-before-compile
    contract the GA's batch evaluator applies (P018/P019 errors, P104
    would-fit-with-quant hint).
    """
    out: List[Finding] = []
    subject = getattr(plan, "name", "") or ""

    def add(rule_id, severity, message, plan_field=None, **context):
        out.append(Finding(rule_id, severity, message, plan_field=plan_field,
                           subject=subject, context=context))

    sizes = _axis_sizes(mesh)
    n_devices = 1
    for s in sizes.values():
        n_devices *= max(s, 1)
    kind = getattr(shape, "kind", None)
    seq = getattr(shape, "seq_len", None)
    batch = getattr(shape, "global_batch", None)

    # --- P001: nonpositive gene values (nothing downstream tolerates them)
    for f, lo in (("microbatches", 1), ("virtual_stages", 1),
                  ("attn_block_q", 1), ("attn_block_kv", 1),
                  ("blockwise_attn_threshold", 1), ("moe_groups", 1),
                  ("vocab_chunk", 0), ("ssd_chunk", 0)):
        v = getattr(plan, f, lo)
        if not isinstance(v, (int, float)) or v < lo:
            add("P001", ERROR, f"{f}={v!r} must be >= {lo}", plan_field=f)
    cap = getattr(plan, "moe_capacity_factor", None)
    if cap is not None and (not isinstance(cap, (int, float)) or cap <= 0):
        add("P001", ERROR, f"moe_capacity_factor={cap!r} must be > 0",
            plan_field="moe_capacity_factor")
    if out:                      # nonsense values poison every later check
        return out

    micro = getattr(plan, "microbatches", 1)
    schedule = getattr(plan, "pipeline_schedule", "gpipe")
    virtual = getattr(plan, "virtual_stages", 1)
    pod = sizes.get("pod", 1)

    # --- P002: microbatch split divisibility — the one hard trace-time
    # assert in plan space (_split_microbatches: batch % microbatches)
    if batch is not None and micro > 1:
        if kind == "train" and batch % micro != 0:
            add("P002", ERROR,
                f"global_batch {batch} % microbatches {micro} != 0: "
                "gradient-accumulation split asserts at trace time",
                plan_field="microbatches", batch=batch, microbatches=micro)
        elif kind not in (None, "train"):
            add("P103", INFO,
                f"microbatches={micro} is inert on a {kind} shape "
                "(no gradient accumulation)", plan_field="microbatches")

    # --- P003/P004/P005: pipeline-schedule hostability ------------------
    from repro.dist.schedules import get_schedule
    sched = get_schedule(schedule)
    if sched is None:
        add("P003", ERROR if pipelined else WARNING,
            f"unknown pipeline schedule {schedule!r}: "
            + ("the requested pipeline cannot be built" if pipelined else
               "the cost model charges bubble 0 (sequential fallback)"),
            plan_field="pipeline_schedule")
    if pipelined and pod <= 1:
        add("P005", WARNING,
            "pipeline requested but the mesh has no pod axis (>1): "
            "pipeline_apply falls back to the sequential reference",
            plan_field="pipeline_schedule", pod=pod)
    if sched is not None and pod > 1:
        v = max(virtual, 1) if schedule == "interleaved" else 1
        built = sched.build(n_stages=pod * v, n_ranks=pod,
                            microbatches=micro, virtual_stages=v)
        if built is None and pipelined:
            add("P004", ERROR,
                f"schedule {schedule!r} cannot host stages={pod * v} "
                f"ranks={pod} microbatches={micro} virtual={v} "
                "(Schedule.build returned None)",
                plan_field="pipeline_schedule")
        elif built is not None and pipelined and micro < pod:
            add("P007", INFO,
                f"microbatches {micro} < pipeline ranks {pod}: bubble "
                f"fraction {built.bubble_fraction:.2f} of every step",
                plan_field="microbatches",
                bubble_fraction=round(built.bubble_fraction, 4))
    if virtual > 1 and schedule != "interleaved":
        add("P006", WARNING,
            f"virtual_stages={virtual} is ignored by schedule "
            f"{schedule!r} (an interleaved-only gene)",
            plan_field="virtual_stages")

    # --- P008: parameter memory vs aggregate device capacity ------------
    if cfg is not None:
        n_params = cfg.n_params()
        p_bytes = n_params * _dtype_bytes(getattr(cfg, "param_dtype",
                                                  "bfloat16"))
        total = p_bytes
        if kind == "train":
            # fp32 grad accumulators + two Adam moments in the plan's
            # opt-state dtype: the floor any training step must hold
            total += n_params * 4
            total += 2 * n_params * _dtype_bytes(
                getattr(plan, "opt_state_dtype", "float32"))
        capacity = n_devices * device_memory_bytes
        if total > capacity:
            add("P008", ERROR,
                f"state floor {total / GiB:.1f} GiB (params"
                + (" + grads + opt moments" if kind == "train" else "")
                + f") exceeds the mesh's aggregate {capacity / GiB:.0f} GiB"
                f" ({n_devices} x {device_memory_bytes / GiB:.0f} GiB): "
                "cannot fit even fully sharded",
                plan_field="opt_state_dtype" if kind == "train" else None,
                state_bytes=total, capacity_bytes=capacity)

    # --- P009: chunked-xent silent disable ------------------------------
    chunk = getattr(plan, "vocab_chunk", 0)
    if chunk and kind == "train" and seq is not None:
        eff = min(chunk, seq)
        if seq % eff != 0:
            add("P009", WARNING,
                f"vocab_chunk={chunk}: seq_len {seq} % {eff} != 0, "
                "chunked_softmax_xent silently falls back to the full "
                "(unchunked) loss", plan_field="vocab_chunk")
    elif chunk and kind in ("prefill", "decode"):
        add("P103", INFO, f"vocab_chunk={chunk} is inert on a {kind} shape "
            "(no training loss)", plan_field="vocab_chunk")

    # --- P010: batch prefix-sharding degradation ------------------------
    if batch is not None and batch > 1 and sizes:
        # batch == 1 carries no signal: a singleton batch cannot shard and
        # that is the shape cell's property, not a plan defect
        avail = tuple(a for a in _BATCH_AXES if sizes.get(a, 1) > 1)
        if avail:
            take = _prefix_take(batch, avail, sizes)
            if take == 0:
                add("P010", WARNING,
                    f"global_batch {batch} is divisible by no prefix of "
                    f"the batch axes {avail}: the batch replicates "
                    "(data parallelism is lost)", batch=batch)
            elif take < len(avail):
                add("P010", INFO,
                    f"global_batch {batch} shards over {avail[:take]} "
                    f"only; {avail[take:]} replicate", batch=batch)

    # --- P011: model-dim replication (an arch property, not plan-fixable)
    model_size = sizes.get("model", 1)
    if cfg is not None and model_size > 1:
        dims = {"heads": cfg.n_heads, "kv_heads": cfg.n_kv_heads,
                "ff": cfg.d_ff, "vocab": cfg.padded_vocab}
        for logical in _MODEL_DIMS:
            dim = dims[logical]
            if dim % model_size != 0:
                add("P011", INFO,
                    f"{logical}={dim} % model axis {model_size} != 0: "
                    "Rules replicates this dimension (tensor parallelism "
                    "degrades for the arch, independent of the plan)",
                    logical=logical, dim=dim)

    # --- P012/P013: serving genes ---------------------------------------
    if getattr(plan, "decode_kv_seq_shard", False):
        if kind == "decode" and seq is not None and model_size > 1 \
                and seq % model_size != 0:
            add("P012", WARNING,
                f"decode_kv_seq_shard: kv_seq {seq} % model axis "
                f"{model_size} != 0, the requested cache sharding "
                "silently replicates", plan_field="decode_kv_seq_shard")
        elif kind in ("train", "prefill"):
            add("P013", INFO,
                f"decode_kv_seq_shard is inert on a {kind} shape",
                plan_field="decode_kv_seq_shard")
    if getattr(plan, "kv_cache_quant", False) and kind == "train":
        add("P013", INFO, "kv_cache_quant is inert on a train shape "
            "(no decode cache)", plan_field="kv_cache_quant")

    # --- P014/P015/P016: genes contradicting the cell -------------------
    if kind in ("prefill", "decode") and getattr(plan, "remat",
                                                 "none") != "none":
        add("P014", INFO,
            f"remat={plan.remat!r} is inert on a {kind} shape "
            "(no backward pass to rematerialize for)", plan_field="remat")
    if cfg is not None and getattr(cfg, "moe", None) is None \
            and getattr(plan, "moe_impl", "gspmd") != "gspmd":
        add("P015", INFO,
            f"moe_impl={plan.moe_impl!r} is inert: {cfg.name} has no MoE "
            "layers", plan_field="moe_impl")
    if getattr(plan, "grad_compression", False):
        if kind in ("prefill", "decode"):
            add("P013", INFO,
                f"grad_compression is inert on a {kind} shape",
                plan_field="grad_compression")
        elif sizes and pod <= 1:
            add("P016", WARNING,
                "grad_compression compresses the cross-pod grad psum, but "
                "the mesh has no pod axis (>1): nothing is compressed",
                plan_field="grad_compression")

    # --- P018/P019/P104: serving context (decode slot pool) -------------
    if serve is not None:
        cache_len = _serve_attr(serve, "cache_len", 0)
        n_slots = _serve_attr(serve, "n_slots", 1)
        prompt_len = _serve_attr(serve, "prompt_len", 0)
        max_gen = _serve_attr(serve, "max_gen", 0)
        need = prompt_len + max_gen
        if cache_len and need > cache_len:
            if cfg is not None and cfg.is_sub_quadratic:
                add("P104", INFO,
                    f"request needs {need} positions > cache_len "
                    f"{cache_len}, but {cfg.name} decodes with "
                    "window/recurrent state (the ring wraps by design)",
                    need=need, cache_len=cache_len)
            else:
                add("P018", ERROR,
                    f"request needs prompt {prompt_len} + gen {max_gen} = "
                    f"{need} positions but the endpoint's cache_len is "
                    f"{cache_len}: the full-attention KV cache cannot host "
                    "it (tokens past cache_len overwrite live entries)",
                    need=need, cache_len=cache_len)
        if cfg is not None and cache_len and n_slots:
            quant = bool(getattr(plan, "kv_cache_quant", False))
            pool = n_slots * serve_kv_bytes(cfg, cache_len, quant=quant)
            params = cfg.n_params() * _dtype_bytes(
                getattr(cfg, "param_dtype", "bfloat16"))
            capacity = n_devices * device_memory_bytes
            if params + pool > capacity:
                add("P019", ERROR,
                    f"slot pool {pool / GiB:.1f} GiB ({n_slots} slots x "
                    f"cache_len {cache_len}) + params {params / GiB:.1f} "
                    f"GiB exceeds the endpoint's {capacity / GiB:.0f} GiB "
                    f"({n_devices} x {device_memory_bytes / GiB:.0f} GiB)",
                    plan_field="kv_cache_quant" if not quant else None,
                    pool_bytes=pool, param_bytes=params,
                    capacity_bytes=capacity)
                if not quant:
                    pool_q = n_slots * serve_kv_bytes(cfg, cache_len,
                                                      quant=True)
                    if params + pool_q <= capacity:
                        add("P104", INFO,
                            "the slot pool would fit with kv_cache_quant "
                            f"(int8 cache: {pool_q / GiB:.1f} GiB)",
                            plan_field="kv_cache_quant",
                            pool_bytes=pool_q)

    # --- P017: implicit attention-block padding -------------------------
    thresh = getattr(plan, "blockwise_attn_threshold", 1 << 30)
    if seq is not None and kind in ("train", "prefill") and seq >= thresh:
        for f in ("attn_block_q", "attn_block_kv"):
            blk = min(getattr(plan, f, seq), seq)
            if blk and seq % blk != 0:
                add("P017", INFO,
                    f"{f}={getattr(plan, f)}: seq {seq} % {blk} != 0, "
                    "blockwise attention pads the sequence (wasted tiles)",
                    plan_field=f)

    return out
