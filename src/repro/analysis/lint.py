"""CLI front-end for the static-analysis passes.

Usage:
  python -m repro.analysis.lint                       # full sweep
  python -m repro.analysis.lint --arch granite-3-2b --plan serve-low-mem
  python -m repro.analysis.lint --strict --json findings.json

Runs the plan feasibility linter over configs × named plans (each named
plan against its *documented* context from ``repro.dist.plan.PLAN_CONTEXTS``
unless ``--shape`` / ``--mesh`` override it), the Pallas kernel lint, and —
unless ``--no-gene-audit`` — the gene-contract audit (the only pass that
needs jax; everything else is pure arithmetic).

Exit status: 1 when any error-severity finding exists; with ``--strict``,
warnings fail too.  ``--json`` writes the full findings report (the CI
artifact).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence

from repro.analysis.findings import (Finding, findings_to_json,
                                     sort_findings)
from repro.analysis.plan_lint import lint_plan

# axis layout of repro.launch.mesh.make_production_mesh, as plain dicts so
# linting a 512-chip mesh never instantiates 512 host devices
PRODUCTION_MESHES: Dict[str, Dict[str, int]] = {
    "single": {"data": 16, "model": 16},
    "multi": {"pod": 2, "data": 16, "model": 16},
}


def lint_cells(archs: Optional[Sequence[str]] = None,
               plans: Optional[Sequence[str]] = None,
               shapes: Optional[Sequence[str]] = None,
               mesh: Optional[str] = None,
               pipelined: bool = False) -> List[dict]:
    """Plan-lint a sweep of cells; one record per (arch, plan, shape, mesh).

    Each named plan defaults to its documented context; ``shapes`` / ``mesh``
    override it for ad-hoc what-if runs (``--mesh both`` fans out).
    """
    from repro.configs import ARCHS, cell_runnable, get_config, get_shape
    from repro.dist.plan import NAMED_PLANS, PLAN_CONTEXTS, Plan

    arch_names = list(archs) if archs else sorted(ARCHS)
    plan_names = list(plans) if plans else sorted(NAMED_PLANS)
    records: List[dict] = []
    for plan_name in plan_names:
        if plan_name in NAMED_PLANS:
            plan = NAMED_PLANS[plan_name]
            ctx = PLAN_CONTEXTS.get(plan_name, {})
        elif plan_name == "default":
            plan, ctx = Plan(), {}
        else:
            raise SystemExit(f"unknown plan {plan_name!r}; have "
                             f"{sorted(NAMED_PLANS) + ['default']}")
        cell_shapes = list(shapes) if shapes \
            else list(ctx.get("shapes", ("train_4k",)))
        mesh_kind = mesh or ctx.get("mesh", "single")
        mesh_kinds = list(PRODUCTION_MESHES) if mesh_kind == "both" \
            else [mesh_kind]
        for arch in arch_names:
            cfg = get_config(arch)
            for shape_name in cell_shapes:
                shape = get_shape(shape_name)
                if not cell_runnable(cfg, shape):
                    continue
                for mk in mesh_kinds:
                    mesh_sizes = None if mk == "none" \
                        else PRODUCTION_MESHES[mk]
                    findings = lint_plan(plan, mesh=mesh_sizes, cfg=cfg,
                                         shape=shape, pipelined=pipelined)
                    records.append({
                        "arch": arch, "plan": plan_name,
                        "shape": shape_name, "mesh": mk,
                        "findings": findings_to_json(findings)})
    return records


def _severity_counts(records: List[dict],
                     extra: Sequence[Finding]) -> Dict[str, int]:
    counts = {"error": 0, "warning": 0, "info": 0}
    for rec in records:
        for f in rec["findings"]:
            counts[f["severity"]] = counts.get(f["severity"], 0) + 1
    for f in extra:
        counts[f.severity] = counts.get(f.severity, 0) + 1
    return counts


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="static plan/kernel linter + gene-contract auditor")
    ap.add_argument("--arch", action="append",
                    help="arch(s) to lint (default: all)")
    ap.add_argument("--plan", action="append",
                    help="named plan(s) or 'default' (default: all named)")
    ap.add_argument("--shape", action="append",
                    help="shape cell(s); default: the plan's documented "
                         "shapes")
    ap.add_argument("--mesh", default=None,
                    choices=["single", "multi", "both", "none"],
                    help="mesh kind; default: the plan's documented mesh")
    ap.add_argument("--pipelined", action="store_true",
                    help="treat the pipeline-schedule genes as explicitly "
                         "requested (hostability failures become errors)")
    ap.add_argument("--strict", action="store_true",
                    help="warnings also fail the run")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the findings report as JSON")
    ap.add_argument("--no-gene-audit", action="store_true",
                    help="skip the gene-contract audit (the only pass "
                         "needing jax)")
    ap.add_argument("--no-kernel-lint", action="store_true")
    args = ap.parse_args(argv)

    records = lint_cells(args.arch, args.plan, args.shape, args.mesh,
                         pipelined=args.pipelined)
    extra: List[Finding] = []

    if not args.no_kernel_lint:
        from repro.analysis.kernel_lint import lint_kernels
        extra.extend(lint_kernels())

    audit_rows: List[dict] = []
    if not args.no_gene_audit:
        from repro.analysis.gene_audit import audit_findings, \
            audit_gene_space
        audits = audit_gene_space()
        extra.extend(audit_findings(audits))
        audit_rows = [{"field": a.field,
                       "declared_model_only": a.declared_model_only,
                       "artifact_invariant": a.artifact_invariant,
                       "violation": a.violation}
                      for a in audits]

    counts = _severity_counts(records, extra)
    report = {
        "cells": len(records),
        "severity_counts": counts,
        "plan_lint": [r for r in records if r["findings"]],
        "kernel_and_gene_findings": findings_to_json(extra),
        "gene_audit": audit_rows,
        "strict": bool(args.strict),
    }
    if args.json:
        from pathlib import Path
        Path(args.json).write_text(json.dumps(report, indent=1))

    # human-readable summary: every non-info finding, then the tallies
    for rec in records:
        for f in rec["findings"]:
            if f["severity"] == "info":
                continue
            print(f"[{f['severity']}] {rec['arch']} x {rec['plan']} x "
                  f"{rec['shape']} x {rec['mesh']}: {f['rule_id']} "
                  f"{f['message']}")
    for f in sort_findings(extra):
        if f.severity == "info":
            continue
        print(f"[{f.severity}] {f.subject}: {f.rule_id} {f.message}")
    print(f"[lint] {len(records)} plan cells, "
          f"{len(extra)} kernel/gene findings: "
          f"{counts['error']} error, {counts['warning']} warning, "
          f"{counts['info']} info"
          + (f" -> {args.json}" if args.json else ""))

    if counts["error"]:
        return 1
    if args.strict and counts["warning"]:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
