"""Shared finding record for the static-analysis passes (repro.analysis).

Every pass — plan lint, gene-contract audit, kernel lint — reports
:class:`Finding` records instead of raising: static analysis *narrows* the
search (paper §II.A: Clang structure analysis runs before any measurement);
it must never crash it.  Severity semantics:

  * ``error``   — the artifact provably cannot be built / verified (a trace
    or compile would fail, or a cache contract is violated): consumers prune
    the candidate with the paper's penalty, no XLA work spent.
  * ``warning`` — the plan lowers but a requested behavior silently does not
    happen (an inert gene, a schedule that falls back to sequential, a
    sharding request that replicates instead).
  * ``info``    — an observation worth surfacing (an arch property, an
    explicit-padding note), never a gate.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, List, Optional

ERROR = "error"
WARNING = "warning"
INFO = "info"

# ordering for sorting / max_severity (most severe first)
SEVERITIES = (ERROR, WARNING, INFO)
_RANK = {s: i for i, s in enumerate(SEVERITIES)}


@dataclass(frozen=True)
class Finding:
    """One static-analysis finding.

    ``rule_id`` is stable and grep-able (``P...`` plan lint, ``G...`` gene
    audit, ``K...`` kernel lint); ``plan_field`` names the Plan dataclass
    field (or kernel parameter) the finding anchors to, when one exists;
    ``subject`` tags what was linted (plan name, kernel name, gene field)
    so the CLI can group findings across a configs × plans sweep.
    """
    rule_id: str
    severity: str
    message: str
    plan_field: Optional[str] = None
    subject: str = ""
    context: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        out = {"rule_id": self.rule_id, "severity": self.severity,
               "message": self.message}
        if self.plan_field:
            out["plan_field"] = self.plan_field
        if self.subject:
            out["subject"] = self.subject
        if self.context:
            out["context"] = dict(self.context)
        return out


def sort_findings(findings: Iterable[Finding]) -> List[Finding]:
    """Most severe first; stable within a severity."""
    return sorted(findings, key=lambda f: _RANK.get(f.severity, len(_RANK)))


def has_errors(findings: Iterable[Finding]) -> bool:
    return any(f.severity == ERROR for f in findings)


def max_severity(findings: Iterable[Finding]) -> Optional[str]:
    worst = None
    for f in findings:
        if worst is None or _RANK.get(f.severity, 99) < _RANK.get(worst, 99):
            worst = f.severity
    return worst


def findings_to_json(findings: Iterable[Finding]) -> List[dict]:
    return [f.to_dict() for f in sort_findings(findings)]
