"""Static lint of the Pallas kernels in ``repro.kernels``.

Each kernel wrapper in ``repro.kernels`` encodes its grid/BlockSpec contract
imperatively (asserts, ``jnp.pad`` calls).  This pass re-states those
contracts declaratively as :class:`KernelModel` records — the wrapper's
padded operand dims, block shapes and index maps for a representative
problem size — and checks them with plain integer arithmetic:

  * **K001** blocking: every block shape must divide its (post-padding)
    operand dims; a dimension the wrapper pads explicitly is an info note
    (wasted tiles), a dimension the wrapper *asserts* on is an error at the
    offending problem size.
  * **K002** index-map bounds: index maps return **block** indices (the
    old-style BlockSpec convention all these kernels use); over every grid
    corner the mapped block must satisfy ``0 <= b`` and
    ``(b+1)*block <= dim``.  Affine/monotone maps make corners sufficient.
  * **K003** output aliasing: a grid dimension the output index map ignores
    means the same output block is revisited across that dimension's steps.
    On TPU the grid runs sequentially with the *last* dim innermost, so a
    revisit is only sound as the declared accumulation pattern over a
    trailing contiguous suffix of grid dims (matmul's K loop, flash's KV
    loop); anything else is a read-modify-write hazard.

``lint_kernels()`` checks every built-in kernel at representative sizes;
``check_model`` is the generic engine the tests drive with deliberately
broken models.
"""
from __future__ import annotations

from dataclasses import dataclass
from itertools import product
from typing import Callable, List, Optional, Sequence, Tuple

from repro.analysis.findings import ERROR, INFO, Finding


@dataclass
class OperandSpec:
    """One pallas_call operand as the wrapper builds it."""
    name: str
    dims: Tuple[int, ...]            # operand dims after wrapper padding
    block: Tuple[int, ...]           # BlockSpec block_shape
    index_map: Callable              # grid point -> block-index tuple
    padded_dims: Tuple[int, ...] = ()  # dims the wrapper jnp.pad-ed


@dataclass
class KernelModel:
    """Declarative contract of one kernel at one problem size."""
    name: str
    grid: Tuple[int, ...]
    inputs: List[OperandSpec]
    output: OperandSpec
    # grid dims whose output-block revisits are the by-design accumulation
    # (carried in VMEM scratch across the sequential innermost steps)
    accum_dims: Tuple[int, ...] = ()
    size_tag: str = ""               # representative-size label for messages


def _corner_points(grid: Tuple[int, ...]):
    return product(*[(0,) if g == 1 else (0, g - 1) for g in grid])


def _map_at(spec: OperandSpec, point) -> Tuple[int, ...]:
    # tdfir's left-edge clamp uses jnp.maximum: coerce array entries to int
    return tuple(int(b) for b in spec.index_map(*point))


def check_model(model: KernelModel) -> List[Finding]:
    """Generic K001/K002/K003 checks over one KernelModel."""
    out: List[Finding] = []
    subject = model.name
    tag = f" [{model.size_tag}]" if model.size_tag else ""

    def add(rule_id, severity, message, **ctx):
        out.append(Finding(rule_id, severity, message + tag,
                           plan_field=None, subject=subject, context=ctx))

    operands = model.inputs + [model.output]
    for spec in operands:
        if len(spec.dims) != len(spec.block):
            add("K001", ERROR,
                f"{spec.name}: block rank {len(spec.block)} != operand "
                f"rank {len(spec.dims)}")
            continue
        for d, (dim, blk) in enumerate(zip(spec.dims, spec.block)):
            if blk <= 0 or dim <= 0:
                add("K001", ERROR,
                    f"{spec.name}: nonpositive dim/block {dim}/{blk} "
                    f"at axis {d}")
            elif dim % blk != 0:
                # the wrapper either padded this dim (then dims here are
                # post-padding and divide) or never guaranteed divisibility
                add("K001", ERROR,
                    f"{spec.name}: dim {dim} % block {blk} != 0 at axis "
                    f"{d} and the wrapper neither pads nor asserts it")
            elif d in spec.padded_dims:
                add("K001", INFO,
                    f"{spec.name}: axis {d} is explicitly padded to "
                    f"{dim} (block {blk}) — divisible by construction, "
                    "padding tiles compute garbage that is sliced off")

    # K002: block-index bounds over the grid corners
    for spec in operands:
        if len(spec.dims) != len(spec.block):
            continue
        for point in _corner_points(model.grid):
            try:
                bidx = _map_at(spec, point)
            except Exception as e:
                add("K002", ERROR,
                    f"{spec.name}: index_map raised at grid point "
                    f"{point}: {e!r}")
                break
            if len(bidx) != len(spec.dims):
                add("K002", ERROR,
                    f"{spec.name}: index_map returns rank {len(bidx)} "
                    f"for a rank-{len(spec.dims)} operand")
                break
            oob = [d for d, (b, dim, blk)
                   in enumerate(zip(bidx, spec.dims, spec.block))
                   if b < 0 or (b + 1) * blk > dim]
            if oob:
                add("K002", ERROR,
                    f"{spec.name}: block index {bidx} at grid point "
                    f"{point} is out of bounds on axes {oob} "
                    f"(dims {spec.dims}, block {spec.block})")
                break

    # K003: output revisits across grid steps
    if len(model.output.dims) == len(model.output.block):
        base = tuple(0 for _ in model.grid)
        try:
            base_idx = _map_at(model.output, base)
            insensitive = []
            for d, g in enumerate(model.grid):
                if g <= 1:
                    continue          # a single step cannot revisit
                probe = list(base)
                probe[d] = 1
                if _map_at(model.output, tuple(probe)) == base_idx:
                    insensitive.append(d)
        except Exception:
            insensitive = []          # K002 already reported the map error
        if insensitive:
            n = len(model.grid)
            trailing = list(range(n - len(insensitive), n))
            if insensitive != trailing:
                add("K003", ERROR,
                    f"output block is revisited across non-innermost grid "
                    f"dims {insensitive} (grid {model.grid}): the "
                    "sequential-accumulation pattern only holds for a "
                    "trailing suffix")
            else:
                undeclared = [d for d in insensitive
                              if d not in model.accum_dims]
                if undeclared:
                    add("K003", ERROR,
                        f"output block is revisited across grid dims "
                        f"{undeclared} but the kernel declares no "
                        "accumulation over them — read-modify-write "
                        "hazard between grid steps")
                else:
                    add("K003", INFO,
                        f"output accumulates over trailing grid dims "
                        f"{insensitive} (declared reduction, VMEM-carried)")
    return out


# ---------------------------------------------------------------------------
# Built-in kernel models: each builder replicates its wrapper's padding /
# assert logic for a problem size, reporting wrapper asserts as K001 errors.
# ---------------------------------------------------------------------------

def matmul_model(m: int = 300, n: int = 200, k: int = 150, *,
                 block_m: int = 128, block_n: int = 128, block_k: int = 128
                 ) -> Tuple[Optional[KernelModel], List[Finding]]:
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    mp, np_, kp = m + pm, n + pn, k + pk
    model = KernelModel(
        name="matmul", grid=(mp // bm, np_ // bn, kp // bk),
        inputs=[
            OperandSpec("a", (mp, kp), (bm, bk),
                        lambda i, j, kk: (i, kk),
                        padded_dims=(0,) * (pm > 0) + (1,) * (pk > 0)),
            OperandSpec("b", (kp, np_), (bk, bn),
                        lambda i, j, kk: (kk, j),
                        padded_dims=(0,) * (pk > 0) + (1,) * (pn > 0)),
        ],
        output=OperandSpec("o", (mp, np_), (bm, bn),
                           lambda i, j, kk: (i, j)),
        accum_dims=(2,), size_tag=f"{m}x{k}@{k}x{n}")
    return model, []


def flash_attention_model(bh: int = 8, sq: int = 1024, skv: int = 1024,
                          d: int = 64, *, block_q: int = 512,
                          block_kv: int = 512
                          ) -> Tuple[Optional[KernelModel], List[Finding]]:
    bq, bkv = min(block_q, sq), min(block_kv, skv)
    if sq % bq != 0 or skv % bkv != 0:
        return None, [Finding(
            "K001", ERROR,
            f"flash_attention: sq {sq} % block_q {bq} or skv {skv} % "
            f"block_kv {bkv} nonzero — the wrapper asserts (no padding "
            "path)", subject="flash_attention")]
    model = KernelModel(
        name="flash_attention", grid=(bh, sq // bq, skv // bkv),
        inputs=[
            OperandSpec("q", (bh, sq, d), (1, bq, d),
                        lambda b, i, j: (b, i, 0)),
            OperandSpec("k", (bh, skv, d), (1, bkv, d),
                        lambda b, i, j: (b, j, 0)),
            OperandSpec("v", (bh, skv, d), (1, bkv, d),
                        lambda b, i, j: (b, j, 0)),
        ],
        output=OperandSpec("o", (bh, sq, d), (1, bq, d),
                           lambda b, i, j: (b, i, 0)),
        accum_dims=(2,), size_tag=f"bh{bh} sq{sq} skv{skv}")
    return model, []


def decode_attention_model(bh: int = 8, s: int = 2048, d: int = 64, *,
                           block_kv: int = 512
                           ) -> Tuple[Optional[KernelModel], List[Finding]]:
    bkv = min(block_kv, s)
    if s % bkv != 0:
        return None, [Finding(
            "K001", ERROR,
            f"decode_attention: cache seq {s} % block_kv {bkv} != 0 — "
            "the wrapper asserts (no padding path)",
            subject="decode_attention")]
    model = KernelModel(
        name="decode_attention", grid=(bh, s // bkv),
        inputs=[
            OperandSpec("q", (bh, 1, d), (1, 1, d),
                        lambda b, j: (b, 0, 0)),
            OperandSpec("k_cache", (bh, s, d), (1, bkv, d),
                        lambda b, j: (b, j, 0)),
            OperandSpec("v_cache", (bh, s, d), (1, bkv, d),
                        lambda b, j: (b, j, 0)),
            OperandSpec("lens", (bh, 1), (1, 1),
                        lambda b, j: (b, 0)),
        ],
        output=OperandSpec("o", (bh, d), (1, d),
                           lambda b, j: (b, 0)),
        accum_dims=(1,), size_tag=f"bh{bh} s{s}")
    return model, []


def tdfir_model(f: int = 4, n: int = 1000, k: int = 16, *,
                block_n: int = 512
                ) -> Tuple[Optional[KernelModel], List[Finding]]:
    bn = min(block_n, n)
    if bn < k:
        return None, [Finding(
            "K001", ERROR,
            f"tdfir: block_n {bn} < taps {k} — the sliding history cannot "
            "cover the filter, the wrapper asserts", subject="tdfir")]
    pn = (-n) % bn
    np_ = n + pn

    def prev_map(i, j):
        return (i, max(j - 1, 0))    # wrapper uses jnp.maximum; same clamp

    model = KernelModel(
        name="tdfir", grid=(f, np_ // bn),
        inputs=[
            OperandSpec("x_prev", (f, np_), (1, bn), prev_map,
                        padded_dims=(1,) * (pn > 0)),
            OperandSpec("x_cur", (f, np_), (1, bn),
                        lambda i, j: (i, j),
                        padded_dims=(1,) * (pn > 0)),
            OperandSpec("h", (f, bn), (1, bn),
                        lambda i, j: (i, 0)),
        ],
        output=OperandSpec("y", (f, np_), (1, bn),
                           lambda i, j: (i, j)),
        size_tag=f"f{f} n{n} k{k}")
    return model, []


_BUILDERS = (matmul_model, flash_attention_model, decode_attention_model,
             tdfir_model)


def kernel_models(builders: Sequence[Callable] = _BUILDERS
                  ) -> Tuple[List[KernelModel], List[Finding]]:
    models, findings = [], []
    for build in builders:
        model, errs = build()
        findings.extend(errs)
        if model is not None:
            models.append(model)
    return models, findings


def lint_kernels(builders: Sequence[Callable] = _BUILDERS) -> List[Finding]:
    """All K-findings for the built-in kernels at representative sizes."""
    models, findings = kernel_models(builders)
    for model in models:
        findings.extend(check_model(model))
    return findings
