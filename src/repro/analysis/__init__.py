"""repro.analysis — static analysis that prunes the search before any
compile (paper §II.A: structure analysis precedes every measurement).

Three passes, one CLI:

  * :func:`lint_plan` — pure-arithmetic feasibility of a Plan × mesh ×
    arch spec (``plan_lint``); wired into the GA evaluators so
    error-severity candidates take the penalty with zero XLA work.
  * :func:`audit_gene_space` — proves the ``structural=False`` gene flags
    against the traced artifact (``gene_audit``): the ``SearchCache``
    identity contract, enforced instead of commented.
  * :func:`lint_kernels` — block/grid/index-map checks over the Pallas
    kernels (``kernel_lint``).

CLI: ``python -m repro.analysis.lint [--arch ... --plan ... --strict]``.
"""
from repro.analysis.findings import (ERROR, INFO, WARNING, Finding,
                                     findings_to_json, has_errors,
                                     max_severity, sort_findings)
from repro.analysis.gene_audit import (GeneAudit, audit_findings,
                                       audit_gene_space)
from repro.analysis.kernel_lint import (KernelModel, OperandSpec,
                                        check_model, lint_kernels)
from repro.analysis.plan_lint import DEVICE_MEMORY_BYTES, lint_plan

__all__ = [
    "ERROR", "WARNING", "INFO", "Finding", "findings_to_json",
    "has_errors", "max_severity", "sort_findings",
    "GeneAudit", "audit_findings", "audit_gene_space",
    "KernelModel", "OperandSpec", "check_model", "lint_kernels",
    "DEVICE_MEMORY_BYTES", "lint_plan",
]
