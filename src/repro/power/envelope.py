"""Per-destination power envelopes (arXiv 2110.11520's measured machines).

A :class:`PowerEnvelope` is the static electrical identity of one offload
destination: what it draws doing nothing (``idle_w``), what it draws flat
out (``peak_w``), and how much of the active draw belongs to the memory
system rather than the compute units (``memory_w_fraction``).  The energy
model (:mod:`repro.power.model`) interpolates between idle and peak with
the roofline's utilization terms, so a comm-bound destination that idles
its ALUs is charged idle-heavy watts over a long step — usually *more*
joules than a busy fast one.

Calibration contract (see ROADMAP "repro.power"): the built-in numbers are
vendor TDP / idle figures for the evaluation hardware of Yamato's power
follow-up (Xeon E5-2660 v4 many-core, Tesla T4 GPU, Intel PAC Arria 10
FPGA) plus a TPU v5e chip envelope for mesh cells.  Only their *relative*
shape matters for selection; override per backend with
``Backend.with_(power=PowerEnvelope(...))`` or per call by passing an
envelope to :class:`~repro.power.model.EnergyModel`.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional


@dataclass(frozen=True)
class PowerEnvelope:
    """Idle/peak draw (+ memory share of the active draw) of one device."""
    name: str
    idle_w: float
    peak_w: float
    # fraction of the active (peak - idle) draw attributable to the memory
    # system; the rest follows compute utilization
    memory_w_fraction: float = 0.3

    def __post_init__(self):
        if self.idle_w < 0 or self.peak_w <= 0:
            raise ValueError(f"non-physical envelope {self.name!r}: "
                             f"idle={self.idle_w}, peak={self.peak_w}")
        if self.peak_w < self.idle_w:
            raise ValueError(f"envelope {self.name!r}: peak_w {self.peak_w} "
                             f"< idle_w {self.idle_w}")
        if not 0.0 <= self.memory_w_fraction <= 1.0:
            raise ValueError(f"envelope {self.name!r}: memory_w_fraction "
                             f"must be in [0, 1]")

    @property
    def active_w(self) -> float:
        return self.peak_w - self.idle_w

    def scaled(self, n: float, name: Optional[str] = None) -> "PowerEnvelope":
        """The envelope of ``n`` such devices (a mesh slice draws n chips)."""
        if n <= 0:
            raise ValueError(f"cannot scale envelope by n={n}")
        return replace(self, name=name or f"{self.name}x{n:g}",
                       idle_w=self.idle_w * n, peak_w=self.peak_w * n)

    def __add__(self, other) -> "PowerEnvelope":
        """The combined envelope of two co-located devices: draws sum, the
        memory share of the combined active draw is the active-weighted mix
        of each device's share.  This is the one definition of "summed
        fleet draw" shared by Router admission headroom and the fleet
        placement planner's power-cap check."""
        if not isinstance(other, PowerEnvelope):
            return NotImplemented
        active = self.active_w + other.active_w
        mem = ((self.active_w * self.memory_w_fraction
                + other.active_w * other.memory_w_fraction) / active
               if active > 0 else self.memory_w_fraction)
        return PowerEnvelope(name=f"{self.name}+{other.name}",
                             idle_w=self.idle_w + other.idle_w,
                             peak_w=self.peak_w + other.peak_w,
                             memory_w_fraction=mem)

    def __radd__(self, other) -> "PowerEnvelope":
        # lets sum(envelopes) work: 0 + envelope == envelope
        if other == 0:
            return self
        return NotImplemented


# Built-in calibration (vendor TDP/idle for the power follow-up's machines).
MANY_CORE_XEON = PowerEnvelope("xeon-e5-2660v4", idle_w=55.0, peak_w=105.0,
                               memory_w_fraction=0.35)
GPU_T4 = PowerEnvelope("tesla-t4", idle_w=10.0, peak_w=70.0,
                       memory_w_fraction=0.25)
FPGA_A10 = PowerEnvelope("intel-pac-arria10", idle_w=25.0, peak_w=66.0,
                         memory_w_fraction=0.20)
# per-chip envelope for compiled mesh cells (repro.launch.dryrun); scaled
# by the cell's chip count
TPU_V5E_CHIP = PowerEnvelope("tpu-v5e-chip", idle_w=60.0, peak_w=200.0,
                             memory_w_fraction=0.30)
# last-resort envelope for destinations that declare nothing
GENERIC = PowerEnvelope("generic-accelerator", idle_w=50.0, peak_w=150.0,
                        memory_w_fraction=0.30)

# paper_analogue -> envelope for the built-in destinations (kept here so
# repro.backends can stay import-light; Backend.power overrides this)
BY_ANALOGUE = {
    "many-core CPU": MANY_CORE_XEON,
    "GPU": GPU_T4,
    "GPU library": GPU_T4,
    "FPGA": FPGA_A10,
}


def envelope_for(backend) -> PowerEnvelope:
    """The envelope the planner charges a backend's records against:
    the backend's declared ``power``, else the built-in calibration for its
    paper analogue, else :data:`GENERIC`."""
    declared = getattr(backend, "power", None)
    if declared is not None:
        return declared
    return BY_ANALOGUE.get(getattr(backend, "paper_analogue", ""), GENERIC)


def fleet_draw_w(draws) -> float:
    """Aggregate modeled draw (watts) of a fleet — the one summation the
    Router's admission headroom and the fleet planner's power-cap check
    share.  ``draws`` is an iterable of per-endpoint/per-app watts; a None
    entry (an app whose draw could not be modeled) is charged as if it
    were not there — callers that must be conservative should have dropped
    unmodeled candidates at ranking time (``rank(power_budget_w=...)``
    already does)."""
    return float(sum(d for d in draws if d is not None))
