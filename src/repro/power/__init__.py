"""repro.power — real energy model + power-aware offload selection.

Yamato's follow-up to the source paper ("Power Saving Evaluation with
Automatic Offloading", arXiv 2110.11520) keeps the verification pipeline
and swaps the objective: pick the destination with the best performance
per watt, optionally under an allowed slowdown.  This package supplies the
physics for that objective; :mod:`repro.backends.policy` supplies the
ranking (``power`` / ``edp`` policies plus the ``power_budget_w`` /
``max_slowdown`` selection constraints).

Public surface (stable — later PRs build on this):

  * :class:`PowerEnvelope` — idle/peak watts + memory-power fraction of one
    destination; built-ins :data:`MANY_CORE_XEON`, :data:`GPU_T4`,
    :data:`FPGA_A10`, :data:`TPU_V5E_CHIP`, :data:`GENERIC`;
    ``envelope_for(backend)`` resolves ``Backend.power`` -> built-in
    calibration -> generic.
  * :class:`EnergyModel` — roofline utilization x envelope -> watts;
    ``from_roofline`` (modeled path) / ``from_time`` (envelope x host-time
    fallback).
  * :class:`EnergyReport` — ``energy_j`` / ``avg_watts`` / ``edp`` /
    ``perf_per_watt`` per step.
  * :func:`energy_for_record` — the planner's per-record charge rule.
  * :func:`fleet_draw_w` — the one definition of summed fleet draw
    (Router admission headroom and the fleet planner's power cap);
    ``PowerEnvelope.__add__`` composes co-located device envelopes.
"""
from repro.power.envelope import (BY_ANALOGUE, FPGA_A10, GENERIC, GPU_T4,
                                  MANY_CORE_XEON, TPU_V5E_CHIP,
                                  PowerEnvelope, envelope_for, fleet_draw_w)
from repro.power.model import (EnergyModel, EnergyReport, cell_energy,
                               energy_for_record)

__all__ = [
    "PowerEnvelope", "EnergyModel", "EnergyReport",
    "MANY_CORE_XEON", "GPU_T4", "FPGA_A10", "TPU_V5E_CHIP", "GENERIC",
    "BY_ANALOGUE", "envelope_for", "energy_for_record", "cell_energy",
    "fleet_draw_w",
]
