"""Energy model: roofline utilization x power envelope -> joules per step.

The model the ``power`` / ``edp`` selection policies rank with
(arXiv 2110.11520 changes the paper's objective from "fastest correct
destination" to performance per watt without changing the pipeline):

    avg_watts = idle_w + active_w * mix
    mix       = (1 - mem_frac) * compute_util
                + mem_frac * (memory_util + collective_util)
    energy_j  = avg_watts * step_time_s

``compute_util`` / ``memory_util`` / ``collective_util`` are the roofline
terms divided by the (bubble-stretched) step time
(:func:`repro.core.cost_model.roofline_terms`), so a pipeline bubble or a
dominant collective lowers the draw but lengthens the step — and the idle
power burned across the stretch makes energy strictly *increase* with the
bubble fraction.  Communication is charged at the memory fraction of the
active draw: moving bytes exercises the memory/IO system, not the ALUs.

When no roofline was recorded (a host-only verification), the fallback is
envelope x host time at full utilization — peak watts for the measured
seconds, the most conservative charge.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Mapping, Optional

from repro.power.envelope import PowerEnvelope


@dataclass(frozen=True)
class EnergyReport:
    """Modeled energy of one destination's step (lower is better)."""
    energy_j: float          # joules per step
    avg_watts: float         # average draw across the step
    edp: float               # energy-delay product, J*s
    perf_per_watt: float     # steps per joule (throughput / watts)
    step_time_s: float
    source: str              # "roofline" | "host-time"
    envelope: str            # name of the envelope charged

    def to_dict(self) -> dict:
        return asdict(self)


def _term(rl, name: str, default: float = 0.0) -> float:
    if isinstance(rl, Mapping):
        v = rl.get(name, default)
    else:
        v = getattr(rl, name, default)
    return float(v) if v is not None else default


class EnergyModel:
    """Turns rooflines (or bare host times) into :class:`EnergyReport`s
    under one :class:`PowerEnvelope`."""

    def __init__(self, envelope: PowerEnvelope):
        self.envelope = envelope

    def watts(self, compute_util: float, memory_util: float,
              collective_util: float = 0.0) -> float:
        env = self.envelope
        mix = ((1.0 - env.memory_w_fraction) * compute_util
               + env.memory_w_fraction * (memory_util + collective_util))
        return env.idle_w + env.active_w * min(max(mix, 0.0), 1.0)

    def _report(self, watts: float, step_s: float, source: str
                ) -> EnergyReport:
        energy = watts * step_s
        return EnergyReport(
            energy_j=energy, avg_watts=watts, edp=energy * step_s,
            perf_per_watt=(1.0 / energy) if energy > 0 else 0.0,
            step_time_s=step_s, source=source, envelope=self.envelope.name)

    def from_roofline(self, rl) -> Optional[EnergyReport]:
        """Energy of a modeled step.  ``rl`` is a
        :class:`~repro.core.cost_model.Roofline` or its ``to_dict()`` form
        (``VerificationRecord.mesh_info["roofline"]``); older dicts without
        the utilization terms fall back to term_s / step_time_s."""
        step = _term(rl, "step_time_s")
        if step <= 0.0:
            return None
        cu = _term(rl, "compute_util", _term(rl, "compute_s") / step)
        mu = _term(rl, "memory_util", _term(rl, "memory_s") / step)
        xu = _term(rl, "collective_util", _term(rl, "collective_s") / step)
        return self._report(self.watts(cu, mu, xu), step, "roofline")

    def from_time(self, time_s: float,
                  utilization: float = 1.0) -> Optional[EnergyReport]:
        """Envelope x host-time fallback: the destination is assumed busy at
        ``utilization`` (default 1.0 => peak watts) for the measured
        seconds."""
        if not (time_s > 0.0) or time_s == float("inf"):
            return None
        # compute AND memory busy at the same level: utilization=1.0 is
        # peak_w exactly, whatever the envelope's memory fraction
        return self._report(self.watts(utilization, utilization), time_s,
                            "host-time")

    def tick_joules(self, tick_s: float,
                    active_fraction: float = 1.0) -> float:
        """Joules one serving tick burns (repro.serve.metrics).

        A continuous-batching slot pool runs the same decode step however
        many slots are live, so draw scales with occupancy, not work: idle
        watts are burned for the whole tick unconditionally, active watts
        for the ``active_fraction`` of slots doing useful decode — the
        idle-power term is exactly why batching together is cheaper per
        token than decoding alone.
        """
        if not (tick_s > 0.0):
            return 0.0
        af = min(max(active_fraction, 0.0), 1.0)
        return (self.envelope.idle_w
                + self.envelope.active_w * af) * tick_s


def cell_energy(rl, n_chips: float) -> Optional[EnergyReport]:
    """Energy of one compiled mesh cell: the TPU chip envelope scaled to
    the slice, at the cell roofline's utilization — the shared charge rule
    of ``repro.launch.dryrun`` cells and ``examples/autoplan_model.py``
    candidates (one place to change when the chip envelope does)."""
    from repro.power.envelope import TPU_V5E_CHIP
    return EnergyModel(TPU_V5E_CHIP.scaled(n_chips)).from_roofline(rl)


def energy_for_record(record, envelope: PowerEnvelope
                      ) -> Optional[EnergyReport]:
    """Energy of one planner :class:`VerificationRecord`: modeled from the
    mesh-verified roofline when a ``cost_runner`` recorded one, envelope x
    host-time otherwise; None when the record has nothing usable (inf /
    incorrect records are never charged)."""
    if not getattr(record, "correct", True):
        return None
    rl = (record.mesh_info or {}).get("roofline") \
        if getattr(record, "mesh_info", None) else None
    model = EnergyModel(envelope)
    if rl:
        rep = model.from_roofline(rl)
        if rep is not None:
            return rep
    return model.from_time(getattr(record, "best_time_s", float("inf")))
