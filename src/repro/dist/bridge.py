"""Planner <-> mesh bridge (paper §II.C meets the real mesh).

The planner's verification environment times candidates unsharded
(:class:`TimedRunner`).  For the destinations that are *mesh analogues* —
"dp" (many-core CPU: data parallel) and "tp" (GPU: tensor parallel) — this
module compiles the candidate for an actual mesh and scores the produced
artifact with :meth:`CompiledCostRunner.measure`, so destination selection
can see collective/communication cost instead of only single-host timing.

This module is the default ``mesh_verify`` hook of the built-in backends
(:mod:`repro.backends.builtin`); a custom backend can swap it for its own
``mesh_verify_fn``.  A backend advertises its mesh analogue via
``Backend.mesh_role`` ("data" | "model" | ""); the bridge derives input
shardings from it:

  * data role — leading dimension of every input over the batch axes;
  * model role — trailing dimension over the "model" axis.

Both inherit :class:`Rules`' divisibility fallback, so odd shapes replicate
instead of failing to lower.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.dist.plan import Plan
from repro.dist.sharding import Rules, tree_shardings

# Plan templates the dp / tp verifications compile under.
DEST_PLANS = {
    "data": Plan(name="verify-dp", remat="none"),
    "model": Plan(name="verify-tp", remat="none"),
}


def state_axes(state, mesh_role: str):
    """Logical-axes pytree for an offloadable app's input state dict."""

    def axes_for(x):
        ndim = getattr(x, "ndim", 0)
        if ndim == 0:
            return ()
        if mesh_role == "data":
            return ("batch",) + (None,) * (ndim - 1)
        return (None,) * (ndim - 1) + ("ff",)      # "ff" -> model axis

    return jax.tree.map(axes_for, state)


def dest_rules(dest, mesh) -> Optional[Rules]:
    role = getattr(dest, "mesh_role", "")
    if not role or role not in DEST_PLANS:
        return None
    return Rules(mesh, DEST_PLANS[role])


def mesh_verify(cost_runner, dest, fn, inputs):
    """Compile ``fn(inputs)`` for ``cost_runner.mesh`` under the
    destination's sharding and return the roofline Evaluation, or None when
    the destination has no mesh analogue (e.g. the FPGA/pallas one)."""
    if cost_runner is None or getattr(cost_runner, "mesh", None) is None:
        return None
    rules = dest_rules(dest, cost_runner.mesh)
    if rules is None:
        return None
    axes = state_axes(inputs, dest.mesh_role)
    sds = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
        if hasattr(x, "shape") else x, inputs)
    in_shardings = tree_shardings(rules, axes, sds)
    return cost_runner.measure(fn, sds, in_shardings=(in_shardings,))
