"""Pipeline-parallel schedules: static tick plans for ``pipeline_apply``.

A :class:`Schedule` turns (stages, ranks, microbatches, virtual stages) into
a :class:`TickPlan` — a static per-tick script that ``pipeline_apply``
executes inside one ``shard_map``.  Every schedule computes the *same*
function (numerics match ``sequential_apply`` exactly, forward and grad);
they differ in how microbatches stream through the stage ring and therefore
in the pipeline **bubble** (ticks a rank sits idle) and the per-rank
activation **in-flight** count (the memory a production backward pass keeps
live) — exactly the trade the GA searches via ``Plan.pipeline_schedule`` /
``Plan.virtual_stages`` (paper §II.C: schedule choice is a verified gene,
not a hardcode).

The three built-ins:

  * ``gpipe``        — the reference: all m microbatches flood the ring,
    bubble S-1 ticks, in-flight m (every activation held until backward).
  * ``one_f_one_b``  — identical forward tick order (1F1B reorders the
    *backward* relative to the forward; per-rank forward order is
    unchanged), annotated with warmup/steady/cooldown phases and an
    in-flight cap of min(S, m) instead of m: the schedule a memory-bound
    candidate should report to the cost model.
  * ``interleaved``  — V virtual stages per rank (stage s lives on rank
    s mod R as chunk s // R); microbatches recirculate the ring V times, so
    the bubble shrinks to R-1 = S/V - 1 ticks at the cost of V-1 extra
    in-flight chunk activations.

Tick semantics (see ``pipeline_apply``): at tick ``t`` every rank applies
its stage to the value it holds, then ``ppermute``s the result forward.
Rank 0 feeds ``mb[feed_mb]`` (a fresh microbatch), ``buf[feed_buf]`` (a
recirculated chunk output) or zeros (a bubble — drain ticks must not
recompute real data); rank 0 stashes the incoming carry into
``buf[stash_buf]`` when a chunk output wraps around; the last rank's output
is captured into final slot ``capture_out``.  Which virtual chunk a rank
computes at tick ``t`` follows from its entry tick:
``chunk = clip((t - rank) // entry_stride, 0, V-1)``.

The closed-form bubble/in-flight numbers live in
``repro.core.cost_model.pipeline_bubble_fraction`` /
``pipeline_in_flight`` (the planner's side); tests pin them to the tick
plans built here.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class Tick:
    """One tick of the static plan (-1 = not this tick)."""

    feed_mb: int = -1       # fresh microbatch index fed at rank 0
    feed_buf: int = -1      # recirculation-buffer slot fed at rank 0
    stash_buf: int = -1     # buffer slot rank 0 stashes the incoming carry to
    capture_out: int = -1   # final output slot captured at the last rank
    phase: str = "steady"   # warmup | steady | cooldown (annotation)


@dataclass(frozen=True)
class TickPlan:
    """A fully static schedule for one (S, R, m, V) pipeline problem."""

    schedule: str
    n_stages: int
    n_ranks: int
    virtual_stages: int
    microbatches: int
    ticks: Tuple[Tick, ...]
    entry_stride: int       # pass-start stride (chunk formula, see module doc)
    in_flight: int          # modeled live microbatch activations per rank

    @property
    def total_ticks(self) -> int:
        return len(self.ticks)

    @property
    def busy_ticks(self) -> int:
        """Per-rank ticks doing useful work: V passes over m microbatches."""
        return self.virtual_stages * self.microbatches

    @property
    def bubble_ticks(self) -> int:
        return self.total_ticks - self.busy_ticks

    @property
    def bubble_fraction(self) -> float:
        return self.bubble_ticks / self.total_ticks


def _ring_ticks(m: int, n_ranks: int, v: int) -> Tuple[Tuple[Tick, ...], int]:
    """Static tick script for m microbatches through an n_ranks ring V times.

    Pass c's entries at rank 0 occupy ticks [c*stride, c*stride + m); item
    (j, c) sits at rank r at tick c*stride + j + r, wraps to rank 0 at
    c*stride + j + n_ranks.  stride = max(m, n_ranks) keeps entries
    conflict-free for every m (wrapped items wait in the buffer, fresh
    passes wait for the previous pass's entries to clear).
    """
    stride = max(m, n_ranks)
    total = (v - 1) * stride + m + n_ranks - 1
    feed_mb: Dict[int, int] = {}
    feed_buf: Dict[int, int] = {}
    stash: Dict[int, int] = {}
    capture: Dict[int, int] = {}
    for c in range(v):
        start = c * stride
        for j in range(m):
            if c == 0:
                feed_mb[start + j] = j
            else:
                feed_buf[start + j] = j
            if c < v - 1:
                stash[start + j + n_ranks] = j
            else:
                capture[start + j + n_ranks - 1] = j
    fill, drain = n_ranks - 1, total - (n_ranks - 1)
    ticks = tuple(
        Tick(feed_mb=feed_mb.get(t, -1), feed_buf=feed_buf.get(t, -1),
             stash_buf=stash.get(t, -1), capture_out=capture.get(t, -1),
             phase=("warmup" if t < fill else
                    "cooldown" if t >= drain else "steady"))
        for t in range(total))
    return ticks, stride


class Schedule:
    """Build a :class:`TickPlan`, or ``None`` when the (stages, ranks, m, V)
    problem does not fit this schedule — ``pipeline_apply`` then falls back
    to the sequential reference, the same discipline as ``Rules``: an
    invalid plan must still compute."""

    name: str = "base"

    def build(self, *, n_stages: int, n_ranks: int, microbatches: int,
              virtual_stages: int = 1) -> Optional[TickPlan]:
        raise NotImplementedError


class GPipeSchedule(Schedule):
    name = "gpipe"

    def build(self, *, n_stages, n_ranks, microbatches, virtual_stages=1):
        # virtual_stages is an interleaved-only gene: ignored here
        if n_stages != n_ranks or microbatches < 1:
            return None
        ticks, stride = _ring_ticks(microbatches, n_ranks, 1)
        return TickPlan(schedule=self.name, n_stages=n_stages,
                        n_ranks=n_ranks, virtual_stages=1,
                        microbatches=microbatches, ticks=ticks,
                        entry_stride=stride, in_flight=microbatches)


class OneFOneBSchedule(Schedule):
    """Same forward tick order as GPipe; the backward interleaving caps the
    per-rank in-flight activations at min(S, m) — the number the cost
    model's memory term sees."""

    name = "one_f_one_b"

    def build(self, *, n_stages, n_ranks, microbatches, virtual_stages=1):
        # virtual_stages is an interleaved-only gene: ignored here
        if n_stages != n_ranks or microbatches < 1:
            return None
        ticks, stride = _ring_ticks(microbatches, n_ranks, 1)
        return TickPlan(schedule=self.name, n_stages=n_stages,
                        n_ranks=n_ranks, virtual_stages=1,
                        microbatches=microbatches, ticks=ticks,
                        entry_stride=stride,
                        in_flight=min(n_ranks, microbatches))


class InterleavedSchedule(Schedule):
    """V virtual stages per rank: stage s = chunk s // R on rank s mod R.
    Bubble shrinks to R-1 = S/V - 1 ticks (for m >= R); each rank holds up
    to V-1 extra chunk activations awaiting recirculation."""

    name = "interleaved"

    def build(self, *, n_stages, n_ranks, microbatches, virtual_stages=1):
        v = virtual_stages
        if (v < 1 or microbatches < 1 or n_ranks < 1
                or n_stages != n_ranks * v):
            return None
        ticks, stride = _ring_ticks(microbatches, n_ranks, v)
        in_flight = min(microbatches * v, min(n_ranks, microbatches) + v - 1)
        return TickPlan(schedule=self.name, n_stages=n_stages,
                        n_ranks=n_ranks, virtual_stages=v,
                        microbatches=microbatches, ticks=ticks,
                        entry_stride=stride, in_flight=in_flight)


SCHEDULES: Dict[str, Schedule] = {
    s.name: s for s in (GPipeSchedule(), OneFOneBSchedule(),
                        InterleavedSchedule())
}


def get_schedule(name) -> Optional[Schedule]:
    """Resolve a schedule name (or pass an instance through); None for an
    unknown name — callers treat that as "cannot pipeline" and fall back."""
    if isinstance(name, Schedule):
        return name
    return SCHEDULES.get(name)


def register_schedule(schedule: Schedule, replace: bool = False) -> Schedule:
    if schedule.name in SCHEDULES and not replace:
        raise ValueError(f"schedule {schedule.name!r} already registered")
    SCHEDULES[schedule.name] = schedule
    return schedule
