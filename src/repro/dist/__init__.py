"""Parallelism-plan subsystem: the framework-side "environment-adaptive"
configuration layer (paper §II.C applied to the mesh, DESIGN.md §2).

Public API (stable — later PRs build on this):

  * :mod:`repro.dist.plan`      — :class:`Plan` execution-plan dataclass with
    the categorical ``GENE_SPACE`` the GA searches (``from_genes`` /
    ``to_genes`` / ``gene_cardinalities``).
  * :mod:`repro.dist.sharding`  — :class:`Rules` (logical-axis -> mesh-axis
    mapping with divisibility / duplicate-axis fallback), :class:`NullRules`,
    ``tree_shardings`` and ``batch_axes``.
  * :mod:`repro.dist.pipeline`  — ``pipeline_apply`` / ``sequential_apply``
    (GPipe-style stage parallelism over the "pod" axis).
  * :mod:`repro.dist.bridge`    — planner <-> mesh bridge: compile a
    dp / tp candidate under a real mesh via ``CompiledCostRunner``.
  * :mod:`repro.dist.compat`    — JAX version shims (``shard_map``,
    ``make_mesh``, ``AxisType``) so the same call sites run on the
    installed runtime and on current JAX.
"""
from repro.dist.plan import Plan
from repro.dist.sharding import NullRules, Rules, batch_axes, tree_shardings

__all__ = ["Plan", "Rules", "NullRules", "tree_shardings", "batch_axes"]
