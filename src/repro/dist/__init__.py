"""Parallelism-plan subsystem: the framework-side "environment-adaptive"
configuration layer (paper §II.C applied to the mesh, DESIGN.md §2).

Public API (stable — later PRs build on this):

  * :mod:`repro.dist.plan`      — :class:`Plan` execution-plan dataclass with
    the categorical ``GENE_SPACE`` the GA searches (``from_genes`` /
    ``to_genes`` / ``gene_cardinalities``); ``Gene(field, choices,
    structural)`` entries flag the model-only pipeline genes
    (``pipeline_schedule`` / ``virtual_stages``), and
    ``Plan.structural_key()`` is the compiled-artifact identity
    ``repro.core.search_cache`` dedupes compiles by.
  * :mod:`repro.dist.sharding`  — :class:`Rules` (logical-axis -> mesh-axis
    mapping with largest-divisible-prefix / duplicate-axis fallback),
    :class:`NullRules`, ``tree_shardings`` and ``batch_axes``.
  * :mod:`repro.dist.schedules` — pipeline-parallel schedules as static tick
    plans: :class:`Schedule` / :class:`TickPlan`, built-ins ``gpipe``,
    ``one_f_one_b``, ``interleaved`` (``SCHEDULES`` / ``get_schedule`` /
    ``register_schedule``).
  * :mod:`repro.dist.pipeline`  — ``pipeline_apply`` / ``sequential_apply``
    (stage parallelism over the "pod" axis under any registered schedule).
  * :mod:`repro.dist.bridge`    — planner <-> mesh bridge: compile a
    dp / tp candidate under a real mesh via ``CompiledCostRunner``.
  * :mod:`repro.dist.compat`    — JAX version shims (``shard_map``,
    ``make_mesh``, ``AxisType``) so the same call sites run on the
    installed runtime and on current JAX.
"""
from repro.dist.plan import Plan
from repro.dist.schedules import (SCHEDULES, Schedule, TickPlan,
                                  get_schedule, register_schedule)
from repro.dist.sharding import NullRules, Rules, batch_axes, tree_shardings

__all__ = ["Plan", "Rules", "NullRules", "tree_shardings", "batch_axes",
           "Schedule", "TickPlan", "SCHEDULES", "get_schedule",
           "register_schedule"]
