"""Pipeline parallelism over the "pod" axis (differentiable, schedulable).

``pipeline_apply`` runs S stacked stages on the mesh's pipeline axis under a
:mod:`repro.dist.schedules` tick plan: each rank holds its stage chunk(s),
microbatches flow rank-to-rank via ``ppermute``, and the last rank's outputs
are gathered with a masked psum.  Numerics match ``sequential_apply``
exactly for every schedule (same ops, same order per microbatch), and
gradients flow to every stage because ``ppermute`` transposes to the
reverse permutation.

Schedules (``schedule=`` / ``virtual_stages=``, see
``repro.dist.schedules``):

  * ``gpipe``        — reference: S ranks, one stage each, bubble S-1.
  * ``one_f_one_b``  — same forward order, in-flight capped at min(S, m).
  * ``interleaved``  — S = ranks x V stages, V chunks per rank; microbatches
    recirculate the ring V times and the bubble shrinks to ranks-1 ticks.

This executor is the *numerics reference*: it replicates the microbatch
array on every rank and autodiffs through the whole tick loop, so its own
peak memory does not depend on the schedule.  The schedule's
``in_flight`` / bubble numbers model what a production backward pass would
pay (the planner's ranking signal, ``repro.core.cost_model``), not this
reference's footprint.

The ``ppermute`` send is double-buffered: the tick-t+1 send is issued
directly off ``stage_fn``'s result, *before* that result is consumed by the
output capture, so XLA's async collective-permute (start/done) overlaps the
wire transfer with the capture/feed bookkeeping of the same tick.

When the mesh cannot host the pipeline (no pipeline axis, stage count not
hosted by the axis under the schedule, or batch not divisible by the
microbatch count) the sequential schedule runs instead — the same fallback
discipline as ``Rules``: an invalid plan must still compute.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.dist.compat import shard_map
from repro.dist.schedules import get_schedule


def sequential_apply(stage_fn, stage_params, x):
    """Reference schedule: fold x through the stacked stages one by one."""

    def body(h, w):
        return stage_fn(w, h), None

    h, _ = jax.lax.scan(body, x, stage_params)
    return h


def _n_stages(stage_params) -> int:
    return jax.tree.leaves(stage_params)[0].shape[0]


def pipeline_apply(stage_fn, stage_params, x, mesh, *, microbatches: int = 1,
                   axis: str = "pod", schedule: str = "gpipe",
                   virtual_stages: int = 1):
    """Run ``stage_params`` (leading dim = stages) as a pipeline over
    ``mesh.shape[axis]`` ranks; x [B, ...] with B % microbatches == 0.

    ``schedule`` picks the tick plan (gpipe | one_f_one_b | interleaved)
    and ``virtual_stages`` the chunks per rank (interleaved only; the stage
    count must equal ranks x virtual_stages).
    """
    n_stages = _n_stages(stage_params)
    batch = x.shape[0]
    sched = get_schedule(schedule)
    plan = None
    if sched is not None and axis in mesh.axis_names \
            and batch % microbatches == 0:
        plan = sched.build(n_stages=n_stages, n_ranks=mesh.shape[axis],
                           microbatches=microbatches,
                           virtual_stages=virtual_stages)
    if plan is None:
        return sequential_apply(stage_fn, stage_params, x)

    m, n_ranks, v = plan.microbatches, plan.n_ranks, plan.virtual_stages
    mb = x.reshape((m, batch // m) + x.shape[1:])
    # stage c*R + r lives on rank r as chunk c: [S, ...] -> [R, V, ...]
    ws = jax.tree.map(
        lambda a: jnp.swapaxes(a.reshape((v, n_ranks) + a.shape[1:]), 0, 1),
        stage_params)
    fwd = [(r, (r + 1) % n_ranks) for r in range(n_ranks)]

    def body(w_local, mb):
        # w_local [1, V, ...]: this rank's stage chunks; mb [m, b, ...]
        # replicated.
        rank = jax.lax.axis_index(axis)
        w_chunks = jax.tree.map(lambda a: a[0], w_local)
        zero = jnp.zeros_like(mb[0])
        carry = zero
        outs = jnp.zeros_like(mb)
        # recirculation buffer: rank 0 parks chunk outputs wrapping around
        # the ring until their next pass starts (interleaved only)
        buf = jnp.zeros_like(mb) if v > 1 else None
        for t, tick in enumerate(plan.ticks):
            if tick.stash_buf >= 0:
                buf = buf.at[tick.stash_buf].set(carry)
            if tick.feed_mb >= 0:
                feed = mb[tick.feed_mb]
            elif tick.feed_buf >= 0:
                feed = buf[tick.feed_buf]
            else:
                # bubble/drain tick: feed zeros, never real data — re-feeding
                # a real microbatch here would recompute it for nothing and
                # overcharge HLO-based roofline scores
                feed = zero
            x_in = jnp.where(rank == 0, feed, carry)
            if v > 1:
                # which chunk this rank runs follows from its entry tick
                c = jnp.clip((t - rank) // plan.entry_stride, 0, v - 1)
                w = jax.tree.map(lambda a: a[c], w_chunks)
            else:
                w = jax.tree.map(lambda a: a[0], w_chunks)
            y = stage_fn(w, x_in)
            # double-buffered send: issue the permute feeding tick t+1
            # before y is consumed by the capture below
            send = jax.lax.ppermute(y, axis, fwd)
            if tick.capture_out >= 0:
                outs = outs.at[tick.capture_out].set(
                    jnp.where(rank == n_ranks - 1, y, jnp.zeros_like(y)))
            carry = send
        return jax.lax.psum(outs, axis)

    out = shard_map(body, mesh=mesh,
                    in_specs=(PartitionSpec(axis), PartitionSpec()),
                    out_specs=PartitionSpec(), axis_names={axis},
                    check_vma=False)(ws, mb)
    return out.reshape(x.shape)
