"""Pipeline parallelism over the "pod" axis (GPipe-style, differentiable).

``pipeline_apply`` runs S stacked stages on S mesh ranks: each rank holds
one stage's params, microbatches flow rank-to-rank via ``ppermute``, and the
last rank's outputs are gathered with a masked psum.  Numerics match
``sequential_apply`` exactly (same ops, same order), and gradients flow to
every stage because ``ppermute`` transposes to the reverse permutation.

When the mesh cannot host the pipeline (no "pod" axis, or its size differs
from the number of stages) the sequential schedule runs instead — the same
fallback discipline as ``Rules``: an invalid plan must still compute.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map


def sequential_apply(stage_fn, stage_params, x):
    """Reference schedule: fold x through the stacked stages one by one."""

    def body(h, w):
        return stage_fn(w, h), None

    h, _ = jax.lax.scan(body, x, stage_params)
    return h


def pipeline_apply(stage_fn, stage_params, x, mesh, *, microbatches: int = 1,
                   axis: str = "pod"):
    """Run ``stage_params`` (leading dim = stages) as a pipeline over
    ``mesh.shape[axis]`` ranks; x [B, ...] with B % microbatches == 0."""
    n_stages = stage_params.shape[0]
    batch = x.shape[0]
    if (axis not in mesh.axis_names or mesh.shape[axis] != n_stages
            or batch % microbatches != 0):
        return sequential_apply(stage_fn, stage_params, x)
    m = microbatches
    mb = x.reshape((m, batch // m) + x.shape[1:])
    fwd = [(r, (r + 1) % n_stages) for r in range(n_stages)]

    def body(w_local, mb):
        # w_local [1, ...]: this rank's stage; mb [m, b, ...] replicated.
        rank = jax.lax.axis_index(axis)
        w = jax.tree.map(lambda a: a[0], w_local)
        carry = jnp.zeros_like(mb[0])
        outs = jnp.zeros_like(mb)
        # microbatch j enters rank 0 at tick j and leaves the last rank at
        # tick j + S - 1; in-flight bubbles compute garbage that is never
        # read back (masked out of both `outs` and the psum below)
        for t in range(m + n_stages - 1):
            feed = mb[min(t, m - 1)]
            x_in = jnp.where(rank == 0, feed, carry)
            y = stage_fn(w, x_in)
            j = t - (n_stages - 1)
            if 0 <= j < m:
                outs = outs.at[j].set(
                    jnp.where(rank == n_stages - 1, y, 0))
            carry = jax.lax.ppermute(y, axis, fwd)
        return jax.lax.psum(outs, axis)

    out = shard_map(body, mesh=mesh, in_specs=(P(axis), P()),
                    out_specs=P(), axis_names={axis},
                    check_vma=False)(stage_params, mb)
    return out.reshape(x.shape)
