"""Execution plans: the framework-side offload pattern the GA searches.

A :class:`Plan` bundles every knob that changes how one step function is
*executed* without changing what it computes — remat policy, microbatching,
gradient compression, attention blocking, MoE dispatch flavor, decode-cache
layout.  It is the framework analogue of the paper's per-loop gene string:
``GENE_SPACE`` lists the categorical genes, and ``from_genes`` /
``to_genes`` convert between a plan and the GA's integer encoding (see
``repro.core.ga`` and ``examples/autoplan_model.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple


@dataclass
class Plan:
    name: str = "default"
    # --- training-step execution -----------------------------------------
    remat: str = "block"                 # none | block | full
    microbatches: int = 1
    grad_compression: bool = False       # int8 + error feedback on "pod" psum
    vocab_chunk: int = 0                 # 0 = full-vocab xent
    opt_state_dtype: str = "float32"
    # --- pipeline (repro.dist.schedules over the "pod" axis) --------------
    pipeline_schedule: str = "gpipe"     # gpipe | one_f_one_b | interleaved
    virtual_stages: int = 1              # chunks per rank (interleaved only)
    # --- attention --------------------------------------------------------
    gqa_grouped: bool = True
    blockwise_attn_threshold: int = 1024  # seq >= threshold -> blockwise
    attn_block_q: int = 512
    attn_block_kv: int = 512
    # --- MoE --------------------------------------------------------------
    moe_impl: str = "gspmd"              # gspmd | shardmap_ep
    moe_capacity_factor: Optional[float] = None
    moe_groups: int = 1
    # --- SSM --------------------------------------------------------------
    ssd_chunk: int = 0
    ssd_bf16: bool = False
    # --- serving ----------------------------------------------------------
    kv_cache_quant: bool = False
    decode_kv_seq_shard: bool = False    # shard kv_seq (not kv_heads) on model

    # ------------------------------------------------------------- genes
    @classmethod
    def gene_cardinalities(cls) -> List[int]:
        return [len(choices) for _, choices in _GENE_SPACE]

    @classmethod
    def from_genes(cls, genes: Sequence[int], name: str = "ga-candidate"
                   ) -> "Plan":
        kw = {}
        for (field_name, choices), g in zip(_GENE_SPACE, genes):
            kw[field_name] = choices[int(g) % len(choices)]
        return cls(name=name, **kw)

    def to_genes(self) -> List[int]:
        genes = []
        for field_name, choices in _GENE_SPACE:
            v = getattr(self, field_name)
            genes.append(choices.index(v) if v in choices else 0)
        return genes


# Categorical gene space for the framework-side GA: (field, choices) pairs.
# Order is part of the public API: gene i of an individual indexes
# _GENE_SPACE[i][1].  Exposed as the plain class attribute Plan.GENE_SPACE
# (not a dataclass field, so dataclasses.asdict stays JSON-clean).
_GENE_SPACE: Tuple[Tuple[str, tuple], ...] = (
    ("remat", ("none", "block", "full")),
    ("microbatches", (1, 2, 4, 8)),
    ("grad_compression", (False, True)),
    ("vocab_chunk", (0, 512, 2048)),
    ("gqa_grouped", (True, False)),
    ("blockwise_attn_threshold", (512, 1024, 1 << 30)),
    ("attn_block_q", (256, 512)),
    ("attn_block_kv", (256, 512)),
    ("moe_impl", ("gspmd", "shardmap_ep")),
    ("decode_kv_seq_shard", (False, True)),
    ("pipeline_schedule", ("gpipe", "one_f_one_b", "interleaved")),
    ("virtual_stages", (1, 2)),
)

# make the class attribute readable without an instance too
Plan.GENE_SPACE = _GENE_SPACE


# --------------------------------------------------------------------------
# Named plans (referenced by --plan <name> in repro.launch.dryrun).
# --------------------------------------------------------------------------

TRAIN_TIGHT_MEM = Plan(name="train-tight-mem", remat="full", microbatches=4,
                       vocab_chunk=512)
CROSS_POD_COMPRESSED = Plan(name="cross-pod-compressed",
                            grad_compression=True)
SERVE_LOW_MEM = Plan(name="serve-low-mem", remat="none", kv_cache_quant=True,
                     decode_kv_seq_shard=True)
