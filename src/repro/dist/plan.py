"""Execution plans: the framework-side offload pattern the GA searches.

A :class:`Plan` bundles every knob that changes how one step function is
*executed* without changing what it computes — remat policy, microbatching,
gradient compression, attention blocking, MoE dispatch flavor, decode-cache
layout.  It is the framework analogue of the paper's per-loop gene string:
``GENE_SPACE`` lists the categorical genes, and ``from_genes`` /
``to_genes`` convert between a plan and the GA's integer encoding (see
``repro.core.ga`` and ``examples/autoplan_model.py``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, List, NamedTuple, Optional, Sequence, Tuple


class Gene(NamedTuple):
    """One ``GENE_SPACE`` entry.

    ``structural=False`` marks a *model-only* gene: flipping it never changes
    the lowered/compiled artifact, only the analytic cost model on top of it
    (the pipeline-schedule genes are scored via ``bubble_fraction``
    arithmetic — the verification machine never executes the pod pipeline).
    Everything structural participates in :meth:`Plan.structural_key`, the
    cache key ``repro.core.search_cache`` dedupes compiles by.
    """
    field: str
    choices: tuple
    structural: bool = True


@dataclass
class Plan:
    name: str = "default"
    # --- training-step execution -----------------------------------------
    remat: str = "block"                 # none | block | full
    microbatches: int = 1
    grad_compression: bool = False       # int8 + error feedback on "pod" psum
    vocab_chunk: int = 0                 # 0 = full-vocab xent
    opt_state_dtype: str = "float32"
    # --- pipeline (repro.dist.schedules over the "pod" axis) --------------
    pipeline_schedule: str = "gpipe"     # gpipe | one_f_one_b | interleaved
    virtual_stages: int = 1              # chunks per rank (interleaved only)
    # --- attention --------------------------------------------------------
    gqa_grouped: bool = True
    blockwise_attn_threshold: int = 1024  # seq >= threshold -> blockwise
    attn_block_q: int = 512
    attn_block_kv: int = 512
    # --- MoE --------------------------------------------------------------
    moe_impl: str = "gspmd"              # gspmd | shardmap_ep
    moe_capacity_factor: Optional[float] = None
    moe_groups: int = 1
    # --- SSM --------------------------------------------------------------
    ssd_chunk: int = 0
    ssd_bf16: bool = False
    # --- serving ----------------------------------------------------------
    kv_cache_quant: bool = False
    decode_kv_seq_shard: bool = False    # shard kv_seq (not kv_heads) on model

    # ------------------------------------------------------------- genes
    @classmethod
    def gene_cardinalities(cls) -> List[int]:
        return [len(g.choices) for g in _GENE_SPACE]

    @classmethod
    def from_genes(cls, genes: Sequence[int], name: str = "ga-candidate"
                   ) -> "Plan":
        kw = {}
        for gene, g in zip(_GENE_SPACE, genes):
            kw[gene.field] = gene.choices[int(g) % len(gene.choices)]
        return cls(name=name, **kw)

    def to_genes(self) -> List[int]:
        genes = []
        for gene in _GENE_SPACE:
            v = getattr(self, gene.field)
            genes.append(gene.choices.index(v) if v in gene.choices else 0)
        return genes

    def structural_key(self) -> Tuple[Tuple[str, Any], ...]:
        """Hashable identity of the *compiled artifact* this plan lowers to.

        Two plans with equal structural keys trace/lower/compile to the
        same executable: every dataclass field participates except ``name``
        (a label) and the model-only genes (``MODEL_ONLY_FIELDS`` — the
        pipeline-schedule genes, which only move the modeled bubble term).
        ``repro.core.search_cache`` keys its compile/analysis layers on this.
        """
        return tuple((f.name, getattr(self, f.name))
                     for f in dataclasses.fields(self)
                     if f.name != "name" and f.name not in MODEL_ONLY_FIELDS)


# Categorical gene space for the framework-side GA: Gene(field, choices,
# structural) triples.  Order is part of the public API: gene i of an
# individual indexes _GENE_SPACE[i].choices.  Exposed as the plain class
# attribute Plan.GENE_SPACE (not a dataclass field, so dataclasses.asdict
# stays JSON-clean).
#
# Structural/model-only contract: a gene is structural when flipping it
# changes the traced/lowered/compiled step; the pipeline-schedule genes are
# model-only — the compiled artifact stays the dp/tp step and the schedule
# is charged as a bubble_fraction on top (repro.core.cost_model), so the
# 3x2 schedule combinations per structural plan share one compile.
_GENE_SPACE: Tuple[Gene, ...] = (
    Gene("remat", ("none", "block", "full")),
    Gene("microbatches", (1, 2, 4, 8)),
    Gene("grad_compression", (False, True)),
    Gene("vocab_chunk", (0, 512, 2048)),
    Gene("gqa_grouped", (True, False)),
    Gene("blockwise_attn_threshold", (512, 1024, 1 << 30)),
    Gene("attn_block_q", (256, 512)),
    Gene("attn_block_kv", (256, 512)),
    Gene("moe_impl", ("gspmd", "shardmap_ep")),
    Gene("decode_kv_seq_shard", (False, True)),
    Gene("pipeline_schedule", ("gpipe", "one_f_one_b", "interleaved"),
         structural=False),
    Gene("virtual_stages", (1, 2), structural=False),
)

# plan fields that never reach the compiled artifact (scored analytically)
MODEL_ONLY_FIELDS = frozenset(g.field for g in _GENE_SPACE
                              if not g.structural)

# make the class attribute readable without an instance too
Plan.GENE_SPACE = _GENE_SPACE


# --------------------------------------------------------------------------
# Named plans (referenced by --plan <name> in repro.launch.dryrun).
# --------------------------------------------------------------------------

TRAIN_TIGHT_MEM = Plan(name="train-tight-mem", remat="full", microbatches=4,
                       vocab_chunk=512)
CROSS_POD_COMPRESSED = Plan(name="cross-pod-compressed",
                            grad_compression=True)
SERVE_LOW_MEM = Plan(name="serve-low-mem", remat="none", kv_cache_quant=True,
                     decode_kv_seq_shard=True)

NAMED_PLANS = {p.name: p for p in (TRAIN_TIGHT_MEM, CROSS_POD_COMPRESSED,
                                   SERVE_LOW_MEM)}

# Documented deployment context per named plan: the mesh kind and shape
# cells the plan is designed for.  ``repro.analysis.lint`` audits each named
# plan against exactly this context (a plan the linter proves infeasible on
# its documented mesh is a bug in the plan, not a waivable finding):
#   * train-tight-mem     — a training plan; grad accumulation + full remat
#     target the multi-pod training footprint.
#   * cross-pod-compressed — compresses the cross-pod grad psum, so it only
#     means anything on the multi-pod mesh.
#   * serve-low-mem       — a decode plan for the single-pod serving mesh
#     (long_500k applies only to sub-quadratic archs, see cell_runnable).
PLAN_CONTEXTS = {
    "train-tight-mem": {"mesh": "multi", "shapes": ("train_4k",)},
    "cross-pod-compressed": {"mesh": "multi", "shapes": ("train_4k",)},
    "serve-low-mem": {"mesh": "single",
                      "shapes": ("decode_32k", "long_500k")},
}
