"""Logical-axis sharding rules: map model-side axis names to mesh axes.

Every layer in ``repro.models`` annotates its params and activations with
*logical* axes (``"embed"``, ``"heads"``, ``"batch"`` ...).  :class:`Rules`
turns a logical-axes tuple into a :class:`~jax.sharding.PartitionSpec` for a
concrete mesh, with two safety fallbacks the GA relies on (an invalid plan
must lower, not crash):

  * divisibility — a dimension is sharded over the largest prefix of its
    assigned mesh axes whose total size divides it (fully replicated only
    when not even the first axis divides);
  * duplicate axes — a mesh axis already used earlier in the same spec is
    skipped (e.g. with ``Plan.decode_kv_seq_shard`` the ``kv_seq`` axis
    claims "model" and ``kv_heads`` falls back to replicated).

``NullRules`` is the single-process no-op used when there is no mesh.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec

# logical axis -> mesh axes.  A tuple value shards one dimension over
# several mesh axes (and stays a tuple inside the PartitionSpec); a string
# value is a single mesh axis.  "batch"/"embed" ride the data-class axes
# (embed sharding over "data" is the FSDP-style parameter shard); the
# model-class axes carry heads / ff / experts / vocab (tensor parallel).
BASE_RULES = {
    "batch": ("pod", "data"),
    "embed": ("data",),
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "lru": "model",
    "vocab": "model",
    "experts": "model",
}


def batch_axes(mesh) -> Tuple[str, ...]:
    """The mesh axes that carry the batch dimension, in batch order."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


class Rules:
    """Sharding rules for one (mesh, plan) pair.

    ``exclude_axes`` removes mesh axes from every rule — used inside a
    ``shard_map`` where those axes are Manual and the inner (Auto) sharding
    constraints must not reference them (``train_step.py`` excludes "pod").
    """

    def __init__(self, mesh, plan=None, exclude_axes: Sequence[str] = ()):
        self.mesh = mesh
        self.plan = plan
        self.exclude_axes = tuple(exclude_axes)
        self.rules = dict(BASE_RULES)
        if plan is not None and getattr(plan, "decode_kv_seq_shard", False):
            self.rules["kv_seq"] = "model"

    # ------------------------------------------------------------------
    def _assign(self, logical: Optional[str], dim: Optional[int],
                used: set):
        """Mesh-axis entry for one dimension (None = replicated)."""
        if logical is None:
            return None
        rule = self.rules.get(logical)
        if rule is None:
            return None
        as_tuple = isinstance(rule, tuple)
        candidates = rule if as_tuple else (rule,)
        axes = tuple(a for a in candidates
                     if a in self.mesh.axis_names
                     and a not in self.exclude_axes
                     and a not in used)
        if not axes:
            return None
        if dim is not None:
            # shard over the largest prefix of the remaining axes whose
            # total size divides the dimension — "batch % (pod*data) != 0"
            # must degrade to sharding over "pod", not all the way to
            # replicated
            size, take = 1, 0
            for a in axes:
                if dim % (size * self.mesh.shape[a]) != 0:
                    break
                size *= self.mesh.shape[a]
                take += 1
            axes = axes[:take]
            if not axes:
                return None                  # replicate: nothing divides
        used.update(axes)
        if as_tuple:
            return axes
        return axes[0]

    def spec(self, axes: Optional[Sequence[Optional[str]]],
             dims: Optional[Sequence[int]] = None) -> PartitionSpec:
        """PartitionSpec for a logical-axes tuple (trailing Nones trimmed).

        ``dims`` (the concrete shape) enables the divisibility fallback;
        without it the rules are applied unconditionally.
        """
        entries = []
        used: set = set()
        for i, logical in enumerate(tuple(axes or ())):
            dim = None if dims is None else dims[i]
            entries.append(self._assign(logical, dim, used))
        while entries and entries[-1] is None:
            entries.pop()
        return PartitionSpec(*entries)

    def sharding(self, axes, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, dims=shape))

    def constrain(self, x, axes):
        """``with_sharding_constraint`` x to its logical axes."""
        return jax.lax.with_sharding_constraint(
            x, self.sharding(axes, getattr(x, "shape", None)))


class NullRules:
    """No-mesh rules: every operation is the identity / fully replicated."""

    mesh = None
    plan = None

    def spec(self, axes, dims=None) -> PartitionSpec:
        return PartitionSpec()

    def sharding(self, axes, shape=None):
        return None

    def constrain(self, x, axes):
        return x


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)


def tree_shardings(rules: Rules, axes_tree, tree_sds):
    """Pytree of NamedShardings from a logical-axes tree + matching
    ShapeDtypeStruct (or array) tree.

    ``axes_tree`` mirrors the value tree with tuples of logical axis names
    as leaves (the ``*_axes`` helpers in ``repro.models``); ``()`` marks a
    scalar leaf.
    """
    return jax.tree.map(
        lambda ax, sds: rules.sharding(ax, getattr(sds, "shape", None)),
        axes_tree, tree_sds, is_leaf=_is_axes_leaf)
