"""JAX version shims.

The repo targets the current JAX sharding API (``jax.shard_map`` with
``axis_names`` / ``check_vma``, ``jax.make_mesh(..., axis_types=...)``,
``jax.sharding.AxisType``); the pinned runtime may predate those.  Every
call site goes through this module so exactly one place knows both idioms.
"""
from __future__ import annotations

import contextlib
import enum

import jax
import numpy as np

try:                                        # jax >= 0.5.1
    from jax.sharding import AxisType as AxisType    # re-exported
    _HAS_AXIS_TYPES = True
except ImportError:
    _HAS_AXIS_TYPES = False

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes, axis_names, axis_types=None, devices=None):
    """``jax.make_mesh`` with ``axis_types`` where supported.

    Older JAX either rejects the kwarg or (0.4.x) expects a different
    dict-style value, so it is only forwarded when the new-API enum exists;
    Auto is the default there anyway.
    """
    if axis_types is not None and _HAS_AXIS_TYPES:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=axis_types, devices=devices)
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def mesh_from_devices(devices, shape, axis_names, axis_types=None):
    """Mesh over an explicit device list reshaped to ``shape``."""
    arr = np.asarray(devices).reshape(shape)
    if axis_types is not None and _HAS_AXIS_TYPES:
        try:
            return jax.sharding.Mesh(arr, axis_names, axis_types=axis_types)
        except TypeError:
            pass
    return jax.sharding.Mesh(arr, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=False):
    """New-style ``jax.shard_map`` signature on any supported JAX.

    ``axis_names`` is the set of *manual* axes (new-API semantics).  On older
    JAX it is translated to the complementary ``auto`` set, and ``check_vma``
    to ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    # Old XLA hard-crashes when nontrivial computations sit in a
    # partially-manual region (hlo_sharding_util IsManualSubgroup check),
    # so the fallback runs fully manual: axes the specs never mention are
    # replicated, which preserves numerics at the cost of redundant
    # within-group compute.  New JAX keeps the real partial-manual path.
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma), auto=frozenset())


# Can with_sharding_constraint reference Auto mesh axes from inside a
# partially-manual shard_map region?  Old XLA hard-crashes on it
# (hlo_sharding_util Check failure), so callers that nest Rules.constrain
# under a shard_map must drop to NullRules when this is False.
PARTIAL_MANUAL_CONSTRAINTS = hasattr(jax, "shard_map")


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on any JAX (older
    versions return a one-element list of per-device dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


@contextlib.contextmanager
def set_mesh(mesh):
    """``with set_mesh(m):`` — activates ``m`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        # capture the enclosing mesh BEFORE the call mutates the ambient
        # state (jax.set_mesh sets immediately even when it also returns a
        # context manager)
        prev = getattr(jax.sharding, "get_abstract_mesh", lambda: None)()
        ctx = jax.set_mesh(mesh)
        if hasattr(ctx, "__enter__"):
            with ctx:
                yield mesh
        else:                               # set_mesh is a plain setter
            try:
                yield mesh
            finally:
                # restore the enclosing mesh (prev=None resets to no-mesh;
                # a loud failure here beats silently leaking `mesh` into
                # every subsequent trace)
                jax.set_mesh(prev)
        return
    with mesh:
        yield mesh
