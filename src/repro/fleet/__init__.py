"""repro.fleet — many apps, one shared destination pool, one power cap.

Public surface (stable — see ROADMAP "repro.fleet"):

  * :class:`FleetApp` / :class:`PoolBackend` — the placement problem's
    two sides (offered load + working set vs. slots + memory + envelope).
  * :class:`FleetPlanner` — ``plan(apps)`` searches assignment vectors
    with the paper's GA (greedy bin-packing seed), scored entirely from
    warm :class:`~repro.core.plan_lookup.PlanLookup` payloads through
    the :class:`~repro.core.candidates.Candidate` contract — zero new
    compiles; ``replan(apps, placement, failed_backend)`` degrades
    around a dead backend, keeping unaffected apps pinned.
  * :class:`Placement` — the evaluated result (feasibility, violations,
    fleet draw, joules-per-request).
  * :func:`round_robin` — the static capacity-blind baseline.
  * :func:`observed_apps` — fold observed per-arch load (from
    :class:`~repro.serve.ServeMetrics`) back into the app estimates; the
    read side of the control loop's plan→serve→observe→replan cycle.
"""
from repro.fleet.placement import (FleetApp, FleetPlanner, Placement,
                                   PoolBackend, observed_apps, round_robin)

__all__ = ["FleetApp", "PoolBackend", "FleetPlanner", "Placement",
           "round_robin", "observed_apps"]
