"""Fleet placement: N applications across one shared destination pool.

The source paper places one application on one destination; the
mixed-destination study (arXiv 2010.08009) and the power follow-up (arXiv
2110.11520) frame the operator's real problem as many applications sharing
one heterogeneous pool under a datacenter power cap.  This module is that
planner:

  * the **genome** is the assignment vector — one gene per app, whose
    value is an index into the backend pool (searched by the same
    ``run_ga`` the offload planner uses, with a greedy bin-packing seed
    so the GA starts from a feasible solution instead of rediscovering
    one);
  * every (app, backend) pair is scored **entirely from warm state**: the
    :class:`~repro.core.plan_lookup.PlanLookup` payload that
    ``plan_offload(..., publish=lookup)`` published, lifted through
    :meth:`Candidate.from_analysis
    <repro.core.candidates.Candidate.from_analysis>` — roofline
    arithmetic plus an :class:`~repro.power.EnergyModel` charge, zero new
    traces or compiles (pinned by a jit-poisoned test, like the router's);
  * a published verification **failure** makes the pair infeasible — the
    planner can never place an app on a destination the verification
    environment proved wrong;
  * **capacity** is enforced per backend (slot-equivalents of offered
    load, resident memory bytes) and globally (``power_budget_w`` over the
    summed utilization-weighted draw — :func:`repro.power.fleet_draw_w`,
    the same summation the Router's admission headroom uses);
  * :meth:`FleetPlanner.replan` is the fault path: when a backend drops,
    apps placed elsewhere stay pinned and only the displaced apps are
    re-placed (greedy first, full GA re-plan when greedy cannot fit
    them) — the placement-level analogue of
    ``repro.runtime.fault_tolerance``'s degrade-and-continue contract.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.backends import get_policy
from repro.core.candidates import Candidate
from repro.core.ga import Evaluation, GAConfig, run_ga
from repro.core.plan_lookup import PlanLookup, serve_key
from repro.obs import get_tracer
from repro.power import fleet_draw_w


@dataclass(frozen=True)
class FleetApp:
    """One application to place: its offered load and working-set size."""
    name: str
    arch: str                       # lookup identity (the app/model name)
    load_rps: float = 1.0           # offered requests per second
    tokens_per_request: float = 32.0  # decode steps per request (scale)
    memory_bytes: float = 0.0       # resident bytes while placed
    plan: object = None             # optional serving Plan (folds into key)


@dataclass(frozen=True)
class PoolBackend:
    """One pooled destination: a backend's machine with fixed capacity."""
    name: str
    backend: object                 # repro.backends.Backend (duck-typed)
    n_chips: int = 1
    slots: float = 4.0              # slot-equivalents of concurrent load
    memory_bytes: float = float("inf")

    def lookup_key(self, app: FleetApp):
        return serve_key(getattr(self.backend, "name", self.name),
                         app.arch, app.plan)


@dataclass
class Placement:
    """One evaluated assignment of every app to a pool backend."""
    assignment: Tuple[int, ...]             # app index -> pool index
    by_app: Dict[str, str]                  # app name -> backend name
    feasible: bool
    objective: float                        # policy score, load-weighted
    fleet_draw_w: float                     # summed utilization-weighted W
    joules_per_request: float               # load-weighted mean energy_j
    violations: List[str] = field(default_factory=list)
    candidates: Dict[str, Candidate] = field(default_factory=dict)
    info: Dict = field(default_factory=dict)


class FleetPlanner:
    """Assign apps to pooled backends from warm lookup state only.

    ``policy`` ranks each (app, backend) Candidate exactly as every other
    selection site does; the placement objective is the load-weighted sum
    of the policy's per-app scores (for the ``power`` policy that is
    joules/request x requests/s = fleet watts).
    """

    def __init__(self, pool: Sequence[PoolBackend], lookup: PlanLookup, *,
                 policy=None, power_budget_w: Optional[float] = None,
                 ga_cfg: Optional[GAConfig] = None):
        if not pool:
            raise ValueError("fleet planner needs at least one backend")
        names = [b.name for b in pool]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pool backend names: {names}")
        self.pool = list(pool)
        self.lookup = lookup
        self.policy = get_policy(policy)
        self.power_budget_w = power_budget_w
        self.ga_cfg = ga_cfg
        self._cand_cache: Dict[Tuple, Optional[Candidate]] = {}

    # ------------------------------------------------------------ scoring
    def candidate(self, app: FleetApp,
                  pb: PoolBackend) -> Optional[Candidate]:
        """The warm Candidate for placing ``app`` on ``pb``, or None when
        the pair is unplaceable (cold lookup or a published verification
        failure).  Pure arithmetic — memoized per (app, backend)."""
        key = (app.name, pb.name)
        if key not in self._cand_cache:
            payload = self.lookup.lookup(pb.lookup_key(app))
            if not self.lookup.usable(payload):
                self._cand_cache[key] = None
            else:
                self._cand_cache[key] = Candidate.from_analysis(
                    payload["analysis"], backend=pb.backend, arch=app.arch,
                    n_chips=pb.n_chips, scale=app.tokens_per_request,
                    plan_key=app.plan.structural_key()
                    if app.plan is not None else None,
                    ref=(app, pb))
        return self._cand_cache[key]

    @staticmethod
    def _utilization(app: FleetApp, cand: Candidate) -> float:
        """Slot-equivalents of offered load: requests/s x service seconds
        (>1 means the app alone needs more than one slot's worth)."""
        return app.load_rps * cand.best_time_s

    @staticmethod
    def _draw_w(app: FleetApp, cand: Candidate) -> Optional[float]:
        """Utilization-weighted modeled draw: the backend serves this app
        for ``min(u, slots)`` slot-equivalents, each at ``avg_watts``."""
        if cand.avg_watts is None:
            return None
        return cand.avg_watts * min(
            FleetPlanner._utilization(app, cand), 1.0)

    # --------------------------------------------------------- evaluation
    def evaluate(self, apps: Sequence[FleetApp],
                 genes: Tuple[int, ...],
                 usable: Optional[Sequence[bool]] = None) -> Placement:
        """Score one assignment vector.  Infeasibility (unplaceable pair,
        slot/memory overflow, power-cap breach, masked backend) is recorded
        in ``violations`` — the GA sees it as an incorrect individual."""
        violations: List[str] = []
        cands: Dict[str, Candidate] = {}
        by_app: Dict[str, str] = {}
        slot_load: Dict[str, float] = {b.name: 0.0 for b in self.pool}
        mem_load: Dict[str, float] = {b.name: 0.0 for b in self.pool}
        draws: List[Optional[float]] = []
        objective = 0.0
        joules = 0.0
        load = 0.0
        for i, app in enumerate(apps):
            pb = self.pool[genes[i]]
            by_app[app.name] = pb.name
            if usable is not None and not usable[genes[i]]:
                violations.append(f"{app.name}: backend {pb.name} is down")
                continue
            cand = self.candidate(app, pb)
            if cand is None:
                violations.append(
                    f"{app.name}: no warm verified plan on {pb.name} "
                    f"(cold or published failure)")
                continue
            cands[app.name] = cand
            slot_load[pb.name] += self._utilization(app, cand)
            mem_load[pb.name] += app.memory_bytes
            draws.append(self._draw_w(app, cand))
            objective += app.load_rps * self.policy.score_candidate(cand)
            if cand.energy_j is not None:
                joules += app.load_rps * cand.energy_j
            load += app.load_rps
        for pb in self.pool:
            if slot_load[pb.name] > pb.slots + 1e-9:
                violations.append(
                    f"{pb.name}: offered load {slot_load[pb.name]:.2f} "
                    f"slot-equivalents > {pb.slots:g} slots")
            if mem_load[pb.name] > pb.memory_bytes:
                violations.append(
                    f"{pb.name}: resident {mem_load[pb.name]:.3g} B "
                    f"> {pb.memory_bytes:.3g} B")
        draw = fleet_draw_w(draws)
        if self.power_budget_w is not None and draw > self.power_budget_w:
            violations.append(f"fleet draw {draw:.1f} W > budget "
                              f"{self.power_budget_w:g} W")
        return Placement(
            assignment=tuple(genes), by_app=by_app,
            feasible=not violations, objective=objective,
            fleet_draw_w=draw,
            joules_per_request=joules / load if load > 0 else 0.0,
            violations=violations, candidates=cands,
            info={"slot_load": slot_load, "mem_load": mem_load})

    # ------------------------------------------------------------- greedy
    def greedy(self, apps: Sequence[FleetApp],
               usable: Optional[Sequence[bool]] = None,
               pinned: Optional[Dict[int, int]] = None
               ) -> Optional[Tuple[int, ...]]:
        """Greedy bin-packing seed: biggest apps first (by offered work),
        each onto the best-scoring backend that still fits it.  ``pinned``
        maps app index -> pool index for apps that must stay put (the
        replan path).  Returns None when some app fits nowhere."""
        pinned = pinned or {}
        genes: Dict[int, int] = dict(pinned)
        slot_left = {b.name: b.slots for b in self.pool}
        mem_left = {b.name: b.memory_bytes for b in self.pool}
        draw = 0.0
        order: List[Tuple[float, int]] = []
        for i, app in enumerate(apps):
            work = [self._utilization(app, c)
                    for c in (self.candidate(app, b) for b in self.pool)
                    if c is not None]
            order.append((max(work) if work else 0.0, i))

        def commit(i: int, j: int) -> bool:
            nonlocal draw
            app, pb = apps[i], self.pool[j]
            cand = self.candidate(app, pb)
            if cand is None:
                return False
            u = self._utilization(app, cand)
            if u > slot_left[pb.name] + 1e-9:
                return False
            if app.memory_bytes > mem_left[pb.name]:
                return False
            d = self._draw_w(app, cand) or 0.0
            if self.power_budget_w is not None \
                    and draw + d > self.power_budget_w:
                return False
            slot_left[pb.name] -= u
            mem_left[pb.name] -= app.memory_bytes
            draw += d
            return True

        for i, j in pinned.items():
            if not commit(i, j):
                return None
        for _, i in sorted(order, reverse=True):
            if i in genes:
                continue
            choices = []
            for j, pb in enumerate(self.pool):
                if usable is not None and not usable[j]:
                    continue
                cand = self.candidate(apps[i], pb)
                if cand is None:
                    continue
                choices.append((self.policy.score_candidate(cand), j))
            placed = False
            for _, j in sorted(choices):
                if commit(i, j):
                    genes[i] = j
                    placed = True
                    break
            if not placed:
                return None
        return tuple(genes[i] for i in range(len(apps)))

    # --------------------------------------------------------------- plan
    def plan(self, apps: Sequence[FleetApp],
             usable: Optional[Sequence[bool]] = None) -> Placement:
        """Place every app: GA over assignment vectors, seeded with the
        greedy solution.  Zero compiles — every fitness call is lookup +
        roofline arithmetic."""
        if not apps:
            raise ValueError("nothing to place")
        with get_tracer().span("plan", cat="fleet", track="fleet",
                               n_apps=len(apps),
                               n_pool=len(self.pool)) as span:
            seed = self.greedy(apps, usable=usable)
            import dataclasses
            cfg = self.ga_cfg or GAConfig.for_gene_length(max(len(apps), 2))
            # the genome is always one pool index per app — the planner
            # owns the cardinalities whatever the caller's cfg says
            cfg = dataclasses.replace(
                cfg, cardinalities=[len(self.pool)] * len(apps))

            def fitness(genes: Tuple[int, ...]) -> Evaluation:
                p = self.evaluate(apps, genes, usable=usable)
                if not p.feasible:
                    return Evaluation(time_s=cfg.penalty_s, correct=False,
                                      info={"violations": p.violations})
                return Evaluation(time_s=max(p.objective, 1e-12),
                                  correct=True, info={"placement": p})

            res = run_ga(len(apps), fitness, cfg,
                         seed_population=[seed] if seed is not None
                         else None)
            best = self.evaluate(apps, res.best_genes, usable=usable)
            best.info["ga"] = {"n_measurements": res.n_measurements,
                               "generations": len(res.history)}
            if seed is not None:
                greedy_p = self.evaluate(apps, seed, usable=usable)
                best.info["greedy"] = {"assignment": seed,
                                       "objective": greedy_p.objective}
            span.set(feasible=best.feasible, objective=best.objective,
                     fleet_draw_w=best.fleet_draw_w,
                     by_app=dict(best.by_app))
        return best

    # ------------------------------------------------------------- replan
    def replan(self, apps: Sequence[FleetApp], placement: Placement,
               failed_backend: str) -> Placement:
        """Degrade-and-continue after ``failed_backend`` drops: apps placed
        elsewhere stay pinned, the displaced apps are greedily re-placed
        over the surviving pool; when greedy cannot fit them the whole
        fleet is re-planned (GA) over the surviving backends.  Mirrors
        ``repro.runtime.fault_tolerance``: shrink, keep serving, never
        hand back a placement that uses the dead destination."""
        idx = {b.name: j for j, b in enumerate(self.pool)}
        if failed_backend not in idx:
            raise ValueError(f"unknown backend {failed_backend!r}")
        with get_tracer().span("replan", cat="fleet", track="fleet",
                               failed=failed_backend,
                               n_apps=len(apps)) as span:
            usable = [b.name != failed_backend for b in self.pool]
            pinned = {i: placement.assignment[i]
                      for i, app in enumerate(apps)
                      if placement.by_app.get(app.name) != failed_backend}
            seed = self.greedy(apps, usable=usable, pinned=pinned)
            if seed is not None:
                out = self.evaluate(apps, seed, usable=usable)
                if out.feasible:
                    out.info["replan"] = {"mode": "pinned-greedy",
                                          "failed": failed_backend}
                    span.set(mode="pinned-greedy", feasible=True,
                             by_app=dict(out.by_app))
                    return out
            out = self.plan(apps, usable=usable)
            out.info["replan"] = {"mode": "full", "failed": failed_backend}
            span.set(mode="full", feasible=out.feasible,
                     by_app=dict(out.by_app))
        return out


def observed_apps(apps: Sequence[FleetApp],
                  loads: Dict[str, float]) -> List[FleetApp]:
    """Fold observed per-arch load back into the fleet's app estimates:
    each app whose ``arch`` appears in ``loads`` gets the observed
    requests/s, split evenly across the apps sharing that arch (the
    router does not attribute requests to apps, only to archs).  Apps
    with no observation keep their declared estimate — the controller's
    plan→serve→observe→replan loop calls this before every replan."""
    import dataclasses
    share: Dict[str, int] = {}
    for app in apps:
        share[app.arch] = share.get(app.arch, 0) + 1
    out: List[FleetApp] = []
    for app in apps:
        if app.arch in loads:
            out.append(dataclasses.replace(
                app, load_rps=loads[app.arch] / share[app.arch]))
        else:
            out.append(app)
    return out


def round_robin(apps: Sequence[FleetApp],
                pool: Sequence[PoolBackend]) -> Tuple[int, ...]:
    """The static baseline the benchmark compares against: app i on
    backend i mod P, capacity- and verdict-blind."""
    return tuple(i % len(pool) for i in range(len(apps)))
