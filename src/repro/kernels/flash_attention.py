"""Blockwise (flash) attention Pallas kernel — FB replacement for the
softmax(QK^T)V block (causal, GQA via pre-grouped heads).

Grid (B*H, Sq/bq, Skv/bkv); kv is the innermost grid dim so the running
(max, denom, acc) scratch persists across kv steps for one q tile
(online-softmax).  Causal masking is positional; fully-masked tiles still
execute (Pallas TPU grids are dense) but contribute zeros.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_kv: int, block_q: int, block_kv: int, causal: bool,
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                  # [bq, d]
    k = k_ref[0]                                  # [bkv, d]
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = qi * block_q + jnp.arange(block_q)
        kpos = ki * block_kv + jnp.arange(block_kv)
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    if causal:
        p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] \
        + jnp.dot(p.astype(v.dtype), v,
                  preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 512,
                    block_kv: int = 512, interpret: bool = True
                    ) -> jax.Array:
    """q [BH, Sq, D], k/v [BH, Skv, D] (heads pre-flattened/grouped)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    bq, bkv = min(block_q, sq), min(block_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (sq, bq, skv, bkv)
    gq, gkv = sq // bq, skv // bkv
    scale = 1.0 / math.sqrt(d)

    return pl.pallas_call(
        functools.partial(_flash_kernel, n_kv=gkv, block_q=bq, block_kv=bkv,
                          causal=causal, scale=scale),
        grid=(bh, gq, gkv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
