"""jit'd wrappers for the Pallas kernels + the interpret/compiled switch.

``mode``: "off" (pure-jnp reference path), "interpret" (Pallas interpreter —
the CPU-validated path used everywhere in this container), "compiled" (real
TPU lowering; flip on hardware).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import matmul as _mm
from repro.kernels import tdfir as _fir
from repro.kernels import ref


def _interpret(mode: str) -> bool:
    if mode == "compiled":
        return False
    return True


@functools.partial(jax.jit, static_argnames=("mode", "block_m", "block_n",
                                             "block_k"))
def matmul(a, b, mode: str = "interpret", block_m: int = 128,
           block_n: int = 128, block_k: int = 128):
    if mode == "off":
        return ref.matmul_ref(a, b)
    return _mm.matmul(a, b, block_m=block_m, block_n=block_n,
                      block_k=block_k, interpret=_interpret(mode))


@functools.partial(jax.jit, static_argnames=("mode", "block_n"))
def tdfir(x, h, mode: str = "interpret", block_n: int = 512):
    if mode == "off":
        return ref.tdfir_ref(x, h)
    return _fir.tdfir(x, h, block_n=block_n, interpret=_interpret(mode))


@functools.partial(jax.jit, static_argnames=("mode", "causal", "block_q",
                                             "block_kv"))
def flash_attention(q, k, v, mode: str = "interpret", causal: bool = True,
                    block_q: int = 512, block_kv: int = 512):
    if mode == "off":
        return ref.mha_ref(q, k, v, causal=causal)
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_kv=block_kv,
                               interpret=_interpret(mode))
