"""Split-K decode attention Pallas kernel (FlashDecoding-style).

One query token attends to a long KV cache; the cache's sequence dim is
split across the innermost grid dim so each step reduces one KV tile with
an online-softmax carry in VMEM (same recurrence as flash_attention but
q_len == 1, so the whole accumulator is a [1, D] vector) — the kernel
analogue of the sequence-sharded decode path in ``repro.models.layers``.

On hardware this grid dim maps to parallel split-K partials combined by a
final logsumexp merge; in interpret mode the sequential reduction gives the
same numerics.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, n_kv: int, block_kv: int, scale: float):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                    # [1, d]
    k = k_ref[0]                                    # [bkv, d]
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)[0] * scale
    kpos = ki * block_kv + jnp.arange(block_kv)
    valid = kpos < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[0] = l_ref[0] * corr + p.sum()
    acc_ref[...] = acc_ref[...] * corr \
        + jnp.dot(p[None].astype(v.dtype), v,
                  preferred_element_type=jnp.float32)
    m_ref[0] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        denom = jnp.maximum(l_ref[0], 1e-20)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)[0]


def decode_attention(q, k_cache, v_cache, cache_len, *, block_kv: int = 512,
                     interpret: bool = True) -> jax.Array:
    """q [BH, D]; k/v_cache [BH, S, D]; cache_len scalar int32 -> [BH, D]."""
    bh, d = q.shape
    s = k_cache.shape[1]
    bkv = min(block_kv, s)
    assert s % bkv == 0, (s, bkv)
    gkv = s // bkv
    scale = 1.0 / math.sqrt(d)
    lens = jnp.full((bh, 1), cache_len, jnp.int32)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, n_kv=gkv, block_kv=bkv,
                          scale=scale),
        grid=(bh, gkv),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, j: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(q[:, None, :], k_cache, v_cache, lens)
    return out


def decode_attention_ref(q, k_cache, v_cache, cache_len) -> jax.Array:
    """Pure-jnp oracle. q [BH, D]; caches [BH, S, D]."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bd,bkd->bk", q, k_cache).astype(jnp.float32) * scale
    valid = jnp.arange(k_cache.shape[1]) < cache_len
    s = jnp.where(valid[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bk,bkd->bd", p.astype(q.dtype), v_cache)
