"""Time-domain FIR Pallas kernel — the paper's tdFIR function-block offload
target (HPEC Challenge; Intel FPGA OpenCL sample analogue).

y[f, n] = sum_k h[f, k] * x[f, n - k]   (causal, per-filter bank)

TPU adaptation of the FPGA systolic FIR: grid (F, N/bn); each step loads the
current x block plus the *previous* block (same input bound twice with
shifted index_maps — the Pallas idiom for overlapping windows), forms the
K-1-deep sliding history in VMEM, and accumulates the tap loop on the VPU.
Complex data is handled as planar re/im (MXU/VPU have no complex type).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tdfir_kernel(xprev_ref, xcur_ref, h_ref, o_ref, *, n_taps: int,
                  block_n: int):
    j = pl.program_id(1)
    xfull = jnp.concatenate([xprev_ref[0], xcur_ref[0]])   # [2*bn]
    # zero history before the signal start (block 0's "previous" block
    # aliases block 0 itself; mask it off)
    idx = jnp.arange(2 * block_n)
    xfull = jnp.where((j == 0) & (idx < block_n), 0.0, xfull)
    h = h_ref[0]                                            # [n_taps]

    def tap(k, acc):
        # y[n] += h[k] * x[n-k]  ->  slice starting at bn-k
        seg = jax.lax.dynamic_slice(xfull, (block_n - k,), (block_n,))
        return acc + h[k] * seg

    acc = jax.lax.fori_loop(0, n_taps, tap,
                            jnp.zeros((block_n,), jnp.float32))
    o_ref[0] = acc.astype(o_ref.dtype)


def tdfir(x: jax.Array, h: jax.Array, *, block_n: int = 512,
          interpret: bool = True) -> jax.Array:
    """x [F, N] float32, h [F, K] float32 -> y [F, N] (causal FIR)."""
    f, n = x.shape
    f2, k = h.shape
    assert f == f2
    bn = min(block_n, n)
    assert bn >= k, f"block_n {bn} must cover the {k} taps"
    pn = (-n) % bn
    if pn:
        x = jnp.pad(x, ((0, 0), (0, pn)))
    gn = x.shape[1] // bn
    hp = jnp.pad(h, ((0, 0), (0, bn - k))) if k < bn else h

    out = pl.pallas_call(
        functools.partial(_tdfir_kernel, n_taps=k, block_n=bn),
        grid=(f, gn),
        in_specs=[
            # previous block (clamped at the left edge; masked in-kernel)
            pl.BlockSpec((1, bn), lambda i, j: (i, jnp.maximum(j - 1, 0))),
            pl.BlockSpec((1, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, x, hp)
    return out[:, :n]


def tdfir_complex(x_re, x_im, h_re, h_im, **kw):
    """Complex FIR via 4 real FIRs (planar layout)."""
    rr = tdfir(x_re, h_re, **kw)
    ii = tdfir(x_im, h_im, **kw)
    ri = tdfir(x_re, h_im, **kw)
    ir = tdfir(x_im, h_re, **kw)
    return rr - ii, ri + ir
