"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32),
                   b.astype(jnp.float32)).astype(a.dtype)


def tdfir_ref(x: jax.Array, h: jax.Array) -> jax.Array:
    """Causal per-filter FIR: y[f,n] = sum_k h[f,k] x[f,n-k]."""
    f, n = x.shape
    k = h.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0)))
    # y[f, n] = sum_k h[f, k] * xp[f, n + (k-1) - k]
    def tap(kk, acc):
        seg = jax.lax.dynamic_slice(xp, (0, k - 1 - kk), (f, n))
        hk = jax.lax.dynamic_slice(h, (0, kk), (f, 1))
        return acc + hk * seg
    y = jax.lax.fori_loop(0, k, tap, jnp.zeros_like(x, jnp.float32))
    return y.astype(x.dtype)


def tdfir_complex_ref(x_re, x_im, h_re, h_im):
    rr = tdfir_ref(x_re, h_re)
    ii = tdfir_ref(x_im, h_im)
    ri = tdfir_ref(x_re, h_im)
    ir = tdfir_ref(x_im, h_re)
    return rr - ii, ri + ir


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
            causal: bool = True) -> jax.Array:
    """q [BH, Sq, D], k/v [BH, Skv, D]."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(q.dtype), v)
