"""MXU-tiled matmul Pallas kernel (FPGA-analogue FB replacement for the 3mm
app and dense-layer blocks).

Grid (M/bm, N/bn, K/bk); A and B tiles stream HBM->VMEM per BlockSpec, the
fp32 accumulator lives in a VMEM scratch that persists across the K grid
dimension (innermost).  Tile defaults are MXU-aligned (128x128x128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(a: jax.Array, b: jax.Array, *, block_m: int = 128,
           block_n: int = 128, block_k: int = 128,
           interpret: bool = True) -> jax.Array:
    """a [M, K] @ b [K, N] -> [M, N] with fp32 accumulation."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    gm, gn, gk = a.shape[0] // bm, b.shape[1] // bn, a.shape[1] // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], b.shape[1]), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
