"""Llama 3.2 Vision 90B — decoder backbone with cross-attn image layers
every 5th layer (80 self + 20 cross = 100L). Vision frontend is a stub:
input_specs provides precomputed patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=28672, vocab_size=128256,
    ffn_act="swiglu", norm="rmsnorm", attn_kind="full",
    cross_attn_every=4, n_img_tokens=1024,
    source="hf:meta-llama/Llama-3.2-11B-Vision (unverified)",
)
