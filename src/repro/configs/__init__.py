"""Config registry: one module per assigned architecture.

``get_config("granite-3-2b")`` returns the full published config;
``get_config(name).reduced()`` the CPU smoke-test version.
"""
from __future__ import annotations

from repro.configs.base import (ModelConfig, MoEConfig, SSMConfig,
                                HybridConfig, ShapeConfig, TrainConfig,
                                SHAPES)

from repro.configs.granite_3_2b import CONFIG as _granite
from repro.configs.h2o_danube_1_8b import CONFIG as _danube
from repro.configs.command_r_plus_104b import CONFIG as _command_r
from repro.configs.nemotron_4_15b import CONFIG as _nemotron
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.recurrentgemma_2b import CONFIG as _rgemma
from repro.configs.mamba2_1_3b import CONFIG as _mamba2
from repro.configs.llama_3_2_vision_90b import CONFIG as _llama_vision
from repro.configs.seamless_m4t_medium import CONFIG as _seamless

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        _granite, _danube, _command_r, _nemotron, _moonshot,
        _arctic, _rgemma, _mamba2, _llama_vision, _seamless,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")
    return SHAPES[name]


def cells():
    """All 40 (arch, shape) cells; runnable() marks long_500k skips."""
    for a in ARCHS.values():
        for s in SHAPES.values():
            yield a, s


def cell_runnable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.is_sub_quadratic
    return True


__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "HybridConfig",
           "ShapeConfig", "TrainConfig", "SHAPES", "ARCHS", "get_config",
           "get_shape", "cells", "cell_runnable"]
