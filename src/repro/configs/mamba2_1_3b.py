"""Mamba-2 1.3B — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1, d_head=64,
    d_ff=0, vocab_size=50280,
    ffn_act="gelu", norm="rmsnorm", attn_kind="none",
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, n_groups=1,
                  conv_kernel=4, chunk=256),
    tie_embeddings=True,
    source="arXiv:2405.21060 (unverified)",
)
