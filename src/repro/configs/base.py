"""Configuration dataclasses for the repro framework.

Every assigned architecture gets a ``ModelConfig`` in its own module under
``repro.configs``; shapes are the four assigned (seq_len, global_batch) cells.
Configs are plain frozen dataclasses so they hash/compare cleanly and can be
reduced (``reduced()``) for CPU smoke tests.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    capacity_factor: float = 1.25
    shared_experts: int = 0        # always-on experts (Moonlight style)
    dense_residual: bool = False   # parallel dense FFN (Arctic style)
    dense_d_ff: int = 0            # hidden of the dense residual FFN
    router_noise: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_kernel: int = 4
    chunk: int = 256               # SSD chunk length (MXU-friendly)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style hybrid: pattern of block kinds, repeated."""
    pattern: Tuple[str, ...] = ("recurrent", "recurrent", "local_attn")
    lru_width: int = 0             # 0 => d_model
    conv_kernel: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                # 0 => d_model // n_heads
    ffn_act: str = "swiglu"        # swiglu | geglu | gelu | relu2
    use_bias: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    rope_theta: float = 10000.0
    attn_kind: str = "full"        # full | swa | none
    window: int = 0                # sliding/local attention window (0 = none)
    logit_softcap: float = 0.0
    tie_embeddings: bool = False
    # MoE / SSM / hybrid extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # VLM: a cross-attention layer is inserted after every `cross_attn_every`
    # self-attention layers. n_layers counts self+cross together.
    cross_attn_every: int = 0
    n_img_tokens: int = 1024
    # enc-dec (audio): encoder depth; n_layers is the decoder depth.
    encoder_layers: int = 0
    n_frames: int = 3072           # stub audio frontend output length
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256
    # provenance
    source: str = ""

    # ---- derived ----
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def is_sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context with O(1)/O(window) state?"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attn_kind == "swa" and self.window > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def n_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.head_dim
        p = self.padded_vocab * d                       # embed
        if not self.tie_embeddings:
            p += self.padded_vocab * d                  # lm head
        def attn_params() -> int:
            return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
        def ffn_params(hidden: int, gated: bool) -> int:
            return d * hidden * (3 if gated else 2)
        gated = self.ffn_act in ("swiglu", "geglu")
        layers = 0
        if self.family == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            # in_proj (z,x,B,C,dt) + conv + out_proj + A,D
            in_proj = d * (2 * di + 2 * s.n_groups * s.d_state + nh)
            layers = self.n_layers * (in_proj + di * d + di * s.conv_kernel
                                      + 2 * nh + 2 * d)
        elif self.family == "hybrid":
            h = self.hybrid
            w = h.lru_width or d
            rec = d * w * 2 + w * d + w * h.conv_kernel + 4 * w  # proj+gates+conv
            att = attn_params()
            n_rec = sum(1 for i in range(self.n_layers)
                        if h.pattern[i % len(h.pattern)] == "recurrent")
            n_att = self.n_layers - n_rec
            layers = n_rec * rec + n_att * att \
                + self.n_layers * (ffn_params(self.d_ff, gated) + 2 * d)
        else:
            per = attn_params() + 2 * d
            if self.moe is not None:
                m = self.moe
                per += d * m.n_experts                       # router
                per += m.n_experts * ffn_params(m.d_expert, gated) // 1
                per += m.shared_experts * ffn_params(m.d_expert, gated)
                if m.dense_residual:
                    per += ffn_params(m.dense_d_ff or self.d_ff, gated)
            else:
                per += ffn_params(self.d_ff, gated)
            n_self = self.n_layers
            if self.cross_attn_every:
                n_cross = self.n_layers // (self.cross_attn_every + 1)
                n_self = self.n_layers - n_cross
                layers = n_self * per + n_cross * (attn_params() + 2 * d +
                                                   ffn_params(self.d_ff, gated))
            else:
                layers = n_self * per
            if self.encoder_layers:
                # encoder self-attn + FFN, decoder adds cross-attn per layer
                layers += self.encoder_layers * per
                layers += self.n_layers * attn_params()
        return p + layers

    def active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        gated = self.ffn_act in ("swiglu", "geglu")
        per_expert = self.d_model * m.d_expert * (3 if gated else 2)
        inactive = self.n_layers * (m.n_experts - m.top_k) * per_expert
        return self.n_params() - inactive

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2 if not self.cross_attn_every else 3),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_head=32,
            d_ff=256,
            vocab_size=512,
            window=min(self.window, 64) if self.window else 0,
            n_img_tokens=16,
            n_frames=32,
            encoder_layers=min(self.encoder_layers, 2),
            vocab_pad_multiple=16,
            dtype="float32",
            param_dtype="float32",
        )
        if self.moe is not None:
            # generous capacity so reduced-scale tests are drop-free (drops
            # make prefill/decode routing legitimately diverge)
            kw["moe"] = replace(self.moe, n_experts=4, top_k=2, d_expert=64,
                                capacity_factor=8.0,
                                dense_d_ff=64 if self.moe.dense_residual
                                else 0)
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=16, headdim=32, chunk=16)
        if self.hybrid is not None:
            kw["hybrid"] = replace(self.hybrid, lru_width=128, conv_kernel=4)
        if self.cross_attn_every:
            kw["cross_attn_every"] = 2
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four assigned LM shape cells.
SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    microbatches: int = 1
    master_dtype: str = "float32"   # optimizer moment / master-param dtype
    use_master_copy: bool = False   # fp32 master params (off: update in-place)
    zero_sharded_opt: bool = True   # shard optimizer state like params
