"""NVIDIA Nemotron-4 15B — GQA, squared-ReLU (non-gated) FFN.
[arXiv:2402.16819; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=24576, vocab_size=256000,
    ffn_act="relu2", norm="layernorm", attn_kind="full",
    source="arXiv:2402.16819 (unverified)",
)
