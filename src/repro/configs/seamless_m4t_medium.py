"""SeamlessM4T medium — enc-dec transformer backbone (12L enc + 12L dec);
audio frontend is a stub: input_specs provides precomputed frame embeddings.
[arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=4096, vocab_size=256206,
    ffn_act="gelu", norm="layernorm", attn_kind="full", use_bias=True,
    encoder_layers=12, n_frames=3072,
    source="arXiv:2308.11596",
)
