"""H2O Danube 1.8B — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, d_head=80,
    d_ff=6912, vocab_size=32000,
    ffn_act="swiglu", norm="rmsnorm", attn_kind="swa", window=4096,
    source="arXiv:2401.16818",
)
