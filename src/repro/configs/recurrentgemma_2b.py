"""RecurrentGemma 2B — RG-LRU + local attention, 2:1 pattern (Griffin).
[arXiv:2402.19427; hf]"""
from repro.configs.base import ModelConfig, HybridConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_head=256,
    d_ff=7680, vocab_size=256000,
    ffn_act="geglu", norm="rmsnorm", attn_kind="local", window=2048,
    hybrid=HybridConfig(pattern=("recurrent", "recurrent", "local_attn"),
                        lru_width=2560, conv_kernel=4),
    tie_embeddings=True,
    source="arXiv:2402.19427",
)
