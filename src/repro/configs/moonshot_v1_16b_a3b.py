"""Moonshot/Moonlight 16B-A3B — MoE 64 experts top-6, 2 shared experts.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1408, vocab_size=163840,
    ffn_act="swiglu", norm="rmsnorm", attn_kind="full",
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, shared_experts=2),
    source="hf:moonshotai/Moonlight-16B-A3B",
)
