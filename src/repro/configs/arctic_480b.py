"""Snowflake Arctic 480B — MoE 128 experts top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=4864, vocab_size=32000,
    ffn_act="swiglu", norm="rmsnorm", attn_kind="full",
    moe=MoEConfig(n_experts=128, top_k=2, d_expert=4864,
                  dense_residual=True, dense_d_ff=4864),
    source="hf:Snowflake/snowflake-arctic-base",
)
