"""Cohere Command R+ 104B — dense GQA, no-bias, 256k vocab.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_head=128,
    d_ff=33792, vocab_size=256000,
    ffn_act="swiglu", norm="rmsnorm", attn_kind="full", use_bias=False,
    source="hf:CohereForAI/c4ai-command-r-v01 (unverified)",
)
