"""Continuous batching: slot-based decode over a fixed-shape pool.

The engine holds ``n_slots`` per-request decode caches stacked on a new
leading slot axis and advances them with **one** jitted
``vmap(decode_step)`` — requests join and leave at decode-step granularity
without ever changing the traced shapes, so the step compiles exactly once
per engine (pinned by ``ContinuousBatcher.traces`` and
tests/test_serve_batching.py).

Slot-pool invariants (the ROADMAP contract):

  * the pool's leading axis is ``n_slots`` on every cache leaf; a slot's
    cache is replaced wholesale at admission (jitted
    ``dynamic_update_index_in_dim`` insert, traced index — one trace total),
    so stale state from a previous occupant can never leak;
  * inactive slots still run the decode step (fixed shapes beat masked
    compute at this scale); their outputs are discarded host-side and their
    cache garbage is overwritten by the next insert;
  * prefill runs at the **exact** prompt length, one jit per unique length
    — right-padding a prompt would poison recurrent (ssm/hybrid) state and
    window-ring caches, and a padded prefill is *not* token-identical to
    the sequential reference;
  * at most one prefill is interleaved per tick, so admissions never starve
    running decodes.

Time is a virtual tick clock (``tick_s`` per engine tick): arrivals,
TTFT/TPOT and the continuous-vs-static comparison all live on one
deterministic timeline, independent of host load.
"""
from __future__ import annotations

import zlib
from typing import Dict, List, Optional

import numpy as np

from repro.obs import get_tracer
from repro.serve.metrics import ServeMetrics
from repro.serve.request import Request

DEFAULT_TICK_S = 0.01


def synth_tokens(rid: str, prompt_len: int, vocab: int) -> np.ndarray:
    """Deterministic synthetic prompt for a request without one (traces,
    benchmarks): seeded from the request id, stable across runs."""
    rng = np.random.RandomState(zlib.crc32(rid.encode()) & 0x7FFFFFFF)
    return rng.randint(0, vocab, size=(prompt_len,)).astype(np.int32)


class ContinuousBatcher:
    """Slot-pool continuous batching over one model replica.

    ``model`` / ``params`` are a :class:`repro.models.lm.Model` and its
    parameters; ``n_slots`` fixes the traced pool width and ``cache_len``
    the per-slot KV/state length.  ``envelope``
    (:class:`repro.power.PowerEnvelope`) prices each tick's energy into
    the metrics; ``eos_id`` stops a request early on that token.
    """

    def __init__(self, model, params, *, n_slots: int, cache_len: int,
                 metrics: Optional[ServeMetrics] = None,
                 envelope=None, eos_id: Optional[int] = None,
                 tick_s: float = DEFAULT_TICK_S):
        import jax
        import jax.numpy as jnp
        from repro.models.lm import init_cache

        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1: {n_slots}")
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.n_slots = int(n_slots)
        self.cache_len = int(cache_len)
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.eos_id = eos_id
        self.tick_s = float(tick_s)
        self.energy_model = None
        if envelope is not None:
            from repro.power import EnergyModel
            self.energy_model = EnergyModel(envelope)

        # trace counters: the counted bodies run only while jax is tracing,
        # so a steady-state tick leaves every counter flat — the engine-side
        # half of the zero-recompile guarantee
        self.traces = {"decode_step": 0, "insert": 0, "prefill": 0}

        one = init_cache(self.cfg, 1, self.cache_len,
                         quant=model.plan.kv_cache_quant)
        self._pool = jax.tree.map(
            lambda x: jnp.zeros((self.n_slots,) + x.shape, x.dtype), one)

        def one_step(params, cache, tok, pos):
            logits, new_cache = model.decode_step(params, cache, tok, pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # [1]
            return nxt, new_cache

        def pool_step(params, pool, toks, poss):
            self.traces["decode_step"] += 1
            return jax.vmap(one_step, in_axes=(None, 0, 0, 0))(
                params, pool, toks, poss)

        def pool_insert(pool, one_cache, idx):
            self.traces["insert"] += 1
            return jax.tree.map(
                lambda p, o: jax.lax.dynamic_update_index_in_dim(
                    p, o.astype(p.dtype), idx, 0), pool, one_cache)

        self._step = jax.jit(pool_step)
        self._insert = jax.jit(pool_insert)
        self._prefill_jits: Dict[int, object] = {}

        # host-side slot state (numpy: mutated at tick granularity)
        self._active = np.zeros(self.n_slots, dtype=bool)
        self._pos = np.zeros(self.n_slots, dtype=np.int32)
        self._last_tok = np.zeros(self.n_slots, dtype=np.int32)
        self._remaining = np.zeros(self.n_slots, dtype=np.int64)
        self._slot_req: List[Optional[Request]] = [None] * self.n_slots
        self._ticks = 0
        self._queue: List[Request] = []       # arrived, awaiting a slot
        self._pending: List[Request] = []     # on the trace, not yet arrived
        self._out: Dict[str, List[int]] = {}

    # ------------------------------------------------------------- intake
    @property
    def now_s(self) -> float:
        return self._ticks * self.tick_s

    @property
    def free_slots(self) -> int:
        return int((~self._active).sum())

    @property
    def live(self) -> int:
        return int(self._active.sum())

    def submit(self, req: Request):
        if req.arch and req.arch != self.cfg.name:
            raise ValueError(
                f"request {req.rid} wants arch {req.arch!r}, engine serves "
                f"{self.cfg.name!r} (route first: repro.serve.router)")
        self.metrics.on_submit(req.rid, req.arrival_s, arch=req.arch)
        self._pending.append(req)
        self._pending.sort(key=lambda r: (r.arrival_s, r.rid))

    # ------------------------------------------------------------ prefill
    def _prefill_fn(self, prompt_len: int):
        import jax
        fn = self._prefill_jits.get(prompt_len)
        if fn is None:
            def pf(params, batch):
                self.traces["prefill"] += 1
                return self.model.prefill(params, batch, self.cache_len)
            fn = self._prefill_jits[prompt_len] = jax.jit(pf)
        return fn

    def _admit(self, req: Request, slot: int, t_done: float):
        import jax.numpy as jnp
        toks = req.tokens
        if toks is None:
            toks = synth_tokens(req.rid, req.prompt_len,
                                self.cfg.vocab_size)
        toks = np.asarray(toks, dtype=np.int32).reshape(1, -1)
        if toks.shape[1] != req.prompt_len:
            raise ValueError(f"request {req.rid}: tokens length "
                             f"{toks.shape[1]} != prompt_len "
                             f"{req.prompt_len}")
        batch = {"tokens": jnp.asarray(toks)}
        for k, v in req.extras.items():
            batch[k] = v
        logits, cache = self._prefill_fn(req.prompt_len)(self.params, batch)
        first = int(np.asarray(logits).argmax(axis=-1)[0])

        self._pool = self._insert(self._pool, cache, slot)
        self._active[slot] = True
        self._pos[slot] = req.prompt_len
        self._last_tok[slot] = first
        self._remaining[slot] = req.max_gen - 1
        self._slot_req[slot] = req
        self._out[req.rid] = [first]

        self.metrics.on_admit(req.rid, t_done)
        self.metrics.on_token(req.rid, t_done)
        if self._remaining[slot] <= 0 or \
                (self.eos_id is not None and first == self.eos_id):
            self._retire(slot, t_done)

    def _retire(self, slot: int, t: float):
        req = self._slot_req[slot]
        self._active[slot] = False
        self._slot_req[slot] = None
        self._remaining[slot] = 0
        if req is not None:
            self.metrics.on_finish(req.rid, t)

    # --------------------------------------------------------------- tick
    def tick(self) -> bool:
        """One engine tick: admit due arrivals (≤1 prefill), advance every
        active slot one decode step, retire finished requests.  Returns
        True while any work remains (live slots, queue, or future
        arrivals)."""
        import jax.numpy as jnp

        now = self.now_s
        t_end = now + self.tick_s
        while self._pending and self._pending[0].arrival_s <= now:
            self._queue.append(self._pending.pop(0))

        # one interleaved prefill per tick: admissions must not starve the
        # decode cadence of the requests already running
        if self._queue and self.free_slots:
            slot = int(np.flatnonzero(~self._active)[0])
            self._admit(self._queue.pop(0), slot, t_end)

        live_before = [r.rid for r in self._slot_req if r is not None]
        if self._active.any():
            toks = jnp.asarray(
                self._last_tok.reshape(self.n_slots, 1, 1))
            poss = jnp.asarray(self._pos)
            nxt, self._pool = self._step(self.params, self._pool, toks,
                                         poss)
            nxt = np.asarray(nxt).reshape(self.n_slots)
            for slot in np.flatnonzero(self._active):
                req = self._slot_req[slot]
                tok = int(nxt[slot])
                self._out[req.rid].append(tok)
                self._last_tok[slot] = tok
                self._pos[slot] += 1
                self._remaining[slot] -= 1
                self.metrics.on_token(req.rid, t_end)
                if self._remaining[slot] <= 0 or \
                        (self.eos_id is not None and tok == self.eos_id):
                    self._retire(slot, t_end)

        self._ticks += 1
        if self.energy_model is not None:
            joules = self.energy_model.tick_joules(
                self.tick_s, len(live_before) / self.n_slots)
            self.metrics.charge_tick(joules, live_before)
        else:
            joules = 0.0
            self.metrics.charge_tick(0.0, live_before)
        # one complete-span per tick on the virtual clock (no-op unless a
        # tracer is enabled): the engine's swim-lane in a Perfetto trace
        get_tracer().complete_span(
            "tick", now, t_end, cat="engine",
            track=f"engine:{self.cfg.name}", tick=self._ticks - 1,
            live=len(live_before), queued=len(self._queue),
            joules=joules)
        return bool(self._active.any() or self._queue or self._pending)

    # ---------------------------------------------------------------- run
    def run(self, requests: Optional[List[Request]] = None,
            max_ticks: int = 1_000_000) -> Dict[str, np.ndarray]:
        """Drive ticks until every submitted request completes; returns
        ``{rid: generated tokens [max_gen]}`` (greedy decode)."""
        for req in requests or ():
            self.submit(req)
        # fast-forward to the first arrival: an empty engine burning idle
        # ticks until the trace starts is not useful work
        if not self._active.any() and not self._queue and self._pending:
            first = self._pending[0].arrival_s
            if first > self.now_s:
                self._ticks = int(np.ceil(first / self.tick_s - 1e-9))
        for _ in range(max_ticks):
            if not self.tick():
                break
        else:
            raise RuntimeError(f"engine did not drain in {max_ticks} ticks")
        return {rid: np.asarray(toks, dtype=np.int32)
                for rid, toks in self._out.items()}
