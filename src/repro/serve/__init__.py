"""repro.serve — online request router + continuous batching engine.

The source paper selects an offload destination *offline*, once per
application; this package is the production form of that decision made
**per request**, at runtime, under the power constraints of the follow-up
study (arXiv 2110.11520):

  * :class:`Request` — one generation request (arch, prompt_len, max_gen,
    optional SLO deadline, arrival time).
  * :class:`Router` / :class:`Endpoint` — scores each request against warm
    :class:`~repro.core.plan_lookup.PlanLookup` analyses for every live
    backend (``score_analysis`` + :class:`~repro.power.EnergyModel`) and
    dispatches under the session
    :class:`~repro.backends.SelectionPolicy`, with admission control from
    an aggregate ``power_budget_w``.  The hot path is dict lookup +
    roofline arithmetic: provably trace/compile-free after warm-up.
  * :class:`ContinuousBatcher` — slot-based decode loop over
    ``Model.prefill`` / ``Model.decode_step``: requests join and leave the
    running batch at decode-step granularity over a fixed-shape slot pool,
    so the jitted step traces exactly once.
  * :class:`ServeMetrics` — queue/TTFT/TPOT/tok-s counters, per-request
    joule charges, refusal-reason counts and per-endpoint latency
    percentiles.
  * :class:`EndpointHealth` / :class:`HealthConfig` — the per-endpoint
    health state machine (healthy → degraded → quarantined → probing →
    recovered) the Router consults on every route: latency-EWMA
    degradation with a score penalty, a circuit breaker with
    exponential-backoff half-open probes, and drain-based removal.  The
    online control loop that drives it lives in
    :mod:`repro.runtime.control`.
"""
from repro.serve.batching import ContinuousBatcher
from repro.serve.health import (DEGRADED, HEALTH_STATES, HEALTHY, PROBING,
                                QUARANTINED, EndpointHealth, HealthConfig)
from repro.serve.metrics import ServeMetrics
from repro.serve.request import Request
from repro.serve.router import Endpoint, Router, RoutingDecision

__all__ = ["Request", "Router", "Endpoint", "RoutingDecision",
           "ContinuousBatcher", "ServeMetrics",
           "EndpointHealth", "HealthConfig", "HEALTH_STATES",
           "HEALTHY", "DEGRADED", "QUARANTINED", "PROBING"]
