"""Request lifecycle metrics: queue, TTFT/TPOT/tok-s, joules per request.

Counter semantics follow the usual serving definitions:

  * **TTFT** — submit-to-first-token: queueing + prefill.
  * **TPOT** — mean inter-token time after the first token.
  * **tok/s** — completed generated tokens over the engine's active span.
  * **joules/request** — every engine tick's energy
    (:meth:`repro.power.EnergyModel.tick_joules`) is split evenly across
    the requests that were live during it, so a request that decoded in a
    full batch is charged a fraction of the tick while a lone straggler
    pays the whole machine — the per-request form of the planner's
    ``energy_for_record`` charge.

All timestamps are caller-supplied seconds on one monotonic timeline (the
engine feeds its own tick clock), so the counters are deterministic under
test.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional


def percentile(values: List[float], p: float) -> Optional[float]:
    """Nearest-rank percentile (p in [0,100]); None on empty input.

    Uses the ceil-based nearest-rank definition ``k = ceil(p/100 * n)``:
    ``int(round(...))`` rounds half-to-even (banker's rounding), which
    picked the *lower* element on exact .5 ranks for half the input sizes
    — a nondeterministic-looking bias pinned away by
    tests/test_serve_metrics.py."""
    if not values:
        return None
    xs = sorted(values)
    if p <= 0:
        return xs[0]
    k = math.ceil(p / 100.0 * len(xs))
    return xs[min(max(k, 1), len(xs)) - 1]


@dataclass
class RequestMetrics:
    rid: str
    submit_s: float = 0.0
    admit_s: Optional[float] = None
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None
    n_tokens: int = 0
    energy_j: float = 0.0
    rejected: Optional[str] = None          # last rejection reason, if any
    arch: Optional[str] = None              # requested architecture
    endpoint: Optional[str] = None          # endpoint it dispatched to
    service_s: Optional[float] = None       # observed service latency

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.submit_s

    @property
    def tpot_s(self) -> Optional[float]:
        """Mean inter-token time after the first token."""
        if self.finish_s is None or self.first_token_s is None \
                or self.n_tokens < 2:
            return None
        return (self.finish_s - self.first_token_s) / (self.n_tokens - 1)


@dataclass
class ServeMetrics:
    requests: Dict[str, RequestMetrics] = field(default_factory=dict)
    rejected: int = 0
    refusals: Dict[str, int] = field(default_factory=dict)
    ticks: int = 0
    total_energy_j: float = 0.0
    _span_start: Optional[float] = None
    _span_end: Optional[float] = None

    # --------------------------------------------------------- lifecycle
    def _get(self, rid: str) -> RequestMetrics:
        m = self.requests.get(rid)
        if m is None:
            m = self.requests[rid] = RequestMetrics(rid)
        return m

    def on_submit(self, rid: str, t: float, arch: Optional[str] = None):
        m = self._get(rid)
        m.submit_s = t
        if arch is not None:
            m.arch = arch
        self._span_start = t if self._span_start is None \
            else min(self._span_start, t)

    def on_reject(self, rid: str, reason: str):
        """One refusal event.  A queued request that is re-routed every
        tick counts one event per attempt — ``refusals`` is the operator's
        view of *why* admission is failing, not a unique-request count."""
        self._get(rid).rejected = reason
        self.rejected += 1
        self.refusals[reason] = self.refusals.get(reason, 0) + 1

    def on_admit(self, rid: str, t: float):
        self._get(rid).admit_s = t

    def on_dispatch(self, rid: str, endpoint: str):
        """The router committed the request to ``endpoint``."""
        self._get(rid).endpoint = endpoint

    def on_complete(self, rid: str, *, latency_s: Optional[float] = None,
                    energy_j: Optional[float] = None,
                    t: Optional[float] = None):
        """A routed request finished service: observed latency (feeds the
        per-endpoint percentiles the health state machine also reads) and
        its realized energy charge."""
        m = self._get(rid)
        if latency_s is not None:
            m.service_s = latency_s
        if energy_j is not None:
            m.energy_j += energy_j
            self.total_energy_j += energy_j
        if t is not None:
            self.on_finish(rid, t)

    def on_token(self, rid: str, t: float, n: int = 1):
        m = self._get(rid)
        if m.first_token_s is None:
            m.first_token_s = t
        m.n_tokens += n
        self._span_end = t if self._span_end is None \
            else max(self._span_end, t)

    def on_finish(self, rid: str, t: float):
        m = self._get(rid)
        m.finish_s = t
        self._span_end = t if self._span_end is None \
            else max(self._span_end, t)

    # ------------------------------------------------------------ energy
    def charge_tick(self, joules: float, active_rids: List[str]):
        """One engine tick's energy, split evenly among the live requests
        (the machine burned it regardless; occupancy decides the split)."""
        self.ticks += 1
        self.total_energy_j += joules
        if not active_rids:
            return
        share = joules / len(active_rids)
        for rid in active_rids:
            self._get(rid).energy_j += share

    # ----------------------------------------------------------- summary
    def endpoint_summary(self) -> Dict[str, dict]:
        """Per-endpoint completed counts and service-latency percentiles —
        the same numbers the health state machine's EWMA digests, so
        operators and the circuit breaker read one source of truth."""
        per: Dict[str, List[float]] = {}
        for m in self.requests.values():
            if m.endpoint is None or m.service_s is None:
                continue
            per.setdefault(m.endpoint, []).append(m.service_s)
        return {
            name: {
                "completed": len(lats),
                "latency_p50_s": percentile(lats, 50),
                "latency_p95_s": percentile(lats, 95),
            }
            for name, lats in sorted(per.items())
        }

    def summary(self) -> dict:
        done = [m for m in self.requests.values() if m.finish_s is not None]
        ttfts = [m.ttft_s for m in done if m.ttft_s is not None]
        tpots = [m.tpot_s for m in done if m.tpot_s is not None]
        tokens = sum(m.n_tokens for m in done)
        span = None
        if self._span_start is not None and self._span_end is not None \
                and self._span_end > self._span_start:
            span = self._span_end - self._span_start
        return {
            "completed": len(done),
            "rejected": self.rejected,
            "refusals": dict(self.refusals),
            "ticks": self.ticks,
            "tokens": tokens,
            "span_s": span,
            "tok_per_s": (tokens / span) if span else None,
            "ttft_p50_s": percentile(ttfts, 50),
            "ttft_p95_s": percentile(ttfts, 95),
            "tpot_mean_s": (sum(tpots) / len(tpots)) if tpots else None,
            "total_energy_j": self.total_energy_j,
            "joules_per_request": (self.total_energy_j / len(done))
            if done else None,
            "endpoints": self.endpoint_summary(),
        }
