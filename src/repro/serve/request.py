"""One generation request: what the router routes and the engine decodes."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional


@dataclass
class Request:
    """An online generation request.

    ``tokens`` (when given) is the prompt as a ``[prompt_len]`` int array /
    list; the benchmark and the CLI synthesize one when absent.  ``extras``
    carries modality context (``img_embed`` / ``frames``) for vlm / audio
    archs.  ``arrival_s`` is the request's position on the open-loop trace
    timeline (seconds from trace start); the engine admits a request only
    once its arrival tick has passed — that is what makes continuous
    batching beat static batching on staggered traces.
    """
    rid: str
    arch: str
    prompt_len: int
    max_gen: int
    deadline_s: Optional[float] = None      # SLO: max acceptable service time
    arrival_s: float = 0.0
    tokens: Any = None
    extras: Dict[str, Any] = field(default_factory=dict)
    retries: int = 0                        # re-dispatches after a failure

    def __post_init__(self):
        if self.prompt_len < 1:
            raise ValueError(f"prompt_len must be >= 1: {self.prompt_len}")
        if self.max_gen < 1:
            raise ValueError(f"max_gen must be >= 1: {self.max_gen}")
