"""Endpoint health: the serve-time half of the verification environment.

The paper's premise is that an offload destination can silently be wrong
or slow — offline, the verification environment catches that before
selection; online, the same distrust has to run continuously.  Each live
:class:`~repro.serve.router.Endpoint` carries one :class:`EndpointHealth`:
a per-endpoint :class:`~repro.runtime.fault_tolerance.StragglerWatchdog`
EWMA over observed request latencies plus explicit error reports, driving
the state machine

    healthy -> degraded -> quarantined -> probing -> (recovered) healthy

  * **healthy -> degraded** — the latency EWMA drifts past
    ``degrade_factor`` x the endpoint's baseline (or the watchdog flags a
    z-score outlier).  A degraded endpoint is *not* skipped: the Router
    applies ``degraded_penalty`` to its score so traffic shifts away
    gradually — graceful degradation, never a cliff.
  * **-> quarantined** — ``error_threshold`` consecutive error reports
    open the circuit breaker: the Router dispatches nothing to a
    quarantined endpoint (refusal reason "endpoint quarantined" when no
    alternative exists).
  * **quarantined -> probing** — after an exponential backoff
    (``backoff_ticks`` x ``backoff_mult`` per consecutive re-quarantine,
    capped at ``max_backoff_ticks``) the circuit goes half-open: at most
    ``probe_quota`` in-flight probe requests are admitted.
  * **probing -> healthy** — ``probe_successes`` successful probes close
    the circuit (a *recovered* transition: backoff resets, the watchdog
    window restarts fresh).  A failed probe re-quarantines with the
    escalated backoff.

Everything here is pure Python arithmetic on a virtual tick clock —
deterministic under test, zero traces/compiles on the routing path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.obs import get_tracer
from repro.runtime.fault_tolerance import StragglerWatchdog

HEALTHY = "healthy"
DEGRADED = "degraded"
QUARANTINED = "quarantined"
PROBING = "probing"

HEALTH_STATES = (HEALTHY, DEGRADED, QUARANTINED, PROBING)


@dataclass(frozen=True)
class HealthConfig:
    """Knobs of the per-endpoint state machine (shared by a Router)."""
    ewma_alpha: float = 0.3         # latency EWMA smoothing
    window: int = 16                # watchdog sample window
    threshold: float = 3.0          # watchdog z-score flag threshold
    baseline_s: Optional[float] = None  # expected latency; 1st obs if None
    degrade_factor: float = 2.0     # ewma > factor x baseline -> degraded
    recover_factor: float = 1.2     # ewma <= factor x baseline -> healthy
    degraded_penalty: float = 1.5   # score multiplier while degraded
    error_threshold: int = 2        # consecutive errors -> quarantine
    backoff_ticks: int = 8          # first quarantine duration (ticks)
    backoff_mult: float = 2.0       # escalation per failed probe cycle
    max_backoff_ticks: int = 512
    probe_quota: int = 1            # concurrent half-open probes
    probe_successes: int = 1        # successes needed to close the circuit

    def __post_init__(self):
        if self.degraded_penalty < 1.0:
            raise ValueError(f"degraded_penalty must be >= 1.0: "
                             f"{self.degraded_penalty}")
        if self.error_threshold < 1:
            raise ValueError(f"error_threshold must be >= 1: "
                             f"{self.error_threshold}")
        if self.backoff_ticks < 1:
            raise ValueError(f"backoff_ticks must be >= 1: "
                             f"{self.backoff_ticks}")


class EndpointHealth:
    """Health state of one live endpoint (see module docstring).

    The Router feeds it from the admission ledger: ``observe_latency`` /
    ``observe_success`` on each completed request, ``observe_error`` on
    each failure report; a controller advances the circuit timers with
    ``on_tick``.  ``transitions`` records every state change (tick, from,
    to, reason, and the triggering ``observed`` measurement) so chaos
    scenarios are assertable and post-mortems can explain each firing.
    """

    def __init__(self, name: str = "", cfg: Optional[HealthConfig] = None):
        self.name = name
        self.cfg = cfg if cfg is not None else HealthConfig()
        self.state = HEALTHY
        self.baseline_s = self.cfg.baseline_s
        self.watchdog = StragglerWatchdog(window=self.cfg.window,
                                          threshold=self.cfg.threshold,
                                          ewma_alpha=self.cfg.ewma_alpha)
        self.consecutive_errors = 0
        self.errors = 0
        self.recoveries = 0
        self.transitions: List[Dict] = []
        self._tick = 0
        self._backoff = float(self.cfg.backoff_ticks)
        self._reopen_at: Optional[int] = None
        self._probes_in_flight = 0
        self._probe_successes = 0

    # ----------------------------------------------------------- plumbing
    def _to(self, state: str, reason: str,
            observed: Optional[Dict] = None):
        """Record a state change.  ``observed`` carries the triggering
        measurement (latency/ewma values, error counts, backoff length) so
        a post-mortem can show *why* the transition fired, not just
        from->to."""
        if state == self.state:
            return
        entry = {"tick": self._tick, "from": self.state, "to": state,
                 "reason": reason, "observed": dict(observed or {})}
        self.transitions.append(entry)
        get_tracer().event("transition", cat="health",
                           track=f"endpoint:{self.name}", **entry,
                           endpoint=self.name)
        self.state = state

    @property
    def available(self) -> bool:
        """May the Router consider this endpoint at all right now?"""
        if self.state == QUARANTINED:
            return False
        if self.state == PROBING:
            return self.probe_free
        return True

    @property
    def probe_free(self) -> bool:
        return self._probes_in_flight < self.cfg.probe_quota

    @property
    def penalty(self) -> float:
        """Score multiplier the Router applies (1.0 unless degraded)."""
        return self.cfg.degraded_penalty if self.state == DEGRADED else 1.0

    # -------------------------------------------------------------- clock
    def on_tick(self, tick: int):
        """Advance the circuit timer: a quarantined endpoint whose backoff
        elapsed goes half-open (probing)."""
        self._tick = int(tick)
        if self.state == QUARANTINED and self._reopen_at is not None \
                and self._tick >= self._reopen_at:
            self._probes_in_flight = 0
            self._probe_successes = 0
            self._to(PROBING, f"backoff elapsed after "
                              f"{int(self._backoff)} ticks: half-open",
                     observed={"backoff_ticks": int(self._backoff)})

    # ------------------------------------------------------- observations
    def on_probe_dispatch(self):
        """A half-open probe request left for this endpoint."""
        self._probes_in_flight += 1

    def observe_latency(self, latency_s: float):
        """One completed request's observed service latency."""
        flagged = self.watchdog.record(self._tick, float(latency_s))
        if self.baseline_s is None:
            self.baseline_s = float(latency_s)
        else:
            # the best latency ever seen is the endpoint's honest baseline:
            # a fault window cannot ratchet it up
            self.baseline_s = min(self.baseline_s, float(latency_s))
        ewma = self.watchdog.ewma
        if ewma is None or self.baseline_s <= 0.0:
            return
        observed = {"latency_s": float(latency_s), "ewma_s": float(ewma),
                    "baseline_s": float(self.baseline_s)}
        if self.state == HEALTHY and \
                (flagged or ewma > self.cfg.degrade_factor * self.baseline_s):
            self._to(DEGRADED,
                     f"latency ewma {ewma:.4g}s > "
                     f"{self.cfg.degrade_factor:g}x baseline "
                     f"{self.baseline_s:.4g}s", observed=observed)
        elif self.state == DEGRADED and \
                ewma <= self.cfg.recover_factor * self.baseline_s:
            self._to(HEALTHY,
                     f"latency ewma {ewma:.4g}s back within "
                     f"{self.cfg.recover_factor:g}x baseline",
                     observed=observed)

    def observe_success(self, probe: bool = False):
        """A request completed correctly on this endpoint."""
        self.consecutive_errors = 0
        if probe:
            self._probes_in_flight = max(self._probes_in_flight - 1, 0)
        if self.state == PROBING:
            self._probe_successes += 1
            if self._probe_successes >= self.cfg.probe_successes:
                self.recoveries += 1
                self._backoff = float(self.cfg.backoff_ticks)
                self._reopen_at = None
                probes = self._probe_successes
                self.watchdog.reset()         # fresh window post-recovery
                self._to(HEALTHY, "recovered: half-open probe succeeded",
                         observed={"probe_successes": probes})

    def observe_error(self, reason: str = "", probe: bool = False):
        """An explicit failure report (died, wrong result, timeout...)."""
        self.errors += 1
        self.consecutive_errors += 1
        if probe:
            self._probes_in_flight = max(self._probes_in_flight - 1, 0)
        observed = {"consecutive_errors": self.consecutive_errors,
                    "errors": self.errors,
                    "error": reason or "error"}
        if self.state == PROBING:
            self._quarantine(f"probe failed: {reason or 'error'}",
                             escalate=True, observed=observed)
        elif self.state != QUARANTINED and \
                self.consecutive_errors >= self.cfg.error_threshold:
            self._quarantine(reason or
                             f"{self.consecutive_errors} consecutive "
                             f"errors", escalate=False, observed=observed)

    # ------------------------------------------------------------ circuit
    def _quarantine(self, reason: str, escalate: bool,
                    observed: Optional[Dict] = None):
        if escalate:
            self._backoff = min(self._backoff * self.cfg.backoff_mult,
                                float(self.cfg.max_backoff_ticks))
        self._reopen_at = self._tick + int(self._backoff)
        obs = dict(observed or {})
        obs["backoff_ticks"] = int(self._backoff)
        self._to(QUARANTINED, reason, observed=obs)

    def quarantine(self, reason: str = "operator request"):
        """Open the circuit explicitly (operator / controller action)."""
        if self.state != QUARANTINED:
            self._quarantine(reason, escalate=False)
