"""Online request router: the paper's destination choice, per request.

The offline planner (``plan_offload``) verifies destinations once per
application; at serve time the same decision repeats per request, so every
ingredient must already be warm:

  * each live :class:`Endpoint`'s plan analysis is published into a
    :class:`~repro.core.plan_lookup.PlanLookup` (by ``plan_offload(...,
    publish=...)`` or directly at endpoint registration);
  * routing a request is then: static lint prune
    (``lint_plan(serve=...)``, the PR-6 prune-before-compile contract) →
    warm payload lookup (a recorded verification *failure* refuses the
    endpoint outright) → pure-arithmetic roofline scoring
    (``score_analysis``) scaled to the request's token work →
    :class:`~repro.power.EnergyModel` watts/joules → ranking under the
    session :class:`~repro.backends.SelectionPolicy` with admission
    control from the aggregate ``power_budget_w``.

Nothing on this path traces or compiles: after warm-up, routing N requests
moves only ``CacheStats.lookups`` — ``CacheStats.misses`` (the compile
counter) stays flat, pinned by tests/test_serve_router.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.backends import SelectionPolicy, get_policy
from repro.core.candidates import Candidate
from repro.core.plan_lookup import PlanLookup, serve_key
from repro.obs import get_tracer
from repro.serve.health import (DEGRADED, PROBING, QUARANTINED,
                                EndpointHealth, HealthConfig)
from repro.serve.metrics import ServeMetrics
from repro.serve.request import Request


@dataclass
class Endpoint:
    """One live serving destination: a backend's machine running one arch
    under one serving plan, with a fixed continuous-batching slot pool."""
    name: str
    backend: object                 # repro.backends.Backend (duck-typed)
    arch: str
    n_chips: int = 1
    n_slots: int = 4
    cache_len: int = 256
    plan: object = None             # repro.dist.plan.Plan (serving genes)
    cfg: object = None              # ModelConfig (for the static lint)
    engine: object = None           # optional ContinuousBatcher
    # live state the router maintains
    in_flight: int = 0
    draining: bool = False          # no new dispatches; in-flight completes

    @property
    def free_slots(self) -> int:
        return max(self.n_slots - self.in_flight, 0)

    def lookup_key(self):
        return serve_key(getattr(self.backend, "name", self.name),
                         self.arch, self.plan)


@dataclass
class RoutingDecision:
    rid: str
    endpoint: Optional[Endpoint]            # None == rejected
    reason: str = ""                        # rejection reason / "ok"
    service_time_s: Optional[float] = None  # modeled prefill+decode seconds
    energy_j: Optional[float] = None
    avg_watts: Optional[float] = None
    considered: int = 0                     # endpoints that survived pruning

    @property
    def accepted(self) -> bool:
        return self.endpoint is not None


class Router:
    """Score-and-dispatch over live endpoints (see module docstring).

    ``power_budget_w`` is the *fleet* budget: admission subtracts the draw
    of requests already in flight, so a request is rejected when the
    marginal endpoint draw no longer fits — the serve-time form of the
    power follow-up's "within allowed power" selection.
    """

    def __init__(self, endpoints: List[Endpoint], lookup: PlanLookup, *,
                 policy=None, power_budget_w: Optional[float] = None,
                 metrics: Optional[ServeMetrics] = None,
                 health_cfg: Optional[HealthConfig] = None):
        if not endpoints:
            raise ValueError("router needs at least one endpoint")
        names = [e.name for e in endpoints]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate endpoint names: {names}")
        self.endpoints = list(endpoints)
        self.lookup = lookup
        self.policy: SelectionPolicy = get_policy(policy)
        self.power_budget_w = power_budget_w
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.health_cfg = health_cfg if health_cfg is not None \
            else HealthConfig()
        # per-endpoint health state machines (repro.serve.health): pure
        # arithmetic, fed from the admission ledger on complete/fail
        self.health: Dict[str, EndpointHealth] = {
            e.name: EndpointHealth(e.name, self.health_cfg)
            for e in endpoints}
        # draw currently admitted per endpoint (watts, modeled at routing)
        self._draw_w: Dict[str, float] = {e.name: 0.0 for e in endpoints}
        # endpoints removed while requests were still in flight: their
        # ledger entries stay completable (draw released on complete),
        # never orphaned — the entry is dropped once the last one drains
        self._removed: Dict[str, Endpoint] = {}
        # admission ledger: rid -> (endpoint name, admitted draw, probe).
        # The slot/draw accounting releases exactly what dispatch charged,
        # once — a double complete (or completing a never-dispatched
        # decision) must not leak negative draw into admission headroom.
        self._admitted: Dict[str, Tuple[str, float, bool]] = {}

    # ------------------------------------------------------------- state
    @property
    def fleet_draw_w(self) -> float:
        from repro.power import fleet_draw_w
        return fleet_draw_w(self._draw_w.values())

    def endpoint(self, name: str) -> Optional[Endpoint]:
        """Live endpoint by name (None when absent or already removed)."""
        for ep in self.endpoints:
            if ep.name == name:
                return ep
        return None

    def in_flight_of(self, name: str) -> int:
        """Admitted-but-uncompleted requests on ``name`` per the ledger
        (authoritative — survives endpoint removal)."""
        return sum(1 for n, _, _ in self._admitted.values() if n == name)

    # ------------------------------------------------- endpoint lifecycle
    def add_endpoint(self, ep: Endpoint):
        """Register a new live endpoint (elastic grow / re-admission)."""
        if self.endpoint(ep.name) is not None or ep.name in self._removed:
            raise ValueError(f"endpoint {ep.name!r} already registered")
        self.endpoints.append(ep)
        self._draw_w.setdefault(ep.name, 0.0)
        self.health[ep.name] = EndpointHealth(ep.name, self.health_cfg)

    def drain(self, name: str) -> Endpoint:
        """Stop dispatching to ``name``; in-flight requests keep their
        slots and complete normally.  The migration primitive: drain, wait
        for :meth:`drained`, then :meth:`remove_endpoint`."""
        ep = self.endpoint(name)
        if ep is None:
            raise ValueError(f"unknown endpoint {name!r}")
        ep.draining = True
        return ep

    def drained(self, name: str) -> bool:
        """True once ``name`` has no admitted request left in the ledger."""
        return self.in_flight_of(name) == 0

    def remove_endpoint(self, name: str) -> Endpoint:
        """Take ``name`` out of routing entirely.  With requests still in
        flight its ledger entries remain completable — draw and slot
        accounting release on ``complete`` exactly as if it were live —
        and the draw entry is dropped only once fully drained."""
        ep = self.endpoint(name)
        if ep is None:
            raise ValueError(f"unknown endpoint {name!r}")
        self.endpoints = [e for e in self.endpoints if e.name != name]
        if self.in_flight_of(name) > 0:
            self._removed[name] = ep
        else:
            self._draw_w.pop(name, None)
        return ep

    # ---------------------------------------------------------- dispatch
    def dispatch(self, decision: "RoutingDecision"):
        """Commit an accepted decision: occupy a slot, add its draw."""
        ep = decision.endpoint
        if ep is None:
            raise ValueError(f"cannot dispatch rejected request "
                             f"{decision.rid}")
        if decision.rid in self._admitted:
            raise ValueError(f"request {decision.rid} is already dispatched")
        ep.in_flight += 1
        draw = decision.avg_watts if decision.avg_watts is not None else 0.0
        self._draw_w[ep.name] = self._draw_w.get(ep.name, 0.0) + draw
        health = self.health.get(ep.name)
        probe = health is not None and health.state == PROBING
        if probe:
            health.on_probe_dispatch()
        self._admitted[decision.rid] = (ep.name, draw, probe)
        self.metrics.on_dispatch(decision.rid, ep.name)

    def complete(self, decision: "RoutingDecision", *,
                 latency_s: Optional[float] = None, ok: bool = True,
                 error: str = "", now_s: Optional[float] = None) -> bool:
        """Release an admitted request's slot and draw.  Returns True when
        the request was in flight; completing a rejected, never-dispatched
        or already-completed decision is a no-op (the ledger guarantees
        ``fleet_draw_w``/``in_flight`` can never go negative).

        The optional observation feeds the endpoint's health state
        machine: ``latency_s`` is the observed service latency, ``ok``
        False reports a failure (``error`` its reason — see :meth:`fail`),
        ``now_s`` stamps the finish time into the metrics."""
        admitted = self._admitted.pop(decision.rid, None)
        if admitted is None:
            return False
        name, draw, probe = admitted
        ep = self.endpoint(name) or self._removed.get(name)
        if ep is not None:
            ep.in_flight = max(ep.in_flight - 1, 0)
        if name in self._draw_w:
            self._draw_w[name] = max(self._draw_w[name] - draw, 0.0)
        if name in self._removed and self.in_flight_of(name) == 0:
            self._removed.pop(name)
            self._draw_w.pop(name, None)
        health = self.health.get(name)
        if health is not None:
            if ok:
                if latency_s is not None:
                    health.observe_latency(latency_s)
                health.observe_success(probe=probe)
            else:
                health.observe_error(error or "error", probe=probe)
        if ok:
            energy = None
            if decision.avg_watts is not None and latency_s is not None:
                energy = decision.avg_watts * latency_s
            self.metrics.on_complete(decision.rid, latency_s=latency_s,
                                     energy_j=energy, t=now_s)
        return True

    def fail(self, decision: "RoutingDecision", reason: str = "error",
             now_s: Optional[float] = None) -> bool:
        """Report a failed request: releases the ledger entry and feeds an
        error to the endpoint's circuit breaker.  The caller owns the
        retry (the request was not served)."""
        return self.complete(decision, ok=False, error=reason, now_s=now_s)

    # ----------------------------------------------------------- scoring
    def _score_endpoint(self, ep: Endpoint, req: Request
                        ) -> Tuple[Optional[Candidate], str]:
        """Warm-path score of one endpoint for one request: ``(candidate,
        verdict)``.  The candidate is None — and the verdict names why —
        when the endpoint cannot serve it: ``lint-pruned`` (static lint
        error), ``cold-lookup`` (nothing published), ``failure-verdict``
        (a recorded verification failure).  Pure arithmetic — no jax."""
        from repro.analysis import lint_plan
        if ep.plan is not None or ep.cfg is not None:
            findings = lint_plan(
                ep.plan if ep.plan is not None else _NULL_PLAN,
                cfg=ep.cfg,
                serve={"n_slots": ep.n_slots, "cache_len": ep.cache_len,
                       "prompt_len": req.prompt_len,
                       "max_gen": req.max_gen})
            if any(f.severity == "error" for f in findings):
                self.lookup.stats.static_pruned += 1
                return None, "lint-pruned"
        payload = self.lookup.lookup(ep.lookup_key())
        if not self.lookup.usable(payload):
            return None, ("cold-lookup" if payload is None
                          else "failure-verdict")
        # the warm analysis describes one decode step; the request costs
        # max_gen steps plus a prefill charged as prompt work at step rate
        return Candidate.from_analysis(
            payload["analysis"], backend=ep.backend, arch=ep.arch,
            n_chips=ep.n_chips,
            scale=req.max_gen + req.prompt_len / 8.0,
            plan_key=ep.plan.structural_key() if ep.plan is not None
            else None,
            ref=ep), "scored"

    # ----------------------------------------------------------- routing
    def route(self, req: Request) -> RoutingDecision:
        """Choose an endpoint for one request (does not dispatch — call
        :meth:`dispatch` on an accepted decision to commit it).

        Health gating: quarantined (and draining) endpoints are skipped
        outright; a probing endpoint is considered only while its
        half-open probe quota has room; a degraded endpoint stays rankable
        but its candidate is penalized by ``HealthConfig.degraded_penalty``
        — traffic shifts away gradually instead of falling off a cliff.

        When a tracer is enabled, each decision records one ``serve/route``
        span carrying a per-endpoint *explain* record — the selection
        rationale as data (lint-pruned / cold-lookup / quarantined /
        draining / scored-with-time)."""
        with get_tracer().span("route", cat="serve", track="router",
                               rid=req.rid) as span:
            decision, explain = self._route(req)
            span.set(reason=decision.reason,
                     endpoint=decision.endpoint.name
                     if decision.endpoint is not None else None,
                     considered=decision.considered,
                     service_time_s=decision.service_time_s,
                     explain=explain)
        return decision

    def _route(self, req: Request
               ) -> Tuple[RoutingDecision, List[Dict]]:
        self.metrics.on_submit(req.rid, req.arrival_s, arch=req.arch)
        cands = []
        explain: List[Dict] = []
        unavailable = 0
        for ep in self.endpoints:
            health = self.health.get(ep.name)
            if ep.draining or (health is not None and not health.available):
                unavailable += 1
                verdict = "draining" if ep.draining else \
                    ("quarantined" if health.state == QUARANTINED
                     else "probe-quota")
                explain.append({"endpoint": ep.name, "verdict": verdict})
                continue
            cand, verdict = self._score_endpoint(ep, req)
            if cand is None:
                explain.append({"endpoint": ep.name, "verdict": verdict})
                continue
            if health is not None and health.state == DEGRADED:
                pen = health.penalty
                cand.best_time_s *= pen
                if cand.mesh_time_s is not None:
                    cand.mesh_time_s *= pen
                if cand.energy_j is not None:
                    cand.energy_j *= pen
                cand.info["health"] = DEGRADED
                verdict = "scored-degraded"
            explain.append({"endpoint": ep.name, "verdict": verdict,
                            "time_s": cand.best_time_s,
                            "watts": cand.avg_watts})
            cands.append(cand)
        if not cands:
            reason = "endpoint quarantined" \
                if unavailable == len(self.endpoints) and unavailable > 0 \
                else "no feasible endpoint"
            self.metrics.on_reject(req.rid, reason)
            return RoutingDecision(req.rid, None, reason=reason), explain
        headroom = None
        if self.power_budget_w is not None:
            headroom = self.power_budget_w - self.fleet_draw_w
        ranked = self.policy.rank(cands, power_budget_w=headroom)
        ranked_eps = {c.ref.name for c in ranked}
        for ex in explain:
            if ex["verdict"].startswith("scored") \
                    and ex["endpoint"] not in ranked_eps:
                ex["verdict"] = "over-budget"
        if not ranked:
            self.metrics.on_reject(req.rid, "power budget saturated")
            return RoutingDecision(req.rid, None,
                                   reason="power budget saturated",
                                   considered=len(cands)), explain
        if req.deadline_s is not None:
            slow = [c for c in ranked if c.best_time_s > req.deadline_s]
            slow_eps = {c.ref.name for c in slow}
            for ex in explain:
                if ex["endpoint"] in slow_eps \
                        and ex["verdict"].startswith("scored"):
                    ex["verdict"] = "slo-infeasible"
            ranked = [c for c in ranked if c.best_time_s <= req.deadline_s]
            if not ranked:
                self.metrics.on_reject(req.rid, "SLO infeasible")
                return RoutingDecision(req.rid, None,
                                       reason="SLO infeasible",
                                       considered=len(cands)), explain
        for cand in ranked:
            if cand.ref.free_slots > 0:
                for ex in explain:
                    if ex["endpoint"] == cand.ref.name:
                        ex["verdict"] = "chosen"
                return RoutingDecision(
                    req.rid, cand.ref, reason="ok",
                    service_time_s=cand.best_time_s,
                    energy_j=cand.energy_j, avg_watts=cand.avg_watts,
                    considered=len(cands)), explain
        self.metrics.on_reject(req.rid, "all slots busy")
        return RoutingDecision(req.rid, None, reason="all slots busy",
                               considered=len(cands)), explain


class _NullPlanType:
    """Stand-in plan when an endpoint lints with cfg only."""
    def __getattr__(self, name):
        raise AttributeError(name)


_NULL_PLAN = None
try:
    from repro.dist.plan import Plan as _Plan
    _NULL_PLAN = _Plan()
except Exception:                               # pragma: no cover
    _NULL_PLAN = _NullPlanType()
