"""Online request router: the paper's destination choice, per request.

The offline planner (``plan_offload``) verifies destinations once per
application; at serve time the same decision repeats per request, so every
ingredient must already be warm:

  * each live :class:`Endpoint`'s plan analysis is published into a
    :class:`~repro.core.plan_lookup.PlanLookup` (by ``plan_offload(...,
    publish=...)`` or directly at endpoint registration);
  * routing a request is then: static lint prune
    (``lint_plan(serve=...)``, the PR-6 prune-before-compile contract) →
    warm payload lookup (a recorded verification *failure* refuses the
    endpoint outright) → pure-arithmetic roofline scoring
    (``score_analysis``) scaled to the request's token work →
    :class:`~repro.power.EnergyModel` watts/joules → ranking under the
    session :class:`~repro.backends.SelectionPolicy` with admission
    control from the aggregate ``power_budget_w``.

Nothing on this path traces or compiles: after warm-up, routing N requests
moves only ``CacheStats.lookups`` — ``CacheStats.misses`` (the compile
counter) stays flat, pinned by tests/test_serve_router.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.backends import SelectionPolicy, get_policy
from repro.core.candidates import Candidate
from repro.core.plan_lookup import PlanLookup, serve_key
from repro.serve.metrics import ServeMetrics
from repro.serve.request import Request


@dataclass
class Endpoint:
    """One live serving destination: a backend's machine running one arch
    under one serving plan, with a fixed continuous-batching slot pool."""
    name: str
    backend: object                 # repro.backends.Backend (duck-typed)
    arch: str
    n_chips: int = 1
    n_slots: int = 4
    cache_len: int = 256
    plan: object = None             # repro.dist.plan.Plan (serving genes)
    cfg: object = None              # ModelConfig (for the static lint)
    engine: object = None           # optional ContinuousBatcher
    # live state the router maintains
    in_flight: int = 0

    @property
    def free_slots(self) -> int:
        return max(self.n_slots - self.in_flight, 0)

    def lookup_key(self):
        return serve_key(getattr(self.backend, "name", self.name),
                         self.arch, self.plan)


@dataclass
class RoutingDecision:
    rid: str
    endpoint: Optional[Endpoint]            # None == rejected
    reason: str = ""                        # rejection reason / "ok"
    service_time_s: Optional[float] = None  # modeled prefill+decode seconds
    energy_j: Optional[float] = None
    avg_watts: Optional[float] = None
    considered: int = 0                     # endpoints that survived pruning

    @property
    def accepted(self) -> bool:
        return self.endpoint is not None


class Router:
    """Score-and-dispatch over live endpoints (see module docstring).

    ``power_budget_w`` is the *fleet* budget: admission subtracts the draw
    of requests already in flight, so a request is rejected when the
    marginal endpoint draw no longer fits — the serve-time form of the
    power follow-up's "within allowed power" selection.
    """

    def __init__(self, endpoints: List[Endpoint], lookup: PlanLookup, *,
                 policy=None, power_budget_w: Optional[float] = None,
                 metrics: Optional[ServeMetrics] = None):
        if not endpoints:
            raise ValueError("router needs at least one endpoint")
        names = [e.name for e in endpoints]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate endpoint names: {names}")
        self.endpoints = list(endpoints)
        self.lookup = lookup
        self.policy: SelectionPolicy = get_policy(policy)
        self.power_budget_w = power_budget_w
        self.metrics = metrics if metrics is not None else ServeMetrics()
        # draw currently admitted per endpoint (watts, modeled at routing)
        self._draw_w: Dict[str, float] = {e.name: 0.0 for e in endpoints}
        # admission ledger: rid -> (endpoint name, admitted draw).  The
        # slot/draw accounting releases exactly what dispatch charged, once
        # — a double complete (or completing a never-dispatched decision)
        # must not leak negative draw into admission headroom.
        self._admitted: Dict[str, Tuple[str, float]] = {}

    # ------------------------------------------------------------- state
    @property
    def fleet_draw_w(self) -> float:
        from repro.power import fleet_draw_w
        return fleet_draw_w(self._draw_w.values())

    def dispatch(self, decision: "RoutingDecision"):
        """Commit an accepted decision: occupy a slot, add its draw."""
        ep = decision.endpoint
        if ep is None:
            raise ValueError(f"cannot dispatch rejected request "
                             f"{decision.rid}")
        if decision.rid in self._admitted:
            raise ValueError(f"request {decision.rid} is already dispatched")
        ep.in_flight += 1
        draw = decision.avg_watts if decision.avg_watts is not None else 0.0
        self._draw_w[ep.name] += draw
        self._admitted[decision.rid] = (ep.name, draw)

    def complete(self, decision: "RoutingDecision") -> bool:
        """Release an admitted request's slot and draw.  Returns True when
        the request was in flight; completing a rejected, never-dispatched
        or already-completed decision is a no-op (the ledger guarantees
        ``fleet_draw_w``/``in_flight`` can never go negative)."""
        admitted = self._admitted.pop(decision.rid, None)
        if admitted is None:
            return False
        name, draw = admitted
        for ep in self.endpoints:
            if ep.name == name:
                ep.in_flight = max(ep.in_flight - 1, 0)
                break
        self._draw_w[name] = max(self._draw_w[name] - draw, 0.0)
        return True

    # ----------------------------------------------------------- scoring
    def _score_endpoint(self, ep: Endpoint,
                        req: Request) -> Optional[Candidate]:
        """Warm-path score of one endpoint for one request, or None when
        the endpoint cannot serve it (cold lookup, recorded failure, or a
        static lint error).  Pure arithmetic — no jax."""
        from repro.analysis import lint_plan
        if ep.plan is not None or ep.cfg is not None:
            findings = lint_plan(
                ep.plan if ep.plan is not None else _NULL_PLAN,
                cfg=ep.cfg,
                serve={"n_slots": ep.n_slots, "cache_len": ep.cache_len,
                       "prompt_len": req.prompt_len,
                       "max_gen": req.max_gen})
            if any(f.severity == "error" for f in findings):
                self.lookup.stats.static_pruned += 1
                return None
        payload = self.lookup.lookup(ep.lookup_key())
        if not self.lookup.usable(payload):
            return None             # cold or a recorded verification failure
        # the warm analysis describes one decode step; the request costs
        # max_gen steps plus a prefill charged as prompt work at step rate
        return Candidate.from_analysis(
            payload["analysis"], backend=ep.backend, arch=ep.arch,
            n_chips=ep.n_chips,
            scale=req.max_gen + req.prompt_len / 8.0,
            plan_key=ep.plan.structural_key() if ep.plan is not None
            else None,
            ref=ep)

    # ----------------------------------------------------------- routing
    def route(self, req: Request) -> RoutingDecision:
        """Choose an endpoint for one request (does not dispatch — call
        :meth:`dispatch` on an accepted decision to commit it)."""
        self.metrics.on_submit(req.rid, req.arrival_s)
        cands = [c for c in (self._score_endpoint(ep, req)
                             for ep in self.endpoints) if c is not None]
        if not cands:
            self.metrics.on_reject(req.rid, "no feasible endpoint")
            return RoutingDecision(req.rid, None,
                                   reason="no feasible endpoint")
        headroom = None
        if self.power_budget_w is not None:
            headroom = self.power_budget_w - self.fleet_draw_w
        ranked = self.policy.rank(cands, power_budget_w=headroom)
        if not ranked:
            self.metrics.on_reject(req.rid, "power budget saturated")
            return RoutingDecision(req.rid, None,
                                   reason="power budget saturated",
                                   considered=len(cands))
        if req.deadline_s is not None:
            ranked = [c for c in ranked if c.best_time_s <= req.deadline_s]
            if not ranked:
                self.metrics.on_reject(req.rid, "SLO infeasible")
                return RoutingDecision(req.rid, None,
                                       reason="SLO infeasible",
                                       considered=len(cands))
        for cand in ranked:
            if cand.ref.free_slots > 0:
                return RoutingDecision(
                    req.rid, cand.ref, reason="ok",
                    service_time_s=cand.best_time_s,
                    energy_j=cand.energy_j, avg_watts=cand.avg_watts,
                    considered=len(cands))
        self.metrics.on_reject(req.rid, "all slots busy")
        return RoutingDecision(req.rid, None, reason="all slots busy",
                               considered=len(cands))


class _NullPlanType:
    """Stand-in plan when an endpoint lints with cfg only."""
    def __getattr__(self, name):
        raise AttributeError(name)


_NULL_PLAN = None
try:
    from repro.dist.plan import Plan as _Plan
    _NULL_PLAN = _Plan()
except Exception:                               # pragma: no cover
    _NULL_PLAN = _NullPlanType()
