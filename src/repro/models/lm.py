"""Unified language model covering all assigned architecture families.

families: dense (granite / danube / command-r+ / nemotron), moe (moonshot /
arctic), hybrid (recurrentgemma), ssm (mamba2), vlm (llama-3.2-vision
backbone, stub image frontend), audio (seamless enc-dec backbone, stub frame
frontend).

Layer stacks are ``jax.lax.scan`` over stacked params (keeps the HLO small —
essential for the 512-device dry-run), with per-block remat controlled by the
active :class:`~repro.dist.plan.Plan`.

Entry points, bound by :class:`Model`:
  * ``train_loss(params, batch)``               -> (loss, metrics)
  * ``prefill(params, batch, cache_len)``       -> (last_logits, cache)
  * ``decode_step(params, cache, tokens, pos)`` -> (logits, cache)

The prefill path collects every layer's K/V (and recurrent/SSM final states)
as ``scan`` outputs — one pass, no per-token loop — so it lowers cleanly at
32k tokens for the dry-run.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.plan import Plan
from repro.dist.sharding import NullRules
from repro.models import layers, moe as moe_mod, rglru, ssm as ssm_mod

Params = Dict[str, Any]


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _pdt(cfg):
    return jnp.dtype(cfg.param_dtype)


# ===========================================================================
# block init / axes
# ===========================================================================

def _init_dense_block(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    ffn = (moe_mod.init_moe(ks[2], cfg, dtype) if cfg.moe is not None
           else layers.init_ffn(ks[2], cfg.d_model, cfg.d_ff, cfg.ffn_act,
                                cfg.use_bias, dtype))
    return {
        "attn_norm": layers.init_norm(ks[0], cfg.d_model, cfg.norm, dtype),
        "attn": layers.init_attn(ks[1], cfg, dtype),
        "ffn_norm": layers.init_norm(ks[3], cfg.d_model, cfg.norm, dtype),
        "ffn": ffn,
    }


def _dense_block_axes(cfg):
    ffn = (moe_mod.moe_axes(cfg) if cfg.moe is not None
           else layers.ffn_axes(cfg.ffn_act, cfg.use_bias))
    return {
        "attn_norm": layers.norm_axes(cfg.norm),
        "attn": layers.attn_axes(cfg),
        "ffn_norm": layers.norm_axes(cfg.norm),
        "ffn": ffn,
    }


def _init_cross_block(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    return {
        "attn_norm": layers.init_norm(ks[0], cfg.d_model, cfg.norm, dtype),
        "attn": layers.init_attn(ks[1], cfg, dtype),
        "ffn_norm": layers.init_norm(ks[2], cfg.d_model, cfg.norm, dtype),
        "ffn": layers.init_ffn(ks[3], cfg.d_model, cfg.d_ff, cfg.ffn_act,
                               cfg.use_bias, dtype),
    }


def _cross_block_axes(cfg):
    return {
        "attn_norm": layers.norm_axes(cfg.norm),
        "attn": layers.attn_axes(cfg),
        "ffn_norm": layers.norm_axes(cfg.norm),
        "ffn": layers.ffn_axes(cfg.ffn_act, cfg.use_bias),
    }


def _init_recurrent_block(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    return {
        "norm": layers.init_norm(ks[0], cfg.d_model, cfg.norm, dtype),
        "lru": rglru.init_rglru(ks[1], cfg, dtype),
        "ffn_norm": layers.init_norm(ks[2], cfg.d_model, cfg.norm, dtype),
        "ffn": layers.init_ffn(ks[3], cfg.d_model, cfg.d_ff, cfg.ffn_act,
                               cfg.use_bias, dtype),
    }


def _recurrent_block_axes(cfg):
    return {
        "norm": layers.norm_axes(cfg.norm),
        "lru": rglru.rglru_axes(cfg),
        "ffn_norm": layers.norm_axes(cfg.norm),
        "ffn": layers.ffn_axes(cfg.ffn_act, cfg.use_bias),
    }


def _init_ssm_block(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "norm": layers.init_norm(ks[0], cfg.d_model, cfg.norm, dtype),
        "ssm": ssm_mod.init_ssm(ks[1], cfg, dtype),
    }


def _ssm_block_axes(cfg):
    return {"norm": layers.norm_axes(cfg.norm),
            "ssm": ssm_mod.ssm_axes(cfg)}


# ===========================================================================
# whole-model init / axes
# ===========================================================================

def _stack_init(init_fn, key, n):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def vlm_groups(cfg) -> Tuple[int, int]:
    per = cfg.cross_attn_every
    return cfg.n_layers // (per + 1), per


def hybrid_groups(cfg) -> Tuple[int, int]:
    pat = cfg.hybrid.pattern
    groups = cfg.n_layers // len(pat)
    return groups, cfg.n_layers - groups * len(pat)


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = _pdt(cfg)
    ks = jax.random.split(key, 8)
    vp = cfg.padded_vocab
    p: Params = {
        "embed": layers.embed_init(ks[0], (vp, cfg.d_model), dtype),
        "final_norm": layers.init_norm(ks[1], cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = layers.dense_init(ks[2], (cfg.d_model, vp),
                                         cfg.d_model, dtype)
    fam = cfg.family
    if fam in ("dense", "moe"):
        p["blocks"] = _stack_init(
            lambda k: _init_dense_block(k, cfg, dtype), ks[3], cfg.n_layers)
    elif fam == "vlm":
        groups, per = vlm_groups(cfg)
        p["self_blocks"] = _stack_init(
            lambda k: _stack_init(
                lambda k2: _init_dense_block(k2, cfg, dtype), k, per),
            ks[3], groups)
        p["cross_blocks"] = _stack_init(
            lambda k: _init_cross_block(k, cfg, dtype), ks[4], groups)
    elif fam == "audio":
        p["enc_blocks"] = _stack_init(
            lambda k: _init_dense_block(k, cfg, dtype), ks[3],
            cfg.encoder_layers)
        p["enc_norm"] = layers.init_norm(ks[5], cfg.d_model, cfg.norm, dtype)
        p["dec_blocks"] = _stack_init(
            lambda k: {"self": _init_dense_block(k, cfg, dtype),
                       "cross": _init_cross_block(
                           jax.random.fold_in(k, 1), cfg, dtype)},
            ks[4], cfg.n_layers)
    elif fam == "hybrid":
        pat = cfg.hybrid.pattern
        groups, tail = hybrid_groups(cfg)

        def init_group(k):
            out = {}
            for i, kind in enumerate(pat):
                sub = jax.random.fold_in(k, i)
                out[f"b{i}"] = (_init_recurrent_block(sub, cfg, dtype)
                                if kind == "recurrent"
                                else _init_dense_block(sub, cfg, dtype))
            return out

        p["blocks"] = _stack_init(init_group, ks[3], groups)
        if tail:
            p["tail"] = [
                (_init_recurrent_block(jax.random.fold_in(ks[6], i), cfg,
                                       dtype)
                 if pat[i % len(pat)] == "recurrent"
                 else _init_dense_block(jax.random.fold_in(ks[6], i), cfg,
                                        dtype))
                for i in range(tail)]
    elif fam == "ssm":
        p["blocks"] = _stack_init(
            lambda k: _init_ssm_block(k, cfg, dtype), ks[3], cfg.n_layers)
    else:
        raise ValueError(f"unknown family {fam}")
    return p


def param_axes(cfg: ModelConfig) -> Params:
    """Pytree of logical-axis tuples matching init_params' structure."""

    def stack(tree):
        return jax.tree.map(lambda ax: ("layers",) + ax, tree,
                            is_leaf=lambda x: isinstance(x, tuple))

    p = {"embed": ("vocab", "embed"),
         "final_norm": layers.norm_axes(cfg.norm)}
    if not cfg.tie_embeddings:
        p["unembed"] = ("embed", "vocab")
    fam = cfg.family
    if fam in ("dense", "moe"):
        p["blocks"] = stack(_dense_block_axes(cfg))
    elif fam == "vlm":
        p["self_blocks"] = stack(stack(_dense_block_axes(cfg)))
        p["cross_blocks"] = stack(_cross_block_axes(cfg))
    elif fam == "audio":
        p["enc_blocks"] = stack(_dense_block_axes(cfg))
        p["enc_norm"] = layers.norm_axes(cfg.norm)
        p["dec_blocks"] = stack({"self": _dense_block_axes(cfg),
                                 "cross": _cross_block_axes(cfg)})
    elif fam == "hybrid":
        pat = cfg.hybrid.pattern
        group = {f"b{i}": (_recurrent_block_axes(cfg) if k == "recurrent"
                           else _dense_block_axes(cfg))
                 for i, k in enumerate(pat)}
        p["blocks"] = stack(group)
        _, tail = hybrid_groups(cfg)
        if tail:
            p["tail"] = [(_recurrent_block_axes(cfg)
                          if pat[i % len(pat)] == "recurrent"
                          else _dense_block_axes(cfg)) for i in range(tail)]
    elif fam == "ssm":
        p["blocks"] = stack(_ssm_block_axes(cfg))
    return p


# ===========================================================================
# forward blocks
# ===========================================================================

def _maybe_remat(fn, plan):
    if plan.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable
              if plan.remat == "full" else
              jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)


def _window_of(cfg) -> int:
    return cfg.window if cfg.attn_kind == "swa" else 0


def _embed(cfg, rules, params, tokens):
    h = jnp.take(params["embed"], tokens, axis=0).astype(_dt(cfg))
    scale = jnp.sqrt(jnp.float32(cfg.d_model)).astype(_dt(cfg))
    return rules.constrain(h * scale, ("batch", "seq", None))


def _apply_dense_block(p, cfg, plan, rules, h, positions, window,
                       collect=False):
    x = layers.apply_norm(p["attn_norm"], h, cfg.norm)
    q = layers.q_project(p["attn"], cfg, x)
    k, v = layers.kv_project(p["attn"], cfg, x)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    q = rules.constrain(q, ("batch", None, "heads", None))
    k = rules.constrain(k, ("batch", None, "kv_heads", None))
    attn_out = layers.attention(q, k, v, causal=True, window=window,
                                softcap=cfg.logit_softcap, plan=plan)
    h = h + rules.constrain(
        layers.out_project(p["attn"], cfg, attn_out), ("batch", None, None))
    x = layers.apply_norm(p["ffn_norm"], h, cfg.norm)
    if cfg.moe is not None:
        if plan.moe_impl == "shardmap_ep":
            y, aux = moe_mod.apply_moe_ep(p["ffn"], cfg, x, rules,
                                          plan.moe_capacity_factor)
        else:
            y, aux = moe_mod.apply_moe(p["ffn"], cfg, x, rules,
                                       plan.moe_capacity_factor,
                                       groups=plan.moe_groups)
    else:
        y, aux = layers.apply_ffn(p["ffn"], x, cfg.ffn_act, cfg.use_bias), 0.0
    h = h + rules.constrain(y, ("batch", None, None))
    kv = (k, v) if collect else None
    return h, aux, kv


def _decode_dense_block(p, cfg, plan, rules, h, cache, pos, window):
    """h [B,1,D]; cache {k,v: [B,W,KV,Dh]}; pos = absolute position scalar."""
    x = layers.apply_norm(p["attn_norm"], h, cfg.norm)
    q = layers.q_project(p["attn"], cfg, x)
    k, v = layers.kv_project(p["attn"], cfg, x)
    posv = jnp.full((h.shape[0], 1), pos, jnp.int32)
    q = layers.apply_rope(q, posv, cfg.rope_theta)
    k = layers.apply_rope(k, posv, cfg.rope_theta)
    w = cache["k"].shape[1]
    slot = (pos % w) if window else jnp.minimum(pos, w - 1)
    quant = "k_scale" in cache
    if quant:
        k, k_s = layers.quantize_kv(k)
        v, v_s = layers.quantize_kv(v)
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    k_cache = rules.constrain(k_cache, ("batch", "kv_seq", "kv_heads", None))
    v_cache = rules.constrain(v_cache, ("batch", "kv_seq", "kv_heads", None))
    cache_len = jnp.minimum(pos + 1, w)
    if quant:
        ks_cache = jax.lax.dynamic_update_slice(
            cache["k_scale"], k_s, (0, slot, 0, 0))
        vs_cache = jax.lax.dynamic_update_slice(
            cache["v_scale"], v_s, (0, slot, 0, 0))
        attn_out = layers.decode_attention_quant(
            q, k_cache, ks_cache, v_cache, vs_cache, cache_len,
            softcap=cfg.logit_softcap)
    else:
        attn_out = layers.decode_attention(q, k_cache, v_cache, cache_len,
                                           softcap=cfg.logit_softcap)
    h = h + layers.out_project(p["attn"], cfg, attn_out)
    x = layers.apply_norm(p["ffn_norm"], h, cfg.norm)
    if cfg.moe is not None:
        if plan.moe_impl == "shardmap_ep":
            y, _ = moe_mod.apply_moe_ep(p["ffn"], cfg, x, rules,
                                        plan.moe_capacity_factor)
        else:
            y, _ = moe_mod.apply_moe(p["ffn"], cfg, x, rules,
                                     plan.moe_capacity_factor,
                                     groups=plan.moe_groups)
    else:
        y = layers.apply_ffn(p["ffn"], x, cfg.ffn_act, cfg.use_bias)
    new_cache = {"k": k_cache, "v": v_cache}
    if quant:
        new_cache["k_scale"] = ks_cache
        new_cache["v_scale"] = vs_cache
    return h + y, new_cache


def _apply_cross_block(p, cfg, plan, rules, h, ctx, collect=False):
    x = layers.apply_norm(p["attn_norm"], h, cfg.norm)
    q = layers.q_project(p["attn"], cfg, x)
    k, v = layers.kv_project(p["attn"], cfg, ctx)
    attn_out = layers.dense_attention(q, k, v, causal=False)
    h = h + layers.out_project(p["attn"], cfg, attn_out)
    x = layers.apply_norm(p["ffn_norm"], h, cfg.norm)
    h = h + layers.apply_ffn(p["ffn"], x, cfg.ffn_act, cfg.use_bias)
    return (h, (k, v)) if collect else (h, None)


def _apply_cross_block_cached(p, cfg, rules, h, kc, vc):
    x = layers.apply_norm(p["attn_norm"], h, cfg.norm)
    q = layers.q_project(p["attn"], cfg, x)
    attn_out = layers.decode_attention(q, kc, vc, jnp.int32(kc.shape[1]))
    h = h + layers.out_project(p["attn"], cfg, attn_out)
    x = layers.apply_norm(p["ffn_norm"], h, cfg.norm)
    return h + layers.apply_ffn(p["ffn"], x, cfg.ffn_act, cfg.use_bias)


def _apply_recurrent_block(bp, cfg, plan, rules, h, collect=False):
    x = layers.apply_norm(bp["norm"], h, cfg.norm)
    if collect:
        y, st = rglru.apply_rglru(bp["lru"], cfg, x, rules, return_state=True)
    else:
        y, st = rglru.apply_rglru(bp["lru"], cfg, x, rules), None
    h = h + y
    x = layers.apply_norm(bp["ffn_norm"], h, cfg.norm)
    h = h + layers.apply_ffn(bp["ffn"], x, cfg.ffn_act, cfg.use_bias)
    return h, st


# ===========================================================================
# backbone (training + prefill share this; prefill collects caches)
# ===========================================================================

def _backbone(cfg, plan, rules, params, h, positions, batch, collect=False):
    """Run the layer stack. Returns (hidden, aux_loss, collected)."""
    fam = cfg.family
    window = _window_of(cfg)
    aux0 = jnp.float32(0.0)

    if fam in ("dense", "moe"):
        def body(carry, layer_p):
            hh, aux = carry
            hh, a, kv = _apply_dense_block(layer_p, cfg, plan, rules, hh,
                                           positions, window, collect)
            return (hh, aux + a), kv

        (h, aux), kvs = jax.lax.scan(_maybe_remat(body, plan), (h, aux0),
                                     params["blocks"])
        return h, aux, {"attn": kvs}

    if fam == "vlm":
        ctx = batch["img_embed"].astype(_dt(cfg))

        def group_body(carry, gp):
            hh, aux = carry

            def self_body(h2, lp):
                h2, _, kv = _apply_dense_block(lp, cfg, plan, rules, h2,
                                               positions, window, collect)
                return h2, kv

            hh, kvs = jax.lax.scan(_maybe_remat(self_body, plan), hh,
                                   gp["self"])
            hh, ckv = _apply_cross_block(gp["cross"], cfg, plan, rules, hh,
                                         ctx, collect)
            return (hh, aux), (kvs, ckv)

        (h, aux), (kvs, ckvs) = jax.lax.scan(
            group_body, (h, aux0),
            {"self": params["self_blocks"], "cross": params["cross_blocks"]})
        return h, aux, {"attn": kvs, "cross": ckvs}

    if fam == "audio":
        enc = encode_audio(cfg, plan, rules, params, batch)

        def dec_body(carry, lp):
            hh, aux = carry
            hh, a, kv = _apply_dense_block(lp["self"], cfg, plan, rules, hh,
                                           positions, window, collect)
            hh, ckv = _apply_cross_block(lp["cross"], cfg, plan, rules, hh,
                                         enc, collect)
            return (hh, aux + a), (kv, ckv)

        (h, aux), (kvs, ckvs) = jax.lax.scan(_maybe_remat(dec_body, plan),
                                             (h, aux0), params["dec_blocks"])
        return h, aux, {"attn": kvs, "cross": ckvs}

    if fam == "hybrid":
        pat = cfg.hybrid.pattern

        def group_body(carry, gp):
            hh, aux = carry
            out = {}
            for i, kind in enumerate(pat):
                if kind == "recurrent":
                    hh, st = _apply_recurrent_block(gp[f"b{i}"], cfg, plan,
                                                    rules, hh, collect)
                    out[f"b{i}"] = st
                else:
                    hh, _, kv = _apply_dense_block(gp[f"b{i}"], cfg, plan,
                                                   rules, hh, positions,
                                                   cfg.window, collect)
                    out[f"b{i}"] = kv
            return (hh, aux), out

        (h, aux), collected = jax.lax.scan(_maybe_remat(group_body, plan),
                                           (h, aux0), params["blocks"])
        tail_out = []
        for i, bp in enumerate(params.get("tail", [])):
            kind = pat[i % len(pat)]
            if kind == "recurrent":
                h, st = _apply_recurrent_block(bp, cfg, plan, rules, h,
                                               collect)
                tail_out.append(st)
            else:
                h, _, kv = _apply_dense_block(bp, cfg, plan, rules, h,
                                              positions, cfg.window, collect)
                tail_out.append(kv)
        return h, aux, {"groups": collected, "tail": tail_out}

    if fam == "ssm":
        def body(carry, lp):
            hh, aux = carry
            x = layers.apply_norm(lp["norm"], hh, cfg.norm)
            if collect:
                y, st = ssm_mod.apply_ssm(lp["ssm"], cfg, x, rules,
                                          return_state=True,
                                          chunk=plan.ssd_chunk,
                                          bf16=plan.ssd_bf16)
            else:
                y, st = ssm_mod.apply_ssm(lp["ssm"], cfg, x, rules,
                                          chunk=plan.ssd_chunk,
                                          bf16=plan.ssd_bf16), None
            return (hh + y, aux), st

        (h, aux), states = jax.lax.scan(_maybe_remat(body, plan), (h, aux0),
                                        params["blocks"])
        return h, aux, {"blocks": states}

    raise ValueError(fam)


def encode_audio(cfg, plan, rules, params, batch):
    enc = batch["frames"].astype(_dt(cfg))
    pos = jnp.arange(enc.shape[1])

    def body(hh, lp):
        x = layers.apply_norm(lp["attn_norm"], hh, cfg.norm)
        q = layers.q_project(lp["attn"], cfg, x)
        k, v = layers.kv_project(lp["attn"], cfg, x)
        q = layers.apply_rope(q, pos, cfg.rope_theta)
        k = layers.apply_rope(k, pos, cfg.rope_theta)
        hh = hh + layers.out_project(
            lp["attn"], cfg, layers.dense_attention(q, k, v, causal=False))
        x = layers.apply_norm(lp["ffn_norm"], hh, cfg.norm)
        return hh + layers.apply_ffn(lp["ffn"], x, cfg.ffn_act,
                                     cfg.use_bias), None

    enc, _ = jax.lax.scan(_maybe_remat(body, plan), enc,
                          params["enc_blocks"])
    return layers.apply_norm(params["enc_norm"], enc, cfg.norm)


# ---------------------------------------------------------------------------
# losses / logits
# ---------------------------------------------------------------------------

def _unembed_matrix(cfg, params):
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


def chunked_softmax_xent(cfg, plan, rules, params, hidden, labels):
    """Cross-entropy; the [B,S,V] logits are never fully materialized."""
    w = _unembed_matrix(cfg, params)
    b, s, d = hidden.shape
    chunk = plan.vocab_chunk or s
    chunk = min(chunk, s)
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    hc = hidden.reshape(b, nc, chunk, d)
    lc = labels.reshape(b, nc, chunk)

    @jax.checkpoint
    def step(acc, inp):
        hh, ll = inp                            # [b,chunk,d], [b,chunk]
        logits = jnp.einsum("bcd,dv->bcv", hh, w).astype(jnp.float32)
        logits = rules.constrain(logits, ("batch", None, "vocab"))
        if cfg.padded_vocab != cfg.vocab_size:
            pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad[None, None, :], layers.NEG_INF, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(step, jnp.float32(0.0),
                            (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0)))
    return total / (b * s)


def logits_for(cfg, rules, params, hidden):
    """Full logits for a short hidden slice (decode / last position)."""
    w = _unembed_matrix(cfg, params)
    logits = jnp.einsum("bsd,dv->bsv", hidden, w).astype(jnp.float32)
    logits = rules.constrain(logits, ("batch", None, "vocab"))
    if cfg.padded_vocab != cfg.vocab_size:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad[None, None, :], layers.NEG_INF, logits)
    return logits


# ===========================================================================
# decode caches
# ===========================================================================

def _kv_cache_len(cfg, seq_len):
    w = _window_of(cfg) or (cfg.window if cfg.family == "hybrid" else 0)
    return min(seq_len, w) if w else seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=None, quant: bool = False) -> Params:
    dtype = dtype or _dt(cfg)
    kvl = _kv_cache_len(cfg, seq_len)
    kv, hd = cfg.n_kv_heads, cfg.head_dim

    def kv_buf(length, *lead):
        shape = tuple(lead) + (batch, length, kv, hd)
        if quant:
            sshape = tuple(lead) + (batch, length, kv, 1)
            return {"k": jnp.zeros(shape, jnp.int8),
                    "v": jnp.zeros(shape, jnp.int8),
                    "k_scale": jnp.zeros(sshape, jnp.float32),
                    "v_scale": jnp.zeros(sshape, jnp.float32)}
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    fam = cfg.family
    if fam in ("dense", "moe"):
        return {"attn": kv_buf(kvl, cfg.n_layers)}
    def kv_buf_plain(length, *lead):
        shape = tuple(lead) + (batch, length, kv, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    if fam == "vlm":
        groups, per = vlm_groups(cfg)
        return {"attn": kv_buf(kvl, groups, per),
                "cross": kv_buf_plain(cfg.n_img_tokens, groups)}
    if fam == "audio":
        return {"attn": kv_buf(kvl, cfg.n_layers),
                "cross": kv_buf_plain(cfg.n_frames, cfg.n_layers)}
    if fam == "hybrid":
        pat = cfg.hybrid.pattern
        groups, tail = hybrid_groups(cfg)
        c: Params = {}
        for i, kind in enumerate(pat):
            if kind == "recurrent":
                base = rglru.init_rglru_cache(cfg, batch, dtype)
                c[f"b{i}"] = jax.tree.map(
                    lambda x: jnp.zeros((groups,) + x.shape, x.dtype), base)
            else:
                c[f"b{i}"] = kv_buf_plain(min(seq_len, cfg.window), groups)
        out = {"groups": c}
        if tail:
            out["tail"] = [
                (rglru.init_rglru_cache(cfg, batch, dtype)
                 if pat[i % len(pat)] == "recurrent"
                 else kv_buf_plain(min(seq_len, cfg.window)))
                for i in range(tail)]
        return out
    if fam == "ssm":
        base = ssm_mod.init_ssm_cache(cfg, batch, dtype)
        return {"blocks": jax.tree.map(
            lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype), base)}
    raise ValueError(fam)


def cache_axes(cfg: ModelConfig, quant: bool = False) -> Params:
    def kvbuf(*lead):
        out = {"k": tuple(lead) + ("batch", "kv_seq", "kv_heads", None),
               "v": tuple(lead) + ("batch", "kv_seq", "kv_heads", None)}
        if quant:
            out["k_scale"] = tuple(lead) + ("batch", "kv_seq", "kv_heads",
                                            None)
            out["v_scale"] = tuple(lead) + ("batch", "kv_seq", "kv_heads",
                                            None)
        return out
    def kvbuf_plain(*lead):
        return {"k": tuple(lead) + ("batch", "kv_seq", "kv_heads", None),
                "v": tuple(lead) + ("batch", "kv_seq", "kv_heads", None)}
    def rec_axes(*lead):
        return {"conv": tuple(lead) + ("batch", None, "lru"),
                "h": tuple(lead) + ("batch", None, "lru")}
    fam = cfg.family
    if fam in ("dense", "moe"):
        return {"attn": kvbuf("layers")}
    if fam == "vlm":
        return {"attn": kvbuf("layers", None),
                "cross": kvbuf_plain("layers")}
    if fam == "audio":
        return {"attn": kvbuf("layers"), "cross": kvbuf_plain("layers")}
    if fam == "hybrid":
        pat = cfg.hybrid.pattern
        groups_axes = {
            f"b{i}": (rec_axes("layers") if kind == "recurrent"
                      else kvbuf("layers"))
            for i, kind in enumerate(pat)}
        out = {"groups": groups_axes}
        _, tail = hybrid_groups(cfg)
        if tail:
            out["tail"] = [
                (rec_axes() if pat[i % len(pat)] == "recurrent" else kvbuf())
                for i in range(tail)]
        return out
    if fam == "ssm":
        return {"blocks": {"conv": ("layers", "batch", None, "lru"),
                           "state": ("layers", "batch", "heads", None,
                                     None)}}
    raise ValueError(fam)


def _ring_place(k_seq, buf_len, seq_len, dtype):
    """Place collected K/V [.., B, S, KV, D] into a ring buffer of buf_len.

    Token t lives at slot t % buf_len; only the last buf_len tokens are kept.
    Works for the full-cache case too (buf_len >= S: identity placement with
    zero padding at the end).
    """
    s = k_seq.shape[-3]
    if buf_len >= s:
        pad = [(0, 0)] * k_seq.ndim
        pad[-3] = (0, buf_len - s)
        return jnp.pad(k_seq.astype(dtype), pad)
    kept = k_seq[..., s - buf_len:, :, :]
    positions = jnp.arange(buf_len) + (s - buf_len)
    slots = positions % buf_len                      # a permutation
    inv = jnp.argsort(slots)
    return jnp.take(kept, inv, axis=-3).astype(dtype)


def assemble_cache(cfg, collected, batch_size, seq_len, cache_len,
                   dtype=None, quant: bool = False):
    """Turn _backbone(collect=True) outputs into a decode cache at position
    seq_len with buffer size cache_len."""
    dtype = dtype or _dt(cfg)
    kvl = _kv_cache_len(cfg, cache_len)

    def place(kv):
        k, v = kv
        if quant:
            kq, ks = layers.quantize_kv(k)
            vq, vs = layers.quantize_kv(v)
            return {"k": _ring_place(kq, kvl, seq_len, jnp.int8),
                    "v": _ring_place(vq, kvl, seq_len, jnp.int8),
                    "k_scale": _ring_place(ks, kvl, seq_len, jnp.float32),
                    "v_scale": _ring_place(vs, kvl, seq_len, jnp.float32)}
        return {"k": _ring_place(k, kvl, seq_len, dtype),
                "v": _ring_place(v, kvl, seq_len, dtype)}

    def place_win(kv):
        k, v = kv
        w = min(cache_len, cfg.window)
        return {"k": _ring_place(k, w, seq_len, dtype),
                "v": _ring_place(v, w, seq_len, dtype)}

    def cross(kv):
        k, v = kv
        return {"k": k.astype(dtype), "v": v.astype(dtype)}

    fam = cfg.family
    if fam in ("dense", "moe"):
        return {"attn": place(collected["attn"])}
    if fam == "vlm":
        return {"attn": place(collected["attn"]),
                "cross": cross(collected["cross"])}
    if fam == "audio":
        return {"attn": place(collected["attn"]),
                "cross": cross(collected["cross"])}
    if fam == "hybrid":
        pat = cfg.hybrid.pattern
        groups = {}
        for i, kind in enumerate(pat):
            groups[f"b{i}"] = (collected["groups"][f"b{i}"]
                               if kind == "recurrent"
                               else place_win(collected["groups"][f"b{i}"]))
        out = {"groups": groups}
        if collected.get("tail"):
            out["tail"] = [
                (st if pat[i % len(pat)] == "recurrent" else place_win(st))
                for i, st in enumerate(collected["tail"])]
        return out
    if fam == "ssm":
        return {"blocks": collected["blocks"]}
    raise ValueError(fam)


def init_cache_with_context(cfg, plan, rules, params, batch, batch_size,
                            cache_len):
    """Fresh decode cache with cross-attention K/V precomputed from the
    modality context (vlm: image embeddings; audio: encoder output).

    Token-by-token decoding without a text prompt still needs these — the
    cross K/V are a function of the context only, not of decoded tokens.
    """
    cache = init_cache(cfg, batch_size, cache_len,
                       quant=plan.kv_cache_quant)
    dtype = _dt(cfg)
    if cfg.family == "vlm":
        ctx = batch["img_embed"].astype(dtype)
        ks, vs = jax.vmap(lambda p: layers.kv_project(p, cfg, ctx))(
            params["cross_blocks"]["attn"])
        cache["cross"] = {"k": ks.astype(dtype), "v": vs.astype(dtype)}
    elif cfg.family == "audio":
        enc = encode_audio(cfg, plan, rules, params, batch)
        ks, vs = jax.vmap(lambda p: layers.kv_project(p, cfg, enc))(
            params["dec_blocks"]["cross"]["attn"])
        cache["cross"] = {"k": ks.astype(dtype), "v": vs.astype(dtype)}
    return cache


# ===========================================================================
# decode
# ===========================================================================

def decode_forward(cfg, plan, rules, params, cache, tokens, pos):
    """One decode step. tokens [B,1] int32; pos scalar absolute position."""
    h = _embed(cfg, rules, params, tokens)
    fam = cfg.family
    window = _window_of(cfg)

    if fam in ("dense", "moe"):
        def body(hh, xs):
            lp, lc = xs
            return _decode_dense_block(lp, cfg, plan, rules, hh, lc, pos,
                                       window)

        h, new_attn = jax.lax.scan(body, h, (params["blocks"],
                                             cache["attn"]))
        new_cache = {"attn": new_attn}
    elif fam == "vlm":
        def group_body(hh, xs):
            gp, gc_attn, gc_cross = xs

            def self_body(h2, xs2):
                lp, lc = xs2
                return _decode_dense_block(lp, cfg, plan, rules, h2, lc,
                                           pos, window)

            hh, new_self = jax.lax.scan(self_body, hh, (gp["self"], gc_attn))
            hh = _apply_cross_block_cached(gp["cross"], cfg, rules, hh,
                                           gc_cross["k"], gc_cross["v"])
            return hh, (new_self, gc_cross)

        h, (new_self, new_cross) = jax.lax.scan(
            group_body, h,
            ({"self": params["self_blocks"],
              "cross": params["cross_blocks"]},
             cache["attn"], cache["cross"]))
        new_cache = {"attn": new_self, "cross": new_cross}
    elif fam == "audio":
        def body(hh, xs):
            lp, lc_attn, lc_cross = xs
            hh, nc = _decode_dense_block(lp["self"], cfg, plan, rules, hh,
                                         lc_attn, pos, window)
            hh = _apply_cross_block_cached(lp["cross"], cfg, rules, hh,
                                           lc_cross["k"], lc_cross["v"])
            return hh, (nc, lc_cross)

        h, (new_attn, new_cross) = jax.lax.scan(
            body, h, (params["dec_blocks"], cache["attn"], cache["cross"]))
        new_cache = {"attn": new_attn, "cross": new_cross}
    elif fam == "hybrid":
        pat = cfg.hybrid.pattern

        def group_body(hh, xs):
            gp, gc = xs
            new_gc = {}
            for i, kind in enumerate(pat):
                bp = gp[f"b{i}"]
                if kind == "recurrent":
                    x = layers.apply_norm(bp["norm"], hh, cfg.norm)
                    y, nc = rglru.decode_rglru(bp["lru"], cfg, x,
                                               gc[f"b{i}"], rules)
                    hh = hh + y
                    x = layers.apply_norm(bp["ffn_norm"], hh, cfg.norm)
                    hh = hh + layers.apply_ffn(bp["ffn"], x, cfg.ffn_act,
                                               cfg.use_bias)
                else:
                    hh, nc = _decode_dense_block(bp, cfg, plan, rules, hh,
                                                 gc[f"b{i}"], pos,
                                                 cfg.window)
                new_gc[f"b{i}"] = nc
            return hh, new_gc

        h, new_groups = jax.lax.scan(group_body, h,
                                     (params["blocks"], cache["groups"]))
        new_cache = {"groups": new_groups}
        if "tail" in params:
            new_tail = []
            for i, bp in enumerate(params["tail"]):
                kind = pat[i % len(pat)]
                tc = cache["tail"][i]
                if kind == "recurrent":
                    x = layers.apply_norm(bp["norm"], h, cfg.norm)
                    y, nc = rglru.decode_rglru(bp["lru"], cfg, x, tc, rules)
                    h = h + y
                    x = layers.apply_norm(bp["ffn_norm"], h, cfg.norm)
                    h = h + layers.apply_ffn(bp["ffn"], x, cfg.ffn_act,
                                             cfg.use_bias)
                else:
                    h, nc = _decode_dense_block(bp, cfg, plan, rules, h, tc,
                                                pos, cfg.window)
                new_tail.append(nc)
            new_cache["tail"] = new_tail
    elif fam == "ssm":
        def body(hh, xs):
            lp, lc = xs
            x = layers.apply_norm(lp["norm"], hh, cfg.norm)
            y, nc = ssm_mod.decode_ssm(lp["ssm"], cfg, x, lc, rules)
            return hh + y, nc

        h, new_blocks = jax.lax.scan(body, h, (params["blocks"],
                                               cache["blocks"]))
        new_cache = {"blocks": new_blocks}
    else:
        raise ValueError(fam)

    h = layers.apply_norm(params["final_norm"], h, cfg.norm)
    logits = logits_for(cfg, rules, params, h)[:, 0]
    return logits, new_cache


# ===========================================================================
# public API
# ===========================================================================

class Model:
    """Binds (cfg, plan, rules) into callable train/serve functions."""

    def __init__(self, cfg: ModelConfig, plan: Optional[Plan] = None,
                 rules=None):
        self.cfg = cfg
        self.plan = plan or Plan()
        self.rules = rules or NullRules()

    def init(self, key) -> Params:
        return init_params(key, self.cfg)

    def param_axes(self) -> Params:
        return param_axes(self.cfg)

    def train_loss(self, params, batch) -> Tuple[jax.Array, Dict]:
        cfg, plan, rules = self.cfg, self.plan, self.rules
        tokens = batch["tokens"]
        h = _embed(cfg, rules, params, tokens)
        positions = jnp.arange(tokens.shape[1])
        h, aux, _ = _backbone(cfg, plan, rules, params, h, positions, batch)
        h = layers.apply_norm(params["final_norm"], h, cfg.norm)
        loss = chunked_softmax_xent(cfg, plan, rules, params, h,
                                    batch["labels"])
        total = loss + 0.01 * aux
        return total, {"loss": loss, "aux_loss": aux}

    def prefill(self, params, batch, cache_len: int):
        """Full-prompt pass; returns (last_logits, decode cache)."""
        cfg, plan, rules = self.cfg, self.plan, self.rules
        tokens = batch["tokens"]
        b, s = tokens.shape
        h = _embed(cfg, rules, params, tokens)
        positions = jnp.arange(s)
        h, _, collected = _backbone(cfg, plan, rules, params, h, positions,
                                    batch, collect=True)
        h = layers.apply_norm(params["final_norm"], h, cfg.norm)
        last = logits_for(cfg, rules, params, h[:, -1:])[:, 0]
        cache = assemble_cache(cfg, collected, b, s, cache_len,
                               quant=plan.kv_cache_quant)
        return last, cache

    def decode_step(self, params, cache, tokens, pos):
        return decode_forward(self.cfg, self.plan, self.rules, params, cache,
                              tokens, pos)

    def init_context_cache(self, params, batch, batch_size, cache_len):
        return init_cache_with_context(self.cfg, self.plan, self.rules,
                                       params, batch, batch_size, cache_len)
