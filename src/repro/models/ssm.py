"""Mamba-2 (SSD — state-space duality) block, chunked matmul formulation.

TPU adaptation: the SSD algorithm is expressed as chunk-local masked matmuls
(MXU work) plus a sequential inter-chunk state recurrence (length S/chunk),
exactly the "matrix-form" duality from arXiv:2405.21060 — no per-token scan,
so the MXU does nearly all the FLOPs and the recurrence touches only the
[H, N, P] chunk states.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import layers


def init_ssm(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = s.n_groups * s.d_state
    ks = jax.random.split(key, 6)
    in_dim = 2 * di + 2 * gn + nh  # z, x, B, C, dt
    p = {
        "w_in": layers.dense_init(ks[0], (d, in_dim), d, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_kernel, di + 2 * gn),
                                     jnp.float32) * 0.1).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, float(nh), nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "w_out": layers.dense_init(ks[2], (di, d), di, dtype),
        "norm_scale": jnp.ones((di,), dtype),
    }
    return p


def ssm_axes(cfg):
    return {
        "w_in": ("embed", "lru"),
        "conv_w": (None, "lru"),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "w_out": ("lru", "embed"),
        "norm_scale": (None,),
    }


def _split_in(cfg, h):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    gn = s.n_groups * s.d_state
    nh = s.n_heads(cfg.d_model)
    z, x, bc, dt = jnp.split(h, [di, 2 * di, 2 * di + 2 * gn], axis=-1)
    b_, c_ = jnp.split(bc, 2, axis=-1)
    return z, x, b_, c_, dt, di, gn, nh


def _causal_conv(x, w, state=None):
    """x [B,S,C], w [K,C] depthwise causal conv. state [B,K-1,C] for decode."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else pad
    return jax.nn.silu(out), new_state


def apply_ssm(p, cfg, hidden, rules, return_state=False, chunk=0,
              bf16=False):
    """Training/prefill path. hidden [B,S,D] -> [B,S,D]."""
    s = cfg.ssm
    b, S, _ = hidden.shape
    q = min(chunk or s.chunk, S)
    assert S % q == 0, f"seq {S} must divide chunk {q}"
    nc = S // q

    h = jnp.einsum("bsd,de->bse", hidden, p["w_in"])
    z, x, B_, C_, dt, di, gn, nh = _split_in(cfg, h)
    conv_in = jnp.concatenate([x, B_, C_], -1)
    xbc, conv_state = _causal_conv(conv_in, p["conv_w"])
    x, B_, C_ = jnp.split(xbc, [di, di + gn], axis=-1)

    P = s.headdim
    N = s.d_state
    G = s.n_groups
    x = x.reshape(b, S, nh, P)
    B_ = B_.reshape(b, S, G, N)
    C_ = C_.reshape(b, S, G, N)
    # broadcast groups to heads
    rep = nh // G
    Bh = jnp.repeat(B_, rep, axis=2)         # [b,S,nh,N]
    Ch = jnp.repeat(C_, rep, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [b,S,nh]
    A = -jnp.exp(p["A_log"])                                      # [nh]
    dA = dt * A                                                   # [b,S,nh] (log-decay)

    # chunk
    xc = x.reshape(b, nc, q, nh, P)
    Bc = Bh.reshape(b, nc, q, nh, N)
    Cc = Ch.reshape(b, nc, q, nh, N)
    dtc = dt.reshape(b, nc, q, nh)
    dAc = dA.reshape(b, nc, q, nh)
    cum = jnp.cumsum(dAc, axis=2)                                 # [b,nc,q,nh]

    # intra-chunk (diagonal block): L[i,j] = exp(cum_i - cum_j) for i >= j
    # `ct` controls the big [b,nc,q,q,nh] intermediates: f32 for exactness,
    # bf16 (MXU-native, f32 accumulate) under Plan.ssd_bf16.
    ct = jnp.bfloat16 if bf16 else jnp.float32
    li = cum[:, :, :, None, :]                                    # i
    lj = cum[:, :, None, :, :]                                    # j
    mask = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    decay = jnp.exp(jnp.where(mask, li - lj, -jnp.inf)).astype(ct)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cc.astype(ct), Bc.astype(ct),
                        preferred_element_type=ct) * decay
    xdt = (xc.astype(jnp.float32) * dtc[..., None]).astype(ct)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", scores, xdt,
                        preferred_element_type=jnp.float32)

    # chunk states: S_c = sum_j exp(cum_last - cum_j) B_j (x_j dt_j)^T
    seg = jnp.exp(cum[:, :, -1:, :] - cum).astype(ct)             # [b,nc,q,nh]
    states = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp",
                        Bc.astype(ct), seg, xdt,
                        preferred_element_type=jnp.float32)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                       # [b,nc,nh]

    # inter-chunk recurrence over nc chunk states
    def step(prev, inp):
        st, dec = inp
        new = st + dec[:, :, None, None] * prev
        return new, prev

    init = jnp.zeros((b, nh, N, P), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                 # [b,nc,h,N,P]

    y_inter = jnp.einsum("bcihn,bchnp->bcihp",
                         Cc.astype(jnp.float32) * jnp.exp(cum)[..., None],
                         prev_states)
    y = (y_diag + y_inter).reshape(b, S, nh, P)
    y = y + x.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, S, di).astype(hidden.dtype)

    # gated RMSNorm (Mamba-2 style): norm(y * silu(z))
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + 1e-6)
         * p["norm_scale"].astype(jnp.float32)).astype(hidden.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    if return_state:
        return out, {"conv": conv_state.astype(hidden.dtype),
                     "state": final_state}
    return out


def init_ssm_cache(cfg, batch, dtype=jnp.float32):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    gn = s.n_groups * s.d_state
    nh = s.n_heads(cfg.d_model)
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, di + 2 * gn), dtype),
        "state": jnp.zeros((batch, nh, s.d_state, s.headdim), jnp.float32),
    }


def decode_ssm(p, cfg, hidden, cache, rules):
    """Single-token decode. hidden [B,1,D]."""
    s = cfg.ssm
    b = hidden.shape[0]
    h = jnp.einsum("bsd,de->bse", hidden, p["w_in"])
    z, x, B_, C_, dt, di, gn, nh = _split_in(cfg, h)
    xbc, conv_state = _causal_conv(
        jnp.concatenate([x, B_, C_], -1), p["conv_w"], cache["conv"])
    x, B_, C_ = jnp.split(xbc, [di, di + gn], axis=-1)
    P, N, G = s.headdim, s.d_state, s.n_groups
    rep = nh // G
    x = x.reshape(b, nh, P)
    Bh = jnp.repeat(B_.reshape(b, G, N), rep, axis=1)
    Ch = jnp.repeat(C_.reshape(b, G, N), rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32).reshape(b, nh) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * A)                                          # [b,nh]
    st = cache["state"] * da[:, :, None, None] + jnp.einsum(
        "bhn,bh,bhp->bhnp", Bh.astype(jnp.float32), dt, x.astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), st)
    y = y + x.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(b, 1, di).astype(hidden.dtype)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + 1e-6)
         * p["norm_scale"].astype(jnp.float32)).astype(hidden.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, {"conv": conv_state, "state": st}
