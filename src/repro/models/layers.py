"""Core layers: norms, RoPE, attention (dense / blockwise / decode), FFNs.

Pure-functional JAX; params are plain dicts.  Every layer has a matching
``*_axes`` helper returning the logical sharding axes for its params so the
distribution layer can build PartitionSpecs without touching array data.

Attention uses grouped-GQA einsums throughout: KV heads are never
materialized repeated-per-query-head (q is reshaped [B,S,KV,rep,Dh] instead),
which keeps the HBM bytes in §Roofline honest.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free
                 # when a row is fully masked (ring-buffer warmup, padding).

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(max(in_axis_size, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(key, d, kind, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_axes(kind):
    p = {"scale": (None,)}
    if kind == "layernorm":
        p["bias"] = (None,)
    return p


def apply_norm(p, x, kind, eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta):
    """x [B, S, H, Dh]; positions [S] or [B, S] (int)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                        # [half]
    pos = positions.astype(jnp.float32)
    if pos.ndim == 1:
        pos = pos[None, :]
    angles = pos[..., :, None] * freqs                            # [B?,S,half]
    cos = jnp.cos(angles)[..., :, None, :]                        # [B?,S,1,half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype)], axis=-1)


# ---------------------------------------------------------------------------
# attention params
# ---------------------------------------------------------------------------

def init_attn(key, cfg, dtype, kv_d_model=None):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dk = kv_d_model or d
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), d, dtype),
        "wk": dense_init(ks[1], (dk, kv, hd), dk, dtype),
        "wv": dense_init(ks[2], (dk, kv, hd), dk, dtype),
        "wo": dense_init(ks[3], (h, hd, d), h * hd, dtype),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def attn_axes(cfg):
    p = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv_heads", None),
        "wv": ("embed", "kv_heads", None),
        "wo": ("heads", None, "embed"),
    }
    if cfg.use_bias:
        p.update({"bq": ("heads", None), "bk": ("kv_heads", None),
                  "bv": ("kv_heads", None), "bo": (None,)})
    return p


def q_project(p, cfg, x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.use_bias:
        q = q + p["bq"]
    return q


def kv_project(p, cfg, x):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.use_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


def out_project(p, cfg, attn_out):
    y = jnp.einsum("bshk,hkd->bsd", attn_out, p["wo"])
    if cfg.use_bias:
        y = y + p["bo"]
    return y


def _group_q(q, n_kv):
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def repeat_kv(k, n_rep):
    """[B,S,KV,D] -> [B,S,KV*n_rep,D] (repeat each kv head n_rep times)."""
    if n_rep == 1:
        return k
    b, s, kv, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, d)) \
              .reshape(b, s, kv * n_rep, d)


# ---------------------------------------------------------------------------
# dense attention (small-seq path)
#
# grouped=True uses the grouped-GQA einsum (never materializes repeated KV —
# best single-device bytes); grouped=False repeats KV to the full head count
# first, which keeps the *query-head* dim shardable on the model axis (the
# grouped layout splits H into (KV, rep), neither of which may divide the
# axis — e.g. 8 kv heads on a 16-way axis replicate the S^2 score tensor).
# ---------------------------------------------------------------------------

def dense_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset: int = 0, softcap: float = 0.0,
                    grouped: bool = True):
    """q [B,Sq,H,Dh], k/v [B,Skv,KV,Dh]."""
    kvh = k.shape[2]
    scale = 1.0 / math.sqrt(q.shape[-1])
    if not grouped:
        k = repeat_kv(k, q.shape[2] // kvh)
        v = repeat_kv(v, q.shape[2] // kvh)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    else:
        qg = _group_q(q, kvh)
        scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(jnp.float32)
    scores = scores * scale
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    pad = (None,) * (scores.ndim - 2)
    scores = jnp.where(mask[pad], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    if not grouped:
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
        return out
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v)
    return out.reshape(q.shape)


# ---------------------------------------------------------------------------
# blockwise (flash-style) attention: full compute, O(S*block) memory.
# Pure JAX, differentiable; the Pallas kernel in repro.kernels.flash_attention
# is the FPGA-analogue replacement for this block.
# ---------------------------------------------------------------------------

def blockwise_attention(q, k, v, *, causal: bool, window: int = 0,
                        q_offset: int = 0, block_q: int = 512,
                        block_kv: int = 512, grouped: bool = True):
    if not grouped:                      # shardable-head layout (see above)
        k = repeat_kv(k, q.shape[2] // k.shape[2])
        v = repeat_kv(v, q.shape[2] // v.shape[2])
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    block_q = min(block_q, sq)
    block_kv = min(block_kv, sk)
    pq = (-sq) % block_q
    pk = (-sk) % block_kv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = q.shape[1] // block_q, k.shape[1] // block_kv
    qb = q.reshape(b, nq, block_q, kvh, rep, dh)
    kb = k.reshape(b, nk, block_kv, kvh, dh)
    vb = v.reshape(b, nk, block_kv, kvh, dh)
    scale = 1.0 / math.sqrt(dh)

    def q_block(qi, qblk):                       # qblk [b,block_q,kvh,rep,dh]
        qpos = q_offset + qi * block_q + jnp.arange(block_q)

        def kv_step(carry, inputs):
            m, lsum, acc = carry
            ki, kblk, vblk = inputs
            kpos = ki * block_kv + jnp.arange(block_kv)
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qblk, kblk)
            s = s.astype(jnp.float32) * scale
            mask = jnp.ones((block_q, block_kv), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window:
                mask &= qpos[:, None] - kpos[None, :] < window
            mask &= (kpos < sk)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = lsum * corr + p.sum(axis=-1)
            pv = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(qblk.dtype), vblk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, rep, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, rep, block_q), jnp.float32)
        a0 = jnp.zeros((b, kvh, rep, block_q, dh), qblk.dtype)
        (m, lsum, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        lsum = jnp.maximum(lsum, 1e-20)
        out = acc / lsum[..., None].astype(acc.dtype)   # [b,g,r,q,dh]
        return jnp.moveaxis(out, 3, 1)               # [b,q,g,r,dh]

    out = jax.lax.map(lambda args: q_block(*args),
                      (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * block_q, h, dh)
    return out[:, :sq]


def attention(q, k, v, *, causal: bool, window: int = 0, q_offset: int = 0,
              softcap: float = 0.0, plan=None):
    """Dispatch dense vs blockwise based on the plan threshold."""
    grouped = plan.gqa_grouped if plan is not None else True
    if plan is not None and q.shape[1] >= plan.blockwise_attn_threshold:
        return blockwise_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset,
                                   block_q=plan.attn_block_q,
                                   block_kv=plan.attn_block_kv,
                                   grouped=grouped)
    return dense_attention(q, k, v, causal=causal, window=window,
                           q_offset=q_offset, softcap=softcap,
                           grouped=grouped)


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (per-token, per-head scales)
#
# The scales factor out of both attention einsums (scores ∝ k_scale[k];
# fold v_scale into probs), so the bf16 cache is never re-materialized —
# HBM reads stay int8.
# ---------------------------------------------------------------------------

def quantize_kv(x):
    """x [..., D] -> (int8 [..., D], scale [..., 1] f32)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127,
                 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decode_attention_quant(q, k_q, k_scale, v_q, v_scale, cache_len, *,
                           softcap: float = 0.0):
    """q [B,1,H,Dh]; k_q/v_q int8 [B,S,KV,Dh]; scales [B,S,KV,1]."""
    kvh = k_q.shape[2]
    qg = _group_q(q, kvh)
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg,
                        k_q.astype(q.dtype)).astype(jnp.float32)
    # fold per-(token, head) k scales into the scores
    ks = k_scale[..., 0]                                # [B,S,KV]
    scores = scores * jnp.transpose(ks, (0, 2, 1))[:, :, None, None, :]
    scores = scores * scale
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    kpos = jnp.arange(k_q.shape[1])
    valid = kpos < cache_len
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    vs = v_scale[..., 0]                                # [B,S,KV]
    probs = probs * jnp.transpose(vs, (0, 2, 1))[:, :, None, None, :]
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs.astype(q.dtype),
                     v_q.astype(q.dtype))
    return out.reshape(q.shape)


# ---------------------------------------------------------------------------
# decode attention over a KV cache (one new token per call)
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     softcap: float = 0.0):
    """q [B,1,H,Dh]; caches [B,S,KV,Dh]; cache_len = #valid entries.

    For ring-buffer (windowed) caches every stored entry is valid once the
    ring wraps; validity is simply ``kpos < cache_len`` with cache_len capped
    at the buffer size by the caller.
    """
    kvh = k_cache.shape[2]
    qg = _group_q(q, kvh)
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k_cache).astype(jnp.float32)
    scores = scores * scale
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    kpos = jnp.arange(k_cache.shape[1])
    valid = kpos < cache_len
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v_cache)
    return out.reshape(q.shape)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def init_ffn(key, d, hidden, act, use_bias, dtype):
    gated = act in ("swiglu", "geglu")
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], (d, hidden), d, dtype),
         "w_out": dense_init(ks[1], (hidden, d), hidden, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], (d, hidden), d, dtype)
    if use_bias:
        p["b_in"] = jnp.zeros((hidden,), dtype)
        p["b_out"] = jnp.zeros((d,), dtype)
    return p


def ffn_axes(act, use_bias):
    gated = act in ("swiglu", "geglu")
    p = {"w_in": ("embed", "ff"), "w_out": ("ff", "embed")}
    if gated:
        p["w_gate"] = ("embed", "ff")
    if use_bias:
        p["b_in"] = ("ff",)
        p["b_out"] = (None,)
    return p


def apply_ffn(p, x, act, use_bias=False):
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    if use_bias:
        h = h + p["b_in"]
    if act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = jax.nn.silu(g) * h
    elif act == "geglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = jax.nn.gelu(g) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(f"unknown act {act}")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_out"])
    if use_bias:
        y = y + p["b_out"]
    return y
