"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Sort-based (not dense one-hot) dispatch keeps the dispatch buffer at
[E, C, D] instead of [T, E, C]: tokens are ordered by expert id, position-
within-expert is computed from segment offsets, and tokens beyond the
per-expert capacity are dropped (standard GShard semantics).  Experts are
sharded over the ``model`` axis (EP); the scatter from token-sharded to
expert-sharded layout is where GSPMD emits the all-to-all that §Roofline
tracks.

Supports Moonlight-style shared experts and Arctic-style dense-residual FFN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def init_moe(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    ek = jax.random.split(ks[1], m.n_experts)
    experts = jax.vmap(
        lambda k: layers.init_ffn(k, d, m.d_expert, cfg.ffn_act, False, dtype)
    )(ek)
    p = {"router": layers.dense_init(ks[0], (d, m.n_experts), d, dtype),
         "experts": experts}
    if m.shared_experts:
        p["shared"] = layers.init_ffn(
            ks[2], d, m.d_expert * m.shared_experts, cfg.ffn_act, False, dtype)
    if m.dense_residual:
        p["dense"] = layers.init_ffn(
            ks[3], d, m.dense_d_ff or cfg.d_ff, cfg.ffn_act, False, dtype)
    return p


def moe_axes(cfg):
    m = cfg.moe
    gated = cfg.ffn_act in ("swiglu", "geglu")
    expert_axes = {"w_in": ("experts", "embed", "ff"),
                   "w_out": ("experts", "ff", "embed")}
    if gated:
        expert_axes["w_gate"] = ("experts", "embed", "ff")
    p = {"router": ("embed", None), "experts": expert_axes}
    if m.shared_experts:
        p["shared"] = layers.ffn_axes(cfg.ffn_act, False)
    if m.dense_residual:
        p["dense"] = layers.ffn_axes(cfg.ffn_act, False)
    return p


def _expert_ffn(p, x, act):
    """x [E, C, D] with per-expert weights stacked on dim 0."""
    h = jnp.einsum("ecd,edf->ecf", x, p["w_in"])
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, p["w_gate"])) * h
    elif act == "geglu":
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, p["w_gate"])) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    return jnp.einsum("ecf,efd->ecd", h, p["w_out"])


def _expert_ffn_grouped(p, x, act):
    """x [G, E, C, D] with per-expert weights stacked on dim 1."""
    h = jnp.einsum("gecd,edf->gecf", x, p["w_in"])
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", x, p["w_gate"])) * h
    elif act == "geglu":
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", x, p["w_gate"])) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    return jnp.einsum("gecf,efd->gecd", h, p["w_out"])


def apply_moe(p, cfg, x, rules, capacity_factor=None, groups: int = 1):
    """x [B,S,D] -> [B,S,D].

    GShard-style grouped dispatch: tokens are split into `groups` groups
    (aligned with the data shards), capacity is per-group, and the dispatch
    buffer is [G, E, C_g, D] with G on the data axes and E on the expert
    axis — the G<->E re-sharding boundary is where GSPMD emits the MoE
    all-to-all.  groups=1 degenerates to a single global group.
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    g = max(int(groups), 1)
    if t % g != 0:
        g = 1
    tg = t // g
    cf = capacity_factor or m.capacity_factor
    capacity = max(int(tg * m.top_k * cf / m.n_experts), m.top_k)

    tokens = rules.constrain(x.reshape(g, tg, d), ("batch", None, None))
    logits = jnp.einsum("gtd,de->gte", tokens,
                        p["router"]).astype(jnp.float32)
    gates, expert_ids = jax.lax.top_k(logits, m.top_k)         # [g,tg,k]
    gates = jax.nn.softmax(gates, axis=-1)

    # per-group (token, k) pairs sorted by expert id
    fe = expert_ids.reshape(g, tg * m.top_k)
    order = jnp.argsort(fe, axis=1)                             # stable
    se = jnp.take_along_axis(fe, order, axis=1)                 # [g, tg*k]
    st = order // m.top_k
    sg = jnp.take_along_axis(gates.reshape(g, tg * m.top_k), order, axis=1)

    counts = jax.vmap(lambda v: jnp.bincount(v, length=m.n_experts))(se)
    starts = jnp.cumsum(counts, axis=1) - counts                # [g, E]
    pos = jnp.arange(tg * m.top_k)[None, :] \
        - jnp.take_along_axis(starts, se, axis=1)
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, 0)

    # dispatch: [G, E, C, D]; G on data axes, E on the expert axis
    vals = jnp.where(keep[..., None],
                     jnp.take_along_axis(tokens, st[..., None], axis=1),
                     0).astype(x.dtype)
    gi = jnp.broadcast_to(jnp.arange(g)[:, None], se.shape)
    buf = jnp.zeros((g, m.n_experts, capacity, d), x.dtype)
    buf = buf.at[gi, se, pos_c].add(vals)
    buf = rules.constrain(buf, ("batch", "experts", None, None))

    out_buf = _expert_ffn_grouped(p["experts"], buf, cfg.ffn_act)
    out_buf = rules.constrain(out_buf, ("batch", "experts", None, None))

    # combine: gather back to token layout, weight by gate
    gathered = out_buf[gi, se, pos_c]                           # [g,tg*k,D]
    gathered = jnp.where(keep[..., None], gathered, 0)
    combined = jnp.zeros((g, tg, d), x.dtype).at[
        gi, st].add((gathered.astype(jnp.float32)
                     * sg[..., None]).astype(x.dtype))
    combined = rules.constrain(combined, ("batch", None, None))
    y = combined.reshape(b, s, d)

    if m.shared_experts:
        y = y + layers.apply_ffn(p["shared"], x, cfg.ffn_act)
    if m.dense_residual:
        y = y + layers.apply_ffn(p["dense"], x, cfg.ffn_act)

    # aux: load-balance loss term (Switch-style), returned via metric hook
    me = jnp.mean(jax.nn.softmax(logits, -1), axis=(0, 1))       # [E]
    ce = counts.sum(axis=0).astype(jnp.float32) / (t * m.top_k)
    aux = m.n_experts * jnp.sum(me * ce)
    return y, aux


def apply_moe_ep(p, cfg, x, rules, capacity_factor=None):
    """Explicit expert-parallel MoE via shard_map over the `model` axis.

    Tokens are replicated across `model` (standard TP residual stream), so
    each model rank routes every token locally, runs ONLY its E/ep local
    experts, and the single collective is a psum of the partial outputs —
    the GSPMD scatter/gather formulation above turns the same dataflow into
    full-buffer masked all-reduces (~100x more wire bytes; see
    EXPERIMENTS.md §Perf moonshot iterations).

    Falls back to apply_moe when no mesh / non-divisible experts.
    """
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.dist.compat import shard_map
    from repro.dist.sharding import batch_axes

    m = cfg.moe
    mesh = getattr(rules, "mesh", None)
    ep = mesh.shape.get("model", 1) if mesh is not None else 1
    if mesh is None or ep == 1 or m.n_experts % ep != 0:
        return apply_moe(p, cfg, x, rules, capacity_factor)
    e_loc = m.n_experts // ep
    dp = batch_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    b, s, d = x.shape
    if b % dp_size != 0:
        return apply_moe(p, cfg, x, rules, capacity_factor)
    t_loc = (b // dp_size) * s
    cf = capacity_factor or m.capacity_factor
    # per-(data-shard, expert) capacity — the deployed-MoE semantics
    capacity = max(int(t_loc * m.top_k * cf / m.n_experts), m.top_k)

    def body(tokens, router, experts):
        # fully manual: tokens is THIS data shard's slice [b/dp, s, d];
        # experts is this model rank's slice [E/ep, d, f]; routing, sort and
        # dispatch are all local — the only collective is the output psum.
        rank = jax.lax.axis_index("model")
        off = rank * e_loc
        # f32 at the boundary: replicated-input cotangents are psum'ed in
        # bwd and 16-bit all-reduce promotion crashes XLA:CPU
        tokens = tokens.astype(x.dtype)
        router = router.astype(x.dtype)
        tk = tokens.reshape(t_loc, d)
        logits = jnp.einsum("td,de->te", tk, router).astype(jnp.float32)
        gates, idx = jax.lax.top_k(logits, m.top_k)             # [t,k]
        gates = jax.nn.softmax(gates, axis=-1)

        fe = idx.reshape(-1)
        fg = gates.reshape(-1)
        ft = jnp.repeat(jnp.arange(t_loc), m.top_k)
        mine = (fe >= off) & (fe < off + e_loc)
        le = jnp.where(mine, fe - off, e_loc)                   # e_loc=drop
        order = jnp.argsort(le)                                 # mine first
        le_s, ft_s, fg_s = le[order], ft[order], fg[order]
        counts = jnp.bincount(le_s, length=e_loc + 1)[:e_loc]
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(t_loc * m.top_k) - jnp.take(
            jnp.append(starts, 0), jnp.minimum(le_s, e_loc))
        keep = (le_s < e_loc) & (pos < capacity)
        le_c = jnp.where(keep, le_s, 0)
        pos_c = jnp.where(keep, pos, 0)

        buf = jnp.zeros((e_loc, capacity, d), tokens.dtype)
        buf = buf.at[le_c, pos_c].add(
            jnp.where(keep[:, None], tk[ft_s], 0).astype(tokens.dtype))
        out_buf = _expert_ffn(experts, buf, cfg.ffn_act)
        gathered = jnp.where(keep[:, None], out_buf[le_c, pos_c], 0)
        partial = jnp.zeros((t_loc, d), jnp.float32).at[ft_s].add(
            gathered.astype(jnp.float32) * fg_s[:, None])
        y = jax.lax.psum(partial, "model").astype(tokens.dtype)
        y = y.reshape(tokens.shape)

        me = jnp.mean(jax.nn.softmax(logits, -1), axis=0)       # [E]
        ce_loc = counts.astype(jnp.float32) / (t_loc * m.top_k)
        aux_partial = m.n_experts * jnp.sum(
            jax.lax.dynamic_slice(me, (off,), (e_loc,)) * ce_loc)
        aux = jax.lax.psum(aux_partial, "model")
        aux = jax.lax.pmean(aux, dp)
        return y, aux

    experts_spec = jax.tree.map(lambda _: P("model"), p["experts"])
    manual = set(dp) | {"model"}
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp), P(), experts_spec),
        out_specs=(P(dp), P()),
        axis_names=manual, check_vma=False,
    )(x.astype(jnp.float32), p["router"].astype(jnp.float32), p["experts"])

    if m.shared_experts:
        y = y + layers.apply_ffn(p["shared"], x, cfg.ffn_act)
    if m.dense_residual:
        y = y + layers.apply_ffn(p["dense"], x, cfg.ffn_act)
    return y, aux
