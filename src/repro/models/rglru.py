"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

TPU adaptation: the diagonal linear recurrence h_t = a_t * h_{t-1} + b_t is
computed with ``jax.lax.associative_scan`` (log-depth, VPU-friendly) instead
of a CUDA per-timestep kernel; the projections around it are MXU matmuls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

_C = 8.0  # Griffin's fixed recurrence-sharpness constant


def init_rglru(key, cfg, dtype):
    d = cfg.d_model
    w = cfg.hybrid.lru_width or d
    ks = jax.random.split(key, 6)
    return {
        "w_x": layers.dense_init(ks[0], (d, w), d, dtype),       # input branch
        "w_gate": layers.dense_init(ks[1], (d, w), d, dtype),    # GeGLU branch
        "conv_w": (jax.random.normal(ks[2], (cfg.hybrid.conv_kernel, w),
                                     jnp.float32) * 0.1).astype(dtype),
        "w_rg": layers.dense_init(ks[3], (w, w), w, dtype),      # recurrence gate
        "w_ig": layers.dense_init(ks[4], (w, w), w, dtype),      # input gate
        # Lambda init so a^c spans ~(0.9, 0.999)
        "lam": jnp.log(jnp.expm1(
            jnp.linspace(0.3, 1.4, w).astype(jnp.float32))),
        "w_out": layers.dense_init(ks[5], (w, d), w, dtype),
    }


def rglru_axes(cfg):
    return {"w_x": ("embed", "lru"), "w_gate": ("embed", "lru"),
            "conv_w": (None, "lru"), "w_rg": ("lru", None),
            "w_ig": ("lru", None), "lam": (None,),
            "w_out": ("lru", "embed")}


def _gates(p, x):
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x, p["w_rg"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x, p["w_ig"])
                       .astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(p["lam"])                   # [b,s,w] <= 0
    a = jnp.exp(log_a)
    gated_x = x.astype(jnp.float32) * i
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * gated_x
    return a, b


def _conv(x, w, state=None):
    from repro.models.ssm import _causal_conv
    out, new_state = _causal_conv(x, w, state)
    return out, new_state


def apply_rglru(p, cfg, hidden, rules, return_state=False):
    """hidden [B,S,D] -> [B,S,D] (full-sequence path)."""
    x = jnp.einsum("bsd,dw->bsw", hidden, p["w_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", hidden, p["w_gate"]))
    x, conv_state = _conv(x, p["conv_w"])
    a, b = _gates(p, x)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    av, bv = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = bv  # h_t with h_0 = 0
    y = (h.astype(hidden.dtype) * gate)
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"])
    if return_state:
        return out, {"conv": conv_state.astype(hidden.dtype),
                     "h": h[:, -1:, :]}
    return out


def init_rglru_cache(cfg, batch, dtype=jnp.float32):
    w = cfg.hybrid.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.hybrid.conv_kernel - 1, w), dtype),
        "h": jnp.zeros((batch, 1, w), jnp.float32),
    }


def decode_rglru(p, cfg, hidden, cache, rules):
    """Single-token decode. hidden [B,1,D]."""
    x = jnp.einsum("bsd,dw->bsw", hidden, p["w_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", hidden, p["w_gate"]))
    x, conv_state = _conv(x, p["conv_w"], cache["conv"])
    a, b = _gates(p, x)
    h = a * cache["h"] + b
    y = (h.astype(hidden.dtype) * gate)
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"])
    return out, {"conv": conv_state, "h": h}
