"""repro.runtime — keep the selected destination honest while it runs.

  * :mod:`repro.runtime.fault_tolerance` — degrade-and-continue execution
    (:class:`StragglerWatchdog`, ``run_resilient``).
  * :mod:`repro.runtime.elastic` — reshard-on-restore across mesh sizes;
    :class:`ResizeEvent` / :func:`detect_resize` signal capacity changes.
  * :mod:`repro.runtime.control` — the online fleet control loop
    (:class:`FleetController`, :class:`FaultInjector`,
    :class:`ControlLoop`) closing plan -> serve -> observe -> replan.

Exports resolve lazily (PEP 562): importing :mod:`repro.runtime` pulls in
no jax and does not eagerly import submodules, so the pure-arithmetic
pieces (health, control) stay importable in jit-poisoned tests and
lightweight tools.
"""
from typing import TYPE_CHECKING

_EXPORTS = {
    "Fault": "repro.runtime.control",
    "FaultInjector": "repro.runtime.control",
    "FleetController": "repro.runtime.control",
    "ControlLoop": "repro.runtime.control",
    "StragglerWatchdog": "repro.runtime.fault_tolerance",
    "ResizeEvent": "repro.runtime.elastic",
    "detect_resize": "repro.runtime.elastic",
}

__all__ = sorted(_EXPORTS)

if TYPE_CHECKING:                               # pragma: no cover
    from repro.runtime.control import (ControlLoop, Fault,  # noqa: F401
                                       FaultInjector, FleetController)
    from repro.runtime.elastic import (ResizeEvent,  # noqa: F401
                                       detect_resize)
    from repro.runtime.fault_tolerance import (  # noqa: F401
        StragglerWatchdog)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
