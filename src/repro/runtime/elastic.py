"""Elastic scaling: resume any checkpoint onto a different mesh.

Checkpoints store full (global) arrays, so resharding is a pure placement
decision at restore time.  ``reshard_restore`` rebuilds the sharding pytree
for the *new* mesh from the model's logical axes and restores onto it —
scale from 512 chips to 256 (or to this CPU host) without conversion.

:class:`ResizeEvent` / :func:`detect_resize` are the signal side: an edge
detector over the live device count that the online fleet controller
(:class:`repro.runtime.control.FleetController.on_resize`) consumes to
trigger a placement replan when a slice is lost or regained.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.dist.plan import Plan
from repro.dist.sharding import Rules, tree_shardings


@dataclass(frozen=True)
class ResizeEvent:
    """One observed change in usable capacity (devices, chips, slots)."""
    tick: int
    n_before: int
    n_after: int

    @property
    def grew(self) -> bool:
        return self.n_after > self.n_before


def detect_resize(prev_n: Optional[int], n: int,
                  tick: int = 0) -> Optional[ResizeEvent]:
    """Edge-detect a capacity change: None while the count is stable (or
    on the first observation), a :class:`ResizeEvent` on any transition —
    the elastic-restart signal the fleet controller replans on."""
    if prev_n is None or prev_n == n:
        return None
    return ResizeEvent(tick=tick, n_before=prev_n, n_after=n)


def shardings_for(cfg, mesh, plan: Plan, tree_sds, axes_tree):
    rules = Rules(mesh, plan)
    return tree_shardings(rules, axes_tree, tree_sds)


def reshard_restore(ckpt: Checkpointer, *, step: Optional[int],
                    new_mesh, plan: Plan, cfg, make_abstract,
                    axes_tree) -> Any:
    """Restore checkpoint `step` re-sharded for `new_mesh`.

    make_abstract() -> pytree of ShapeDtypeStruct matching the saved tree.
    """
    sds = make_abstract()
    shardings = shardings_for(cfg, new_mesh, plan, sds, axes_tree)
    tree, extra = ckpt.restore(step, shardings=shardings)
    return tree, extra


def available_mesh(preferred_shape=None, axes=("data", "model")):
    """Best mesh for the devices that are actually alive (elastic restart
    after losing a slice): largest power-of-two data axis x rest."""
    from repro.dist.compat import AxisType, mesh_from_devices
    n = len(jax.devices())
    if preferred_shape is not None:
        need = 1
        for s in preferred_shape:
            need *= s
        if need <= n:
            return mesh_from_devices(
                jax.devices()[:need], preferred_shape, axes,
                axis_types=(AxisType.Auto,) * len(axes))
    # fall back: 1-D data mesh over whatever is left
    return mesh_from_devices(jax.devices(), (n, 1), axes,
                             axis_types=(AxisType.Auto,) * len(axes))
