"""Online fleet control: close the plan -> serve -> observe -> replan loop.

The source paper verifies an offload destination once, offline; the
mixed-destination environment it targets keeps changing after selection —
machines die, slow down, or start returning wrong results.  This module is
the controller that keeps the serve-time system honest:

  * :class:`Fault` / :class:`FaultInjector` — pluggable fault plans on the
    same virtual tick clock the engine uses (``ContinuousBatcher.tick_s``),
    so chaos scenarios are byte-for-byte reproducible: an endpoint dies at
    tick T, runs kx slower for a window, returns a wrong result (the
    online form of a verification failure), or spikes its power draw.
  * :class:`FleetController` — folds observed per-arch load and realized
    draw from :class:`~repro.serve.ServeMetrics` back into the
    :class:`~repro.fleet.FleetApp` estimates, calls
    :meth:`~repro.fleet.FleetPlanner.replan` on quarantine / degradation /
    elastic-resize events, and migrates by *draining* endpoints through
    the Router's admission ledger — in-flight requests always complete,
    pinned by test: zero dropped, zero double-completed across a
    migration, ``fleet_draw_w`` never negative.
  * :class:`ControlLoop` — a deterministic tick simulator wiring Router,
    FaultInjector and FleetController together; the substrate of
    ``tests/test_control.py`` and ``benchmarks/chaos.py``.

The whole loop re-scores through :class:`~repro.core.plan_lookup.PlanLookup`
+ :meth:`Candidate.from_analysis <repro.core.candidates.Candidate
.from_analysis>` only — zero new traces or compiles, pinned by a
jit-poisoned test exactly like the router's and the fleet planner's.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.fleet.placement import (FleetApp, FleetPlanner, Placement,
                                   observed_apps)
from repro.obs import get_tracer
from repro.serve.batching import DEFAULT_TICK_S
from repro.serve.health import DEGRADED, HEALTHY, QUARANTINED
from repro.serve.request import Request
from repro.serve.router import Endpoint, Router, RoutingDecision

KILL = "kill"
LATENCY = "latency"
WRONG_RESULT = "wrong_result"
POWER_SPIKE = "power_spike"

FAULT_KINDS = (KILL, LATENCY, WRONG_RESULT, POWER_SPIKE)


@dataclass(frozen=True)
class Fault:
    """One planned fault: ``endpoint`` misbehaves as ``kind`` from
    ``at_tick`` (inclusive) to ``until_tick`` (exclusive; None = forever).

    ``factor`` is the latency multiplier for ``latency`` faults and the
    added watts for ``power_spike`` faults; ignored otherwise.
    """
    kind: str
    endpoint: str
    at_tick: int
    until_tick: Optional[int] = None
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.until_tick is not None and self.until_tick <= self.at_tick:
            raise ValueError(f"empty fault window "
                             f"[{self.at_tick}, {self.until_tick})")

    def active(self, tick: int) -> bool:
        return tick >= self.at_tick and \
            (self.until_tick is None or tick < self.until_tick)


class FaultInjector:
    """Pure function of (endpoint, tick) -> fault effects.

    Holds a static fault plan; queries never mutate state, so any chaos
    scenario replays identically from the same plan and trace.
    """

    def __init__(self, faults: Sequence[Fault] = ()):
        self.faults: List[Fault] = list(faults)

    def add(self, fault: Fault) -> "FaultInjector":
        self.faults.append(fault)
        return self

    def _active(self, endpoint: str, tick: int, kind: str):
        for f in self.faults:
            if f.kind == kind and f.endpoint == endpoint and f.active(tick):
                yield f

    def is_dead(self, endpoint: str, tick: int) -> bool:
        """Requests in flight on a dead endpoint fail; new ones will too."""
        return any(True for _ in self._active(endpoint, tick, KILL))

    def latency_factor(self, endpoint: str, tick: int) -> float:
        """Multiplier on service time (overlapping windows compound)."""
        out = 1.0
        for f in self._active(endpoint, tick, LATENCY):
            out *= f.factor
        return out

    def wrong_result(self, endpoint: str, tick: int) -> bool:
        """The endpoint completes but its output fails verification."""
        return any(True for _ in self._active(endpoint, tick, WRONG_RESULT))

    def power_spike_w(self, endpoint: str, tick: int) -> float:
        """Extra observed watts beyond the modeled draw."""
        return sum(f.factor for f in self._active(endpoint, tick,
                                                  POWER_SPIKE))


class FleetController:
    """Fold serve-time observations back into fleet placement.

    Owns three feedback paths, all on the deterministic tick clock:

      * **observe** — :meth:`on_complete` accumulates per-arch completed
        counts; :meth:`observed_apps` rewrites the declared
        ``FleetApp.load_rps`` estimates with observed requests/s (via
        :func:`repro.fleet.observed_apps`) before every replan.
      * **replan** — :meth:`step` watches every endpoint's health
        transitions; a new quarantine triggers
        :meth:`FleetPlanner.replan` with that endpoint's pool backend
        failed (survivors stay pinned), a degradation or an elastic
        resize triggers a full re-plan over the currently usable pool.
      * **migrate** — when a replan stops using a pool backend the
        previous placement used, its healthy endpoints are *drained*
        (:meth:`Router.drain`): no new dispatches, in-flight requests
        complete through the admission ledger, and :meth:`step` removes
        the endpoint only once :meth:`Router.drained` reports the ledger
        empty.  Quarantined endpoints are never drained — their half-open
        probes are the path back into service.
    """

    def __init__(self, router: Router, planner: FleetPlanner,
                 apps: Sequence[FleetApp], *,
                 placement: Optional[Placement] = None,
                 tick_s: float = DEFAULT_TICK_S,
                 pool_name_of: Optional[Callable[[Endpoint], str]] = None):
        self.router = router
        self.planner = planner
        self.apps = list(apps)
        self.placement = placement
        self.tick_s = float(tick_s)
        self.pool_name_of = pool_name_of if pool_name_of is not None \
            else (lambda ep: getattr(ep.backend, "name", ep.name))
        self.events: List[Dict] = []
        self.replans = 0
        # per-arch completion observations: n requests over [first, last]
        self._obs: Dict[str, Dict[str, float]] = {}
        # realized energy per completed request (arch -> joules, count)
        self._seen_transitions: Dict[str, int] = {}
        self._prev_used: Optional[set] = \
            set(placement.by_app.values()) if placement is not None else None

    # ------------------------------------------------------------- observe
    def on_complete(self, req: Request, endpoint: str, latency_s: float,
                    tick: int):
        """One request finished service: feed the per-arch load estimate."""
        rec = self._obs.setdefault(
            req.arch, {"n": 0.0, "first": float(tick), "last": float(tick)})
        rec["n"] += 1.0
        rec["last"] = float(tick)

    def observed_load_rps(self) -> Dict[str, float]:
        """Observed requests/s per arch over each arch's completion span."""
        loads: Dict[str, float] = {}
        for arch, rec in self._obs.items():
            span_s = max(rec["last"] - rec["first"], 1.0) * self.tick_s
            loads[arch] = rec["n"] / span_s
        return loads

    def observed_apps(self) -> List[FleetApp]:
        """The declared apps with observed load folded in (estimates stand
        in where nothing completed yet)."""
        return observed_apps(self.apps, self.observed_load_rps())

    # -------------------------------------------------------------- replan
    def _usable_mask(self) -> List[bool]:
        """Pool backends that currently have at least one endpoint neither
        quarantined nor draining (backends with no endpoint at all stay
        usable: standby capacity the planner may call up)."""
        state: Dict[str, bool] = {}
        for ep in self.router.endpoints:
            pool = self.pool_name_of(ep)
            h = self.router.health.get(ep.name)
            ok = not ep.draining and \
                (h is None or h.state != QUARANTINED)
            state[pool] = state.get(pool, False) or ok
        return [state.get(pb.name, True) for pb in self.planner.pool]

    def replan(self, tick: int, failed: Optional[str] = None) -> Placement:
        """Re-place the fleet from observed load.  ``failed`` names a pool
        backend that just dropped: survivors stay pinned
        (:meth:`FleetPlanner.replan`); otherwise a full plan runs over the
        usable pool.  Always followed by drain-based migration."""
        with get_tracer().span("replan", cat="control", track="control",
                               tick=tick, failed=failed) as span:
            apps = self.observed_apps()
            # verdicts may have changed since the last plan (a wrong result
            # published a failure): the planner's memo must not outlive them
            self.planner._cand_cache.clear()
            pool_names = {pb.name for pb in self.planner.pool}
            if failed is not None and failed in pool_names \
                    and self.placement is not None:
                placement = self.planner.replan(apps, self.placement,
                                                failed)
            else:
                placement = self.planner.plan(apps,
                                              usable=self._usable_mask())
            self.replans += 1
            self.events.append({"tick": tick, "event": "replan",
                                "failed": failed,
                                "feasible": placement.feasible,
                                "by_app": dict(placement.by_app),
                                "fleet_draw_w": placement.fleet_draw_w})
            self._migrate(tick, placement)
            self.placement = placement
            self._prev_used = set(placement.by_app.values())
            span.set(feasible=placement.feasible,
                     by_app=dict(placement.by_app),
                     fleet_draw_w=placement.fleet_draw_w)
        return placement

    def _migrate(self, tick: int, placement: Placement):
        """Drain healthy endpoints on pool backends the previous placement
        used but the new one does not.  Never drains quarantined or
        probing endpoints (recovery owns those) and never drops in-flight
        work — the ledger keeps every admitted request completable."""
        if self._prev_used is None:
            return
        freed = self._prev_used - set(placement.by_app.values())
        for ep in list(self.router.endpoints):
            if self.pool_name_of(ep) not in freed or ep.draining:
                continue
            h = self.router.health.get(ep.name)
            if h is not None and h.state not in (HEALTHY, DEGRADED):
                continue
            self.router.drain(ep.name)
            in_flight = self.router.in_flight_of(ep.name)
            self.events.append({"tick": tick, "event": "drain",
                                "endpoint": ep.name,
                                "in_flight": in_flight})
            get_tracer().event("drain", cat="control", track="control",
                               tick=tick, endpoint=ep.name,
                               in_flight=in_flight)

    # ---------------------------------------------------------------- step
    def step(self, tick: int):
        """One control tick: advance every circuit timer, react to new
        health transitions, finalize completed drains."""
        for h in self.router.health.values():
            h.on_tick(tick)
        quarantined: List[str] = []
        degraded = False
        for name in list(self.router.health):
            h = self.router.health[name]
            seen = self._seen_transitions.get(name, 0)
            for tr in h.transitions[seen:]:
                self.events.append({"tick": tick, "event": "health",
                                    "endpoint": name, **tr})
                if tr["to"] == QUARANTINED:
                    quarantined.append(name)
                elif tr["to"] == DEGRADED:
                    degraded = True
            self._seen_transitions[name] = len(h.transitions)
        for name in quarantined:
            ep = self.router.endpoint(name)
            pool = self.pool_name_of(ep) if ep is not None else None
            self.replan(tick, failed=pool)
        if degraded and not quarantined:
            self.replan(tick)
        for ep in list(self.router.endpoints):
            if ep.draining and self.router.drained(ep.name):
                self.router.remove_endpoint(ep.name)
                self.events.append({"tick": tick, "event": "removed",
                                    "endpoint": ep.name})
                get_tracer().event("migrated", cat="control",
                                   track="control", tick=tick,
                                   endpoint=ep.name)

    # -------------------------------------------------------------- resize
    def on_resize(self, event) -> Placement:
        """An elastic capacity change (:class:`repro.runtime.elastic
        .ResizeEvent`): log it and re-plan over the usable pool."""
        self.events.append({"tick": event.tick, "event": "resize",
                            "n_before": event.n_before,
                            "n_after": event.n_after})
        return self.replan(event.tick)


class ControlLoop:
    """Deterministic tick simulator closing route -> dispatch -> observe.

    Each tick, in a fixed order so runs replay exactly:

      1. **arrivals** — requests whose arrival tick passed join the queue;
      2. **failures** — in-flight requests on endpoints the
         :class:`FaultInjector` declares dead fail now
         (:meth:`Router.fail` feeds the circuit breaker) and re-queue
         (up to ``max_retries``, then they count as *dropped*);
      3. **completions** — in-flight requests whose service time elapsed
         complete; a ``wrong_result`` fault turns the completion into a
         failure *and* publishes the failure verdict into the lookup
         (``register_failure``), so every later scoring pass — router and
         fleet planner alike — statically refuses that destination;
      4. **routing** — queued requests route and dispatch; the modeled
         service time (stretched by any active latency fault) schedules
         the completion tick.  Refused requests stay queued;
      5. **control** — ``controller.step`` (or bare health ``on_tick``):
         circuit timers, replans, drain finalization.

    ``summary()`` reports completions, drops, double completions (must be
    zero — the ledger is idempotent), refusal counts, the fleet-draw
    trace, and per-endpoint dispatch counts.
    """

    def __init__(self, router: Router, requests: Sequence[Request], *,
                 controller: Optional[FleetController] = None,
                 injector: Optional[FaultInjector] = None,
                 tick_s: float = DEFAULT_TICK_S, max_retries: int = 3,
                 max_ticks: int = 10_000):
        self.router = router
        self.controller = controller
        self.injector = injector if injector is not None else FaultInjector()
        self.tick_s = float(tick_s)
        self.max_retries = int(max_retries)
        self.max_ticks = int(max_ticks)
        self._pending: List[Request] = sorted(
            requests, key=lambda r: (r.arrival_s, r.rid))
        self.queue: Deque[Request] = deque()
        # rid -> (decision, dispatch tick, completion tick, request)
        self.inflight: Dict[str, Tuple[RoutingDecision, int, int, Request]]\
            = {}
        self.completed_ok = 0
        self.failed = 0
        self.dropped: List[str] = []
        self.double_completed = 0
        self.dispatches: Dict[str, int] = {}
        self.dispatch_log: List[Tuple[int, str, str]] = []
        self.draw_trace: List[float] = []
        self.ticks_run = 0

    # ------------------------------------------------------------ plumbing
    def _requeue(self, req: Request):
        req.retries += 1
        if req.retries > self.max_retries:
            self.dropped.append(req.rid)
        else:
            self.queue.appendleft(req)      # retries route before new work

    def _fail(self, rid: str, tick: int, reason: str):
        decision, t0, _, req = self.inflight.pop(rid)
        self.failed += 1
        get_tracer().complete_span(
            "request", t0 * self.tick_s, tick * self.tick_s, cat="serve",
            track=f"endpoint:{decision.endpoint.name}", rid=rid, ok=False,
            reason=reason, retries=req.retries)
        self.router.fail(decision, reason=reason, now_s=tick * self.tick_s)
        self._requeue(req)

    # ---------------------------------------------------------------- tick
    def _tick(self, tick: int):
        # pin the tracer to the virtual clock: every record this tick
        # emits — health transitions, replans, GA generations inside a
        # replan — is stamped with the tick time, so a replayed scenario
        # produces a byte-identical event log
        get_tracer().set_time(tick * self.tick_s)
        # 1. arrivals
        while self._pending and \
                self._pending[0].arrival_s <= tick * self.tick_s + 1e-12:
            self.queue.append(self._pending.pop(0))
        # 2. failures: endpoints that are dead right now kill their flight
        for rid in list(self.inflight):
            name = self.inflight[rid][0].endpoint.name
            if self.injector.is_dead(name, tick):
                self._fail(rid, tick, "endpoint died")
        # 3. completions
        for rid in list(self.inflight):
            decision, t0, t1, req = self.inflight[rid]
            if t1 > tick:
                continue
            name = decision.endpoint.name
            if self.injector.wrong_result(name, tick):
                # the online analogue of a verification failure: fail the
                # request AND publish the verdict so every later scoring
                # pass refuses this destination statically
                self.router.lookup.register_failure(
                    decision.endpoint.lookup_key(),
                    f"wrong result observed at tick {tick}")
                self._fail(rid, tick, "wrong result")
                continue
            del self.inflight[rid]
            latency_s = (tick - t0) * self.tick_s
            if not self.router.complete(decision, latency_s=latency_s,
                                        now_s=tick * self.tick_s):
                self.double_completed += 1
                continue
            self.completed_ok += 1
            get_tracer().complete_span(
                "request", t0 * self.tick_s, tick * self.tick_s,
                cat="serve", track=f"endpoint:{name}", rid=rid, ok=True,
                latency_s=latency_s, energy_j=decision.energy_j)
            if self.controller is not None:
                self.controller.on_complete(req, name, latency_s, tick)
        # 4. routing
        still_queued: List[Request] = []
        while self.queue:
            req = self.queue.popleft()
            decision = self.router.route(req)
            if not decision.accepted:
                still_queued.append(req)    # wait; circuit may close later
                continue
            self.router.dispatch(decision)
            name = decision.endpoint.name
            stretch = self.injector.latency_factor(name, tick)
            service = (decision.service_time_s or self.tick_s) * stretch
            n_ticks = max(int(math.ceil(service / self.tick_s)), 1)
            self.inflight[req.rid] = (decision, tick, tick + n_ticks, req)
            self.dispatches[name] = self.dispatches.get(name, 0) + 1
            self.dispatch_log.append((tick, req.rid, name))
        self.queue.extend(still_queued)
        # 5. observe draw (modeled admitted draw + any injected spike)
        spike = sum(self.injector.power_spike_w(ep.name, tick)
                    for ep in self.router.endpoints)
        self.draw_trace.append(self.router.fleet_draw_w + spike)
        # 6. control
        if self.controller is not None:
            self.controller.step(tick)
        else:
            for h in self.router.health.values():
                h.on_tick(tick)
        # one instant per tick with the cumulative counters the post-mortem
        # trends on (cache hit-rate, joules/request, fleet draw)
        stats = self.router.lookup.stats
        get_tracer().event(
            "tick", cat="loop", track="loop", tick=tick,
            completed=self.completed_ok, failed=self.failed,
            queued=len(self.queue), inflight=len(self.inflight),
            draw_w=self.draw_trace[-1],
            energy_j=self.router.metrics.total_energy_j,
            lookups=stats.lookups, lookup_hits=stats.hits)

    # ----------------------------------------------------------------- run
    def run(self) -> dict:
        for tick in range(self.max_ticks):
            self._tick(tick)
            self.ticks_run = tick + 1
            if not self._pending and not self.inflight and not self.queue:
                break
            # queued requests with everything quarantined keep waiting:
            # the circuit's half-open probes are their way back in, and
            # max_ticks bounds the wait deterministically
        return self.summary()

    def summary(self) -> dict:
        return {
            "ticks": self.ticks_run,
            "completed": self.completed_ok,
            "failed": self.failed,
            "dropped": list(self.dropped),
            "double_completed": self.double_completed,
            "unrouted": len(self.queue),
            "dispatches": dict(self.dispatches),
            "refusals": dict(self.router.metrics.refusals),
            "fleet_draw_w_max": max(self.draw_trace, default=0.0),
            "fleet_draw_w_min": min(self.draw_trace, default=0.0),
            "events": list(self.controller.events)
            if self.controller is not None else [],
        }
