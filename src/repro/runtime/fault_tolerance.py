"""Fault-tolerant training runtime: auto-resume, retry with emergency
checkpoints, straggler watchdog, elastic restart.

On a real pod, failures surface as raised exceptions from collectives /
device halts; here the same control flow is exercised by fault-injection
hooks (tests inject exceptions at chosen steps).
"""
from __future__ import annotations

import logging
import time
from collections import deque
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Callable, Deque, Dict, List, Optional

if TYPE_CHECKING:                                 # jax-free import path:
    from repro.checkpoint.checkpointer import Checkpointer
    # repro.serve.health reuses the watchdog on the serve hot path, so the
    # heavyweight checkpointer (jax) import stays lazy in run_resilient

log = logging.getLogger("repro.runtime")


@dataclass
class StragglerWatchdog:
    """Step-time EWMA + z-score straggler/anomaly detector.

    On multi-host deployments each host feeds its own step time; a rank
    whose time exceeds mean + threshold*std across the window is flagged
    (-> report for the scheduler to replace the node).  Single-process here:
    flags slow *steps*, the same statistics path.  The serve-time health
    state machine (repro.serve.health) runs one per endpoint over observed
    request latencies; ``reset()`` starts a fresh window when an endpoint
    recovers, so post-recovery statistics are never judged against the
    degraded regime.
    """
    window: int = 50
    threshold: float = 3.0
    ewma_alpha: float = 0.1
    times: Deque[float] = field(default_factory=deque)
    ewma: Optional[float] = None
    flagged: List[Dict] = field(default_factory=list)

    def __post_init__(self):
        # bounded ring buffer: append evicts the oldest sample for free
        self.times = deque(self.times, maxlen=self.window)

    def record(self, step: int, dt: float) -> bool:
        import statistics
        self.times.append(dt)
        self.ewma = dt if self.ewma is None else \
            self.ewma_alpha * dt + (1 - self.ewma_alpha) * self.ewma
        if len(self.times) >= 10:
            prior = list(self.times)[:-1]
            mu = statistics.fmean(prior)
            sd = statistics.pstdev(prior) or 1e-9
            if dt > mu + self.threshold * sd:
                self.flagged.append({"step": step, "dt": dt, "mean": mu,
                                     "std": sd})
                log.warning("straggler step %d: %.3fs (mean %.3fs)",
                            step, dt, mu)
                return True
        return False

    def reset(self):
        """Start a fresh window (per-endpoint reuse after recovery): the
        sample window and EWMA restart cold; ``flagged`` keeps its history
        — past flags are a record, not current state."""
        self.times.clear()
        self.ewma = None


@dataclass
class ResilientLoopResult:
    last_step: int
    restarts: int
    metrics_history: List[dict]
    watchdog: StragglerWatchdog


def run_resilient(
    *,
    total_steps: int,
    checkpointer: "Checkpointer",
    init_state: Callable[[], Any],
    step_fn: Callable[[Any, int], tuple],        # (state, step) -> (state, metrics)
    save_every: int = 50,
    max_restarts: int = 3,
    state_shardings: Any = None,
    fault_hook: Optional[Callable[[int], None]] = None,
    async_checkpoint: bool = True,
) -> ResilientLoopResult:
    """Checkpointed training loop with automatic retry + resume.

    * resumes from the latest checkpoint if one exists;
    * on exception: emergency-saves nothing (state may be poisoned), rolls
      back to the last good checkpoint and retries, up to ``max_restarts``;
    * straggler watchdog records every step time.
    """
    watchdog = StragglerWatchdog()
    restarts = 0
    history: List[dict] = []

    def load_or_init():
        last = checkpointer.latest_step()
        if last is not None:
            state, extra = checkpointer.restore(last,
                                                shardings=state_shardings)
            log.info("resumed from step %d", last)
            return state, int(extra.get("next_step", last))
        return init_state(), 0

    state, step = load_or_init()
    while step < total_steps:
        try:
            t0 = time.perf_counter()
            if fault_hook is not None:
                fault_hook(step)
            state, metrics = step_fn(state, step)
            dt = time.perf_counter() - t0
            watchdog.record(step, dt)
            history.append({"step": step, "dt": dt, **{
                k: float(v) for k, v in (metrics or {}).items()
                if hasattr(v, "__float__") or isinstance(v, (int, float))}})
            step += 1
            if step % save_every == 0 or step == total_steps:
                if async_checkpoint:
                    checkpointer.async_save(step, state,
                                            {"next_step": step})
                else:
                    checkpointer.save(step, state, {"next_step": step})
        except KeyboardInterrupt:
            raise
        except Exception as e:
            restarts += 1
            log.error("step %d failed (%r); restart %d/%d", step, e,
                      restarts, max_restarts)
            if restarts > max_restarts:
                checkpointer.wait()
                raise
            checkpointer.wait()
            state, step = load_or_init()
    checkpointer.wait()
    return ResilientLoopResult(last_step=step, restarts=restarts,
                               metrics_history=history, watchdog=watchdog)
