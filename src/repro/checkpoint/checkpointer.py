"""Sharded, atomic, async checkpointing with reshard-on-restore.

Layout:   <dir>/step_<n>.tmp/  ->  (atomic rename)  ->  <dir>/step_<n>/
            manifest.json        tree structure, shapes, dtypes, metadata
            leaf_<i>.npy         one file per leaf (full/global array)

Restore takes optional shardings: the full arrays are re-placed under
whatever mesh the restoring job runs — a checkpoint written on a (2,16,16)
mesh restores onto (16,16) or a single host unchanged (elastic restart).
Writes can run on a background thread (``async_save``); ``wait()`` joins.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _tree_flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat, treedef = _tree_flatten_with_paths(tree)
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
            if hasattr(jax.tree_util.tree_structure(tree),
                       "serialize_using_proto") else None,
            "n_leaves": len(flat),
            "leaves": [],
            "extra": extra or {},
            "time": time.time(),
        }
        for i, leaf in enumerate(flat):
            arr = np.asarray(jax.device_get(leaf))
            true_dtype = str(arr.dtype)
            if arr.dtype.kind == "V" or true_dtype not in (
                    "float64", "float32", "float16", "int64", "int32",
                    "int16", "int8", "uint64", "uint32", "uint16", "uint8",
                    "bool", "complex64", "complex128"):
                # ml_dtypes (bfloat16/fp8/...) don't survive np.save;
                # store the raw bits and re-view on load
                view = {1: np.uint8, 2: np.uint16, 4: np.uint32,
                        8: np.uint64}[arr.dtype.itemsize]
                np.save(tmp / f"leaf_{i}.npy", arr.view(view))
            else:
                np.save(tmp / f"leaf_{i}.npy", arr)
            manifest["leaves"].append(
                {"index": i, "shape": list(arr.shape),
                 "dtype": true_dtype})
        # structure via example pytree pickled as json paths
        import pickle
        with open(tmp / "treedef.pkl", "wb") as f:
            pickle.dump(treedef, f)
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                       # atomic publish
        self._gc()
        return final

    def async_save(self, step: int, tree: Any,
                   extra: Optional[dict] = None):
        # snapshot to host first (cheap on CPU; on TPU this is the D2H copy)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self.wait()

        def work():
            try:
                self.save(step, host_tree, extra)
            except BaseException as e:   # surfaced at next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ---------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                 if not p.name.endswith(".tmp")]
        return max(steps) if steps else None

    def restore(self, step: Optional[int] = None,
                shardings: Any = None) -> tuple:
        """Returns (tree, extra). shardings: matching pytree of NamedSharding
        (or None leaves) — enables restore onto a different mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step}"
        manifest = json.loads((path / "manifest.json").read_text())
        import pickle
        with open(path / "treedef.pkl", "rb") as f:
            treedef = pickle.load(f)
        leaves = []
        for i in range(manifest["n_leaves"]):
            arr = np.load(path / f"leaf_{i}.npy")
            want = manifest["leaves"][i]["dtype"]
            if str(arr.dtype) != want:
                import ml_dtypes
                target = getattr(ml_dtypes, want, None) or np.dtype(want)
                arr = arr.view(target)
            leaves.append(arr)
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else
                jax.numpy.asarray(x), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree, manifest["extra"]

    # --------------------------------------------------------------- gc
    def _gc(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.dir.glob("step_*")
                       if not p.name.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)
