"""AdamW with ZeRO-style sharded moments and warmup+cosine schedule.

Moments inherit the parameter sharding (params are already FSDP+TP sharded
under the plan, so m/v are fully sharded — ZeRO-1 falls out of GSPMD).
``master_dtype`` controls moment precision; an optional fp32 master copy of
the params supports pure-bf16 param storage at pod scale.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def lr_schedule(tcfg: TrainConfig, step):
    warm = jnp.minimum(step / jnp.maximum(tcfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - tcfg.warmup_steps)
                 / jnp.maximum(tcfg.total_steps - tcfg.warmup_steps, 1),
                 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return tcfg.lr * warm * (0.1 + 0.9 * cos)


def init(params, tcfg: TrainConfig) -> Dict[str, Any]:
    mdt = jnp.dtype(tcfg.master_dtype)

    def zeros(p):
        return jnp.zeros(p.shape, mdt)

    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if tcfg.use_master_copy:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def opt_state_axes(par_axes, tcfg: TrainConfig):
    """Logical axes for the optimizer state (moments mirror params)."""
    state = {
        "m": par_axes,
        "v": par_axes,
        "count": (),
    }
    if tcfg.use_master_copy:
        state["master"] = par_axes
    return state


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(grads, state, params, tcfg: TrainConfig
           ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    count = state["count"] + 1
    lr = lr_schedule(tcfg, count)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tcfg.grad_clip / (gnorm + 1e-9))
    mdt = jnp.dtype(tcfg.master_dtype)

    b1, b2, eps = tcfg.beta1, tcfg.beta2, tcfg.eps
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p, master=None):
        g = g.astype(jnp.float32) * clip
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        base = master if master is not None else p
        step_vec = mhat / (jnp.sqrt(vhat) + eps) \
            + tcfg.weight_decay * base.astype(jnp.float32)
        new_base = base.astype(jnp.float32) - lr * step_vec
        return new_base, m_new.astype(mdt), v_new.astype(mdt)

    if tcfg.use_master_copy:
        out = jax.tree.map(upd, grads, state["m"], state["v"], params,
                           state["master"])
        new_master = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree.map(
            lambda nm, p: nm.astype(p.dtype), new_master, params)
        new_state = {
            "m": jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda x: isinstance(x, tuple)),
            "v": jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda x: isinstance(x, tuple)),
            "master": new_master,
            "count": count,
        }
    else:
        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(
            lambda t, p: t[0].astype(p.dtype), out, params,
            is_leaf=lambda x: isinstance(x, tuple))
        new_state = {
            "m": jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda x: isinstance(x, tuple)),
            "v": jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda x: isinstance(x, tuple)),
            "count": count,
        }
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics
