"""Train / serve step builders.

``make_train_step`` returns a pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
with microbatch gradient accumulation and plan-controlled remat; it is meant
to be ``jax.jit``-ed with shardings by the launcher (see
``repro.launch.train`` / ``repro.launch.dryrun``).

``make_pod_parallel_train_step`` is the explicit multi-pod variant: the data
axes inside a pod stay under GSPMD (auto axes), while the cross-pod gradient
reduction is lifted into a ``shard_map`` over the "pod" axis so it can be
compressed (int8 + error feedback) — the paper's transfer-reduction idea
applied to the slowest link.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.models.lm import Model
from repro.train import grad_compression, optimizer


def _split_microbatches(batch, n):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} % microbatches {n} != 0"
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(split, batch)


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        return model.train_loss(params, batch)
    return loss_fn


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    n_micro = max(model.plan.microbatches, 1)
    loss_fn = make_loss_fn(model)

    def train_step(params, opt_state, batch, step):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mb = _split_microbatches(batch, n_micro)

            def acc_step(carry, microbatch):
                g_acc, l_acc = carry
                (mb_loss, _), g = grad_fn(params, microbatch)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + mb_loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss_sum), _ = jax.lax.scan(acc_step, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro
            metrics = {"loss": loss, "aux_loss": jnp.float32(0.0)}

        new_params, new_opt, opt_metrics = optimizer.update(
            grads, opt_state, params, tcfg)
        metrics = dict(metrics, **opt_metrics, step=step)
        return new_params, new_opt, metrics

    return train_step


def make_pod_parallel_train_step(model: Model, tcfg: TrainConfig,
                                 mesh) -> Callable:
    """Explicit cross-pod shard_map with (optionally compressed) grad psum.

    opt_state gains an "ef" entry (error-feedback buffers) when the plan
    enables grad_compression.
    """
    from jax.sharding import PartitionSpec as P

    from repro.dist import compat
    from repro.dist.compat import shard_map
    from repro.dist.sharding import NullRules, Rules
    from repro.models.lm import Model

    # inside the pod shard_map the "pod" axis is Manual: the inner model's
    # sharding rules must only reference the remaining (Auto) axes — and on
    # JAX/XLA too old for partial-manual constraints they are dropped
    # entirely (a layout hint, not semantics; GSPMD still propagates the
    # in_specs shardings)
    inner_rules = (Rules(mesh, model.plan, exclude_axes=("pod",))
                   if compat.PARTIAL_MANUAL_CONSTRAINTS else NullRules())
    inner_model = Model(model.cfg, model.plan, inner_rules)
    loss_fn = make_loss_fn(inner_model)
    compress = model.plan.grad_compression

    def train_step(params, opt_state, batch, step):
        def pod_body(params_l, ef_l, batch_l):
            # grads for this pod's batch shard; data/model axes stay auto
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params_l, batch_l)
            if compress:
                grads, new_ef = grad_compression.compressed_psum(
                    grads, ef_l, "pod")
            else:
                grads = grad_compression.plain_psum(grads, "pod")
                new_ef = ef_l
            grads = jax.tree.map(
                lambda g: g / mesh.shape["pod"], grads)
            loss = jax.lax.pmean(loss, "pod")
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"),
                                   metrics)
            return grads, new_ef, loss, metrics

        ef = opt_state.get("ef")
        if ef is None:
            ef = jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)

        def rep(tree):
            return jax.tree.map(lambda _: P(), tree)

        shard_batch = jax.tree.map(lambda _: P("pod"), batch)
        grads, new_ef, loss, metrics = shard_map(
            pod_body, mesh=mesh,
            in_specs=(rep(params), rep(ef), shard_batch),
            out_specs=(rep(params), rep(ef), P(), rep({"loss": 0,
                                                       "aux_loss": 0})),
            check_vma=False,
            axis_names={"pod"},
        )(params, ef, batch)

        opt_wo_ef = {k: v for k, v in opt_state.items() if k != "ef"}
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, opt_wo_ef, params, tcfg)
        new_opt["ef"] = new_ef
        metrics = dict(metrics, **opt_metrics, loss=loss, step=step)
        return new_params, new_opt, metrics

    return train_step


def make_pipeline_train_step(stage_fn, tcfg: TrainConfig, mesh, plan,
                             *, axis: str = "pod",
                             loss_fn: Callable = None) -> Callable:
    """Train step for a stage-stacked model pipelined over ``axis``.

    The forward pass runs under the plan's pipeline genes
    (``pipeline_schedule`` / ``virtual_stages`` / ``microbatches``, see
    ``repro.dist.schedules``); the backward pass falls out of autodiff
    through the schedule's ``ppermute`` plan.  ``stage_params`` has leading
    dim = number of stages; ``batch`` is ``(x, y)``; ``loss_fn(pred, y)``
    defaults to mean squared error.
    """
    from repro.dist.pipeline import pipeline_apply

    n_micro = max(getattr(plan, "microbatches", 1), 1)
    schedule = getattr(plan, "pipeline_schedule", "gpipe")
    virtual = getattr(plan, "virtual_stages", 1)
    loss_of = loss_fn or (lambda pred, y: jnp.mean((pred - y) ** 2))

    def train_step(stage_params, opt_state, batch, step):
        x, y = batch

        def loss(ws):
            out = pipeline_apply(stage_fn, ws, x, mesh,
                                 microbatches=n_micro, axis=axis,
                                 schedule=schedule, virtual_stages=virtual)
            return loss_of(out, y)

        lval, grads = jax.value_and_grad(loss)(stage_params)
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, opt_state, stage_params, tcfg)
        metrics = dict(opt_metrics, loss=lval, step=step)
        return new_params, new_opt, metrics

    return train_step


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def make_prefill_step(model: Model, cache_len: int) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len)
    return prefill_step


def make_serve_step(model: Model) -> Callable:
    """(params, cache, tokens[B,1], pos) -> (logits [B,V], new cache)."""
    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)
    return serve_step
