"""int8 error-feedback gradient compression for the slow cross-pod axis.

Cross-pod (DCN-class) bandwidth is the scarce resource in multi-pod data
parallelism.  ``compressed_psum`` quantizes each gradient leaf to int8 with a
per-leaf scale before the all-reduce over the pod axis and adds the
quantization residual to an error-feedback buffer that is re-injected on the
next step (1-bit-Adam/EF-SGD style, but int8).

Used inside ``shard_map`` over the "pod" axis (see
``repro.train.train_step.make_pod_parallel_train_step``), and unit-tested on
a forced 8-device host platform.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_feedback(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads, ef_state, axis_name: str):
    """All-reduce `grads` over `axis_name` in int8 with error feedback.

    Returns (reduced_grads_fp32, new_ef_state).  Inside shard_map only.
    """
    def leaf(g, ef):
        gf = g.astype(jnp.float32) + ef
        q, scale = quantize_int8(gf)
        deq = dequantize_int8(q, scale)
        new_ef = gf - deq
        # int8 values summed in int32 to avoid overflow across pods;
        # per-pod scales are reduced alongside (scale differs per pod, so
        # reduce the dequantized representation's contributions exactly by
        # psum'ing q*scale in fp32 is equivalent to psum(deq); we keep the
        # wire format int8 by psum'ing q (int32 accum) and using the max
        # scale — the residual goes into error feedback either way.
        scale_max = jax.lax.pmax(scale, axis_name)
        q_rescaled = jnp.round(deq / scale_max).astype(jnp.int32)
        total = jax.lax.psum(q_rescaled, axis_name)
        out = total.astype(jnp.float32) * scale_max
        # fold the rescaling error into the feedback buffer too
        new_ef = new_ef + (deq - q_rescaled.astype(jnp.float32) * scale_max)
        return out, new_ef

    out = jax.tree.map(leaf, grads, ef_state)
    reduced = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return reduced, new_ef


def plain_psum(grads, axis_name: str):
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_name), grads)
