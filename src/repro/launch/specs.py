"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` -> dict of SDS for the step function selected by
the shape kind:
  * train:   {tokens, labels} (+ img_embed / frames)
  * prefill: {tokens} (+ extras)
  * decode:  {tokens[B,1], cache, pos}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct as SDS

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm


def _extras(cfg: ModelConfig, batch: int, dtype):
    out = {}
    if cfg.family == "vlm":
        out["img_embed"] = SDS((batch, cfg.n_img_tokens, cfg.d_model), dtype)
    if cfg.family == "audio":
        out["frames"] = SDS((batch, cfg.n_frames, cfg.d_model), dtype)
    return out


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    b = shape.global_batch
    if shape.kind == "train":
        out = {"tokens": SDS((b, shape.seq_len), jnp.int32),
               "labels": SDS((b, shape.seq_len), jnp.int32)}
        out.update(_extras(cfg, b, dtype))
        return out
    if shape.kind == "prefill":
        out = {"tokens": SDS((b, shape.seq_len), jnp.int32)}
        out.update(_extras(cfg, b, dtype))
        return out
    if shape.kind == "decode":
        return {"tokens": SDS((b, 1), jnp.int32)}
    raise ValueError(shape.kind)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, plan=None) -> dict:
    """Abstract decode-cache pytree (eval_shape over init_cache)."""
    quant = bool(plan and getattr(plan, "kv_cache_quant", False))
    return jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len,
                              quant=quant))


def logical_batch_axes(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Logical sharding axes for each batch input."""
    if shape.kind == "train":
        out = {"tokens": ("batch", None), "labels": ("batch", None)}
    elif shape.kind == "prefill":
        out = {"tokens": ("batch", None)}
    else:
        out = {"tokens": ("batch", None)}
    if cfg.family == "vlm" and shape.kind != "decode":
        out["img_embed"] = ("batch", None, None)
    if cfg.family == "audio" and shape.kind != "decode":
        out["frames"] = ("batch", None, None)
    return out
