"""Production mesh builders.

A FUNCTION (not module-level constant) so importing never touches jax device
state.  Single-pod: (16, 16) = 256 chips, axes ("data", "model").  Multi-pod:
(2, 16, 16) = 512 chips, axes ("pod", "data", "model") — "pod" is the
DCN-class axis used for cross-pod data parallelism (or pipeline stages).

All constructors go through repro.dist.compat so the same code runs on the
pinned JAX and on current JAX (axis_types only exists on the latter).
"""
from __future__ import annotations

import jax

from repro.dist.compat import AxisType, make_mesh, mesh_from_devices


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()
    if len(devices) > need:       # single-pod mesh on the 512-device host
        devices = devices[:need]
    return mesh_from_devices(devices, shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for forced-multi-device unit tests."""
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist locally, as a 1-D data mesh (examples/CI)."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))
