import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell the full-size step function (train_step / prefill / serve_step)
is lowered with ShapeDtypeStruct inputs and compiled for the production mesh;
``memory_analysis()`` proves the per-device footprint, ``cost_analysis()`` +
HLO collective parsing feed the §Roofline terms.  Results are cached as JSON
under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  python -m repro.launch.dryrun --all             # driver: subprocess/cell
  python -m repro.launch.dryrun --all --mesh multi
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def default_plan(cfg, shape, plan_name: str = "auto",
                 overrides: dict = None):
    """Baseline per-cell plan (recorded in EXPERIMENTS.md as the baseline).

    `overrides` (from --plan-json) patches arbitrary Plan fields on top of
    the auto baseline — the §Perf hillclimb mechanism.
    """
    from repro.dist.plan import Plan
    import dataclasses as dc
    if plan_name not in ("auto", "baseline"):
        from repro.dist import plan as plan_mod
        named = {p.name: p for p in vars(plan_mod).values()
                 if isinstance(p, Plan)}
        if plan_name in named:
            # overrides (--plan-json / --schedule) patch the named plan,
            # they must not silently replace it with the auto baseline
            base = named[plan_name]
            return dc.replace(base, **overrides) if overrides else base
    kw = {}
    if shape.kind != "train":
        kw["remat"] = "none"
    if shape.kind == "decode":
        kw["decode_kv_seq_shard"] = True
    if cfg.padded_vocab >= 100_000:
        kw["vocab_chunk"] = 512
    name = "auto-baseline"
    if overrides:
        kw.update(overrides)
        name = plan_name if plan_name not in ("auto", "baseline") \
            else "override"
    return Plan(name=name, **kw)


def build_step(cfg, shape, mesh, plan):
    """Returns (fn, example_args_SDS, in_shardings, donate)."""
    import jax
    import jax.numpy as jnp
    from jax import ShapeDtypeStruct as SDS

    from repro.configs.base import TrainConfig
    from repro.dist.sharding import Rules, tree_shardings
    from repro.launch import specs
    from repro.models.lm import Model, param_axes, cache_axes
    from repro.train import optimizer, train_step as ts

    rules = Rules(mesh, plan)
    model = Model(cfg, plan, rules)
    key_sds = SDS((2,), jnp.uint32)
    params_sds = jax.eval_shape(
        lambda k: model.init(k), key_sds)
    p_axes = param_axes(cfg)
    params_sh = tree_shardings(rules, p_axes, params_sds)
    batch_sds = specs.batch_specs(cfg, shape)
    b_axes = specs.logical_batch_axes(cfg, shape)
    batch_sh = {k: rules.sharding(b_axes[k], batch_sds[k].shape)
                for k in batch_sds}

    if shape.kind == "train":
        tcfg = TrainConfig(microbatches=plan.microbatches,
                           master_dtype=plan.opt_state_dtype)
        opt_sds = jax.eval_shape(lambda p: optimizer.init(p, tcfg),
                                 params_sds)
        o_axes = optimizer.opt_state_axes(p_axes, tcfg)
        opt_sh = tree_shardings(rules, o_axes, opt_sds)
        fn = ts.make_train_step(model, tcfg)
        args = (params_sds, opt_sds, batch_sds, SDS((), jnp.int32))
        shardings = (params_sh, opt_sh, batch_sh, None)
        return fn, args, shardings, (0, 1)
    if shape.kind == "prefill":
        fn = ts.make_prefill_step(model, cache_len=shape.seq_len)
        args = (params_sds, batch_sds)
        return fn, args, (params_sh, batch_sh), ()
    # decode
    cache_sds = specs.cache_specs(cfg, shape, plan)
    c_axes = cache_axes(cfg, quant=plan.kv_cache_quant)
    cache_sh = tree_shardings(rules, c_axes, cache_sds)
    fn = ts.make_serve_step(model)
    args = (params_sds, cache_sds, batch_sds["tokens"], SDS((), jnp.int32))
    shardings = (params_sh, cache_sh, batch_sh["tokens"], None)
    return fn, args, shardings, (1,)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             plan_name: str = "auto", out_dir: Path = OUT_DIR,
             overrides: dict = None, policy: str = "host-time",
             use_cache: bool = True) -> dict:
    """One dry-run cell, wrapped in a ``dryrun/cell`` span (repro.obs)."""
    from repro.obs import get_tracer
    with get_tracer().span("cell", cat="dryrun", track="dryrun",
                           arch=arch, shape=shape_name, mesh=mesh_kind,
                           plan=plan_name) as span:
        result = _run_cell(arch, shape_name, mesh_kind, plan_name, out_dir,
                           overrides, policy, use_cache)
        span.set(skipped="skip" in result, pruned="lint" in result
                 and "error" in result, cache_hit=result.get("cache_hit"),
                 compile_s=result.get("compile_s"),
                 verify_s=result.get("verify_s"))
    return result


def _run_cell(arch: str, shape_name: str, mesh_kind: str,
              plan_name: str = "auto", out_dir: Path = OUT_DIR,
              overrides: dict = None, policy: str = "host-time",
              use_cache: bool = True) -> dict:
    import jax
    from repro.configs import get_config, get_shape, cell_runnable
    from repro.core import cost_model
    from repro.core import search_cache as sc
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    result: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "plan": plan_name, "policy": policy}
    if not cell_runnable(cfg, shape):
        result["skip"] = ("long_500k needs sub-quadratic attention; "
                          f"{arch} is pure full-attention (see DESIGN.md)")
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    plan = default_plan(cfg, shape, plan_name, overrides)
    result["plan_detail"] = dataclasses.asdict(plan)

    # static plan lint (repro.analysis): findings ride the cell JSON so a
    # sweep over cells doubles as a lint sweep; an error-severity finding
    # prunes the cell before any lowering or XLA compile is spent on it
    from repro.analysis import findings_to_json, has_errors, lint_plan
    pipelined = bool(overrides and "pipeline_schedule" in overrides)
    lint = lint_plan(plan, mesh=mesh, cfg=cfg, shape=shape,
                     pipelined=pipelined)
    result["lint"] = findings_to_json(lint)
    if has_errors(lint):
        result["error"] = "statically pruned: " + "; ".join(
            f"{f.rule_id}: {f.message}" for f in lint
            if f.severity == "error")
        return result

    # structure-keyed compile cache: cells whose plans differ only in
    # model-only genes (e.g. --schedule variants of the same baseline)
    # share one compiled artifact, and repeat invocations skip XLA entirely
    cache = sc.SearchCache((out_dir / "search_cache.json") if use_cache
                           else None)
    cache_key = ("dryrun", arch, shape_name, mesh_kind,
                 sc.mesh_fingerprint(mesh), plan.structural_key())
    cache.stats.candidates += 1
    t0 = time.time()
    payload = cache.lookup(cache_key)
    cache_hit = (payload is not None and "error" not in payload
                 and isinstance(payload.get("extra"), dict)
                 and "memory" in payload["extra"])
    if cache_hit:
        analyzed = payload["analysis"]
        t_lower = payload["extra"].get("lower_s", 0.0)
        t_compile = payload.get("compile_s", 0.0)
        ca = payload["extra"].get("xla_cost_analysis", {})
        memory = payload["extra"]["memory"]
        verify_s = time.time() - t0        # actual cost this run: a lookup
    else:
        fn, args, shardings, donate = build_step(cfg, shape, mesh, plan)
        jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        verify_s = t_lower + t_compile

        from repro.dist.compat import cost_analysis_dict
        ca_raw = cost_analysis_dict(compiled)
        ca = {k: float(v) for k, v in ca_raw.items()
              if isinstance(v, (int, float))
              and ("flops" in k or k == "bytes accessed")}
        ma = compiled.memory_analysis()
        memory = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        }
        analyzed = sc.analyze_compiled(compiled)  # loop-aware per-device
        cache.put(cache_key, analyzed, t_compile,
                  extra={"lower_s": round(t_lower, 2),
                         "memory": memory, "xla_cost_analysis": ca})
    mf = cost_model.model_flops_for(cfg, shape)
    # pipeline-schedule genes stretch the step by the schedule's bubble —
    # but only for cells that explicitly request a pipeline (--schedule /
    # --plan-json): the baseline step is data-parallel over "pod", and the
    # default Plan genes must not shift every cached multi-mesh roofline
    pipe_ranks = mesh.shape["pod"] if "pod" in mesh.axis_names else 1
    bubble = (cost_model.plan_bubble_fraction(plan, pipe_ranks)
              if pipelined else 0.0)
    rl = cost_model.roofline_terms(
        analyzed["flops"], analyzed["bytes"],
        analyzed["collective_bytes"],
        n_chips=n_chips, model_flops=mf, bubble_fraction=bubble)

    result.update({
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "verify_s": round(verify_s, 3),
        "cache_hit": cache_hit,
        "xla_cost_analysis": ca,
        "hlo_analysis": {k: float(v) for k, v in analyzed.items()},
        "memory": memory,
        "collectives": {k.replace("coll_", ""): v
                        for k, v in analyzed.items()
                        if k.startswith("coll_")},
        "collective_counts": {k.replace("count_", ""): v
                              for k, v in analyzed.items()
                              if k.startswith("count_")},
        "roofline": rl.to_dict(),
        "fits_16GiB": memory["peak_estimate_bytes"] < 16 * 1024**3,
    })
    # modeled energy of the cell (repro.power): the slice's chip envelope
    # at the roofline's utilization — what --policy power | edp rank
    from repro.power import cell_energy
    e_rep = cell_energy(rl, n_chips)
    result["energy"] = e_rep.to_dict() if e_rep is not None else None
    # selection-policy score (repro.backends.policy): the ranking key the
    # cost policy assigns this cell — host-time / modeled rank pure step
    # time; price-weighted ranks step_time x chip count (throughput per
    # relative dollar); power ranks the cell's modeled joules per step and
    # edp its energy-delay product.  A cell enters ranking as a Candidate
    # (repro.core.candidates) like every other selection site.
    from repro.backends import get_policy
    from repro.core.candidates import Candidate
    pol = get_policy(policy)
    result["policy_score"] = pol.score_candidate(Candidate.from_cell(
        rl.step_time_s, n_chips=float(n_chips), backend=mesh_kind,
        arch=str(arch), energy=result["energy"]))
    return result


def cell_path(out_dir: Path, arch, shape, mesh_kind, plan_name) -> Path:
    tag = f"{arch}__{shape}__{mesh_kind}"
    if plan_name not in ("auto", "baseline"):
        tag += f"__{plan_name}"
    return out_dir / f"{tag}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--plan", default="auto")
    ap.add_argument("--plan-json", default=None,
                    help='JSON dict of Plan field overrides')
    ap.add_argument("--schedule", default=None,
                    choices=["gpipe", "one_f_one_b", "interleaved"],
                    help="pipeline schedule gene (repro.dist.schedules); "
                         "overrides Plan.pipeline_schedule and folds the "
                         "schedule's bubble fraction into the roofline on "
                         "meshes with a pod axis")
    ap.add_argument("--virtual-stages", type=int, default=None,
                    help="chunks per rank for --schedule interleaved")
    ap.add_argument("--policy", default="host-time",
                    help="selection policy ranking the compiled cells "
                         "(repro.backends.policy): host-time | modeled "
                         "rank pure modeled step time; price-weighted "
                         "ranks step_time x chip count; power ranks the "
                         "cell's modeled joules per step (repro.power: "
                         "TPU chip envelope x roofline utilization) and "
                         "edp its energy-delay product. With --all, the "
                         "best mesh per (arch, shape) under the policy "
                         "is printed.")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-search-cache", action="store_true",
                    help="bypass the structure-keyed compile cache "
                         "(<out>/search_cache.json) and always recompile")
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a repro.obs trace of this invocation's "
                         "cells; writes JSONL events if PATH ends in "
                         ".jsonl, else a Perfetto-loadable Chrome trace "
                         "(single-cell mode only — the --all driver runs "
                         "each cell in a subprocess)")
    args = ap.parse_args()
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    # schedule flags ride the Plan-override mechanism; pipelined cells cache
    # under their own tag so they never shadow the baseline plan's JSON —
    # whether the pipeline genes arrive via --schedule or --plan-json
    sched_overrides = {}
    if args.schedule:
        sched_overrides["pipeline_schedule"] = args.schedule
    if args.virtual_stages:
        if not args.schedule:
            ap.error("--virtual-stages requires --schedule")
        sched_overrides["virtual_stages"] = args.virtual_stages
    try:
        json_overrides = json.loads(args.plan_json) if args.plan_json else {}
    except json.JSONDecodeError as e:
        ap.error(f"--plan-json is not valid JSON: {e}")
    all_overrides = dict(json_overrides, **sched_overrides)
    plan_tag = args.plan
    if "pipeline_schedule" in all_overrides:
        plan_tag = f"{args.plan}-{all_overrides['pipeline_schedule']}"
        if all_overrides.get("virtual_stages"):
            plan_tag += f"-v{all_overrides['virtual_stages']}"

    if args.all:
        from repro.configs import ARCHS, SHAPES
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        todo = [(a, s, m) for a in ARCHS for s in SHAPES for m in meshes]
        ok = fail = skip = 0
        for arch, shape, mesh_kind in todo:
            path = cell_path(out_dir, arch, shape, mesh_kind, plan_tag)
            if path.exists() and not args.force:
                prev = json.loads(path.read_text())
                ok += ("error" not in prev and "skip" not in prev)
                skip += "skip" in prev
                fail += "error" in prev
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                   "--plan", args.plan, "--policy", args.policy,
                   "--out", str(out_dir)]
            if args.schedule:
                cmd += ["--schedule", args.schedule]
            if args.virtual_stages:
                cmd += ["--virtual-stages", str(args.virtual_stages)]
            if args.plan_json:
                cmd += ["--plan-json", args.plan_json]
            if args.no_search_cache:
                cmd += ["--no-search-cache"]
            print(f"[dryrun] {arch} × {shape} × {mesh_kind} ...",
                  flush=True)
            try:
                r = subprocess.run(cmd, timeout=args.timeout,
                                   capture_output=True, text=True)
                if r.returncode != 0:
                    path.write_text(json.dumps(
                        {"arch": arch, "shape": shape, "mesh": mesh_kind,
                         "error": (r.stderr or r.stdout)[-4000:]}, indent=1))
                    fail += 1
                    print(f"  FAIL (rc={r.returncode})", flush=True)
                else:
                    res = json.loads(path.read_text())
                    if "skip" in res:
                        skip += 1
                        print("  skip", flush=True)
                    else:
                        ok += 1
                        rl = res["roofline"]
                        e = res.get("energy") or {}
                        e_tag = (f" energy={e['energy_j']:.1f}J"
                                 f"@{e['avg_watts']:.0f}W" if e else "")
                        print(f"  ok compile={res['compile_s']}s "
                              f"dominant={rl['dominant']} "
                              f"step={rl['step_time_s']:.4f}s{e_tag}",
                              flush=True)
            except subprocess.TimeoutExpired:
                path.write_text(json.dumps(
                    {"arch": arch, "shape": shape, "mesh": mesh_kind,
                     "error": f"timeout after {args.timeout}s"}, indent=1))
                fail += 1
                print("  TIMEOUT", flush=True)
        # policy selection across meshes: for each (arch, shape) with more
        # than one compiled mesh cell, report the one the cost policy picks
        from repro.backends import get_policy
        pol = get_policy(args.policy)
        by_cell: dict = {}
        for arch, shape, mesh_kind in todo:
            path = cell_path(out_dir, arch, shape, mesh_kind, plan_tag)
            if not path.exists():
                continue
            r = json.loads(path.read_text())
            if "error" in r or "skip" in r or "roofline" not in r:
                continue
            # always rescore from the stored roofline: a cell JSON written
            # by an older build may carry a policy_score in different
            # units (or no energy block at all), and min() must compare
            # one unit across cells — recompute the energy when absent
            energy = r.get("energy")
            if energy is None and "roofline" in r:
                from repro.power import cell_energy
                e_rep = cell_energy(r["roofline"], r["n_chips"])
                energy = e_rep.to_dict() if e_rep is not None else None
                r["energy"] = energy
            from repro.core.candidates import Candidate
            score = pol.score_candidate(Candidate.from_cell(
                r["roofline"]["step_time_s"], n_chips=float(r["n_chips"]),
                backend=mesh_kind, arch=str(arch), energy=energy, ref=r))
            by_cell.setdefault((arch, shape), []).append((score, mesh_kind, r))
        for (arch, shape), cells in sorted(by_cell.items()):
            if len(cells) < 2:
                continue
            score, mesh_kind, r = min(cells, key=lambda c: c[0])
            e = r.get("energy") or {}
            e_tag = (f", {e['energy_j']:.1f} J/step "
                     f"@ {e['avg_watts']:.0f} W" if e else "")
            print(f"[policy={pol.name}] {arch} x {shape}: {mesh_kind} "
                  f"({r['n_chips']} chips, "
                  f"step={r['roofline']['step_time_s']:.4f}s{e_tag}, "
                  f"score={score:.4f})")
        print(f"[dryrun] done: {ok} ok, {skip} skip, {fail} fail")
        sys.exit(1 if fail else 0)

    # single cell (in-process)
    assert args.arch and args.shape
    path = cell_path(out_dir, args.arch, args.shape, args.mesh, plan_tag)
    from repro import obs
    tracer = obs.Tracer() if args.trace else obs.NULL_TRACER
    try:
        with obs.use_tracer(tracer):
            res = run_cell(args.arch, args.shape, args.mesh, args.plan,
                           out_dir, all_overrides or None,
                           policy=args.policy,
                           use_cache=not args.no_search_cache)
    except Exception:
        res = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "error": traceback.format_exc()[-6000:]}
        path.write_text(json.dumps(res, indent=1))
        print(json.dumps(res, indent=1))
        sys.exit(1)
    finally:
        if args.trace:
            if args.trace.endswith(".jsonl"):
                obs.write_jsonl(tracer.records, args.trace)
            else:
                obs.write_chrome_trace(tracer.records, args.trace)
    path.write_text(json.dumps(res, indent=1))
    print(json.dumps({k: v for k, v in res.items()
                      if k in ("arch", "shape", "mesh", "compile_s",
                               "verify_s", "cache_hit", "roofline",
                               "energy", "fits_16GiB", "skip")}, indent=1))


if __name__ == "__main__":
    main()
