"""End-to-end training driver.

CPU-runnable with reduced configs (``--reduced``), production-structured:
mesh + sharded jit train step, deterministic data pipeline, fault-tolerant
checkpointed loop, straggler watchdog, optional int8 cross-pod gradient
compression (``--pod-parallel --compress``).

On a real TPU pod, launch per-host with the same flags; the XLA flags below
enable async collectives + latency-hiding scheduling (no-ops on CPU).

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --reduced --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import os

TPU_XLA_FLAGS = " ".join([
    "--xla_enable_async_collective_permute=true",
    "--xla_tpu_enable_async_collective_fusion=true",
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
    "--xla_latency_hiding_scheduler_rerun=2",
])
if os.environ.get("REPRO_TPU"):
    os.environ["LIBTPU_INIT_ARGS"] = os.environ.get(
        "LIBTPU_INIT_ARGS", "") + " " + TPU_XLA_FLAGS

import argparse
import time

import jax
import jax.numpy as jnp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--pod-parallel", action="store_true")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--remat", default="block",
                    choices=["none", "block", "full"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.data.pipeline import SyntheticTokens, data_config_for
    from repro.dist.plan import Plan
    from repro.dist.sharding import Rules
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.launch.mesh import make_host_mesh
    from repro.models.lm import Model, param_axes
    from repro.runtime.fault_tolerance import run_resilient
    from repro.train import optimizer, train_step as ts
    from repro.dist.sharding import tree_shardings

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    plan = Plan(name="train-cli", remat=args.remat,
                microbatches=args.microbatches,
                grad_compression=args.compress,
                vocab_chunk=min(2048, args.seq))
    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1),
                       microbatches=args.microbatches)

    mesh = make_host_mesh()
    rules = Rules(mesh, plan)
    model = Model(cfg, plan, rules)

    dcfg = data_config_for(cfg, shape)
    data = SyntheticTokens(dcfg)

    p_axes = param_axes(cfg)
    params_sds = jax.eval_shape(model.init,
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
    params_sh = tree_shardings(rules, p_axes, params_sds)

    if args.pod_parallel and "pod" in mesh.axis_names:
        step_fn_raw = ts.make_pod_parallel_train_step(model, tcfg, mesh)
    else:
        step_fn_raw = ts.make_train_step(model, tcfg)
    jstep = jax.jit(step_fn_raw, donate_argnums=(0, 1))

    ckpt = Checkpointer(args.ckpt_dir, keep=2)

    def init_state():
        params = jax.jit(model.init, out_shardings=params_sh)(
            jax.random.PRNGKey(tcfg.seed))
        opt = optimizer.init(params, tcfg)
        return {"params": params, "opt": opt}

    def body(state, step):
        batch = data.batch(step)
        t0 = time.perf_counter()
        params, opt, metrics = jstep(state["params"], state["opt"], batch,
                                     jnp.int32(step))
        metrics = jax.device_get(metrics)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"dt={time.perf_counter()-t0:.3f}s", flush=True)
        return {"params": params, "opt": opt}, metrics

    res = run_resilient(total_steps=args.steps, checkpointer=ckpt,
                        init_state=init_state, step_fn=body,
                        save_every=args.save_every)
    losses = [h.get("loss") for h in res.metrics_history if "loss" in h]
    print(f"done: {res.last_step} steps, {res.restarts} restarts, "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}, "
          f"{len(res.watchdog.flagged)} straggler flags")
    return res


if __name__ == "__main__":
    main()
