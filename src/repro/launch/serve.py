"""Batched serving driver: prefill + greedy decode over a request batch.

CPU-runnable with reduced configs; the same ``serve_step`` is what the
decode dry-run cells lower at pod scale (with sequence-sharded KV).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def generate(model, params, batch, prompt_len: int, gen: int,
             cache_len: int):
    """Greedy decode `gen` tokens after prefilling `batch['tokens']`."""
    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len))
    step = jax.jit(model.decode_step)
    logits, cache = prefill(params, batch)
    toks = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    toks.append(tok)
    for i in range(gen - 1):
        logits, cache = step(params, cache, tok,
                             jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        toks.append(tok)
    return jnp.concatenate(toks, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.models.lm import Model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["img_embed"] = jax.random.normal(
            key, (args.batch, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.n_frames, cfg.d_model), jnp.float32)

    cache_len = args.prompt_len + args.gen
    t0 = time.perf_counter()
    out = generate(model, params, batch, args.prompt_len, args.gen,
                   cache_len)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s incl. compile)")
    print("sample tokens:", jax.device_get(out[0, :12]).tolist())
    return out


if __name__ == "__main__":
    main()
