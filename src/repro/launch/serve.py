"""Serving driver: continuous-batching engine over one model replica.

CPU-runnable with reduced configs.  ``generate`` remains the sequential
batch reference (prefill + greedy decode, jits memoized per model so
repeated calls never re-trace); the CLI routes through
:class:`repro.serve.ContinuousBatcher`, where requests join and leave the
running batch at decode-step granularity and the KV slot pool persists
across requests.  At pod scale the same ``decode_step`` is what the decode
dry-run cells lower — sharded per the destination's plan (e.g. the
``serve-low-mem`` serving genes), not pinned to any one mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --reduced --batch 4 --prompt-len 32 --gen 16
  # open-loop synthetic trace with staggered arrivals:
  PYTHONPATH=src python -m repro.launch.serve --reduced --trace 8
"""
from __future__ import annotations

import argparse
import time
import weakref

import jax
import jax.numpy as jnp

# per-model memo of the jitted prefill/step pair: repeated generate()
# calls (the benchmark's static baseline loops it) must not pay a fresh
# trace per call — jax.jit caches compiles per function object, so the
# function objects themselves must be reused
_GENERATE_JITS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _jits_for(model, cache_len: int):
    per_model = _GENERATE_JITS.setdefault(model, {})
    pair = per_model.get(cache_len)
    if pair is None:
        prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len))
        step = jax.jit(model.decode_step)
        pair = per_model[cache_len] = (prefill, step)
    return pair


def generate(model, params, batch, prompt_len: int, gen: int,
             cache_len: int):
    """Greedy decode `gen` tokens after prefilling `batch['tokens']`.

    The sequential reference the continuous engine's parity test compares
    against: whole batch prefilled together, decoded in lock-step."""
    prefill, step = _jits_for(model, cache_len)
    logits, cache = prefill(params, batch)
    toks = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    toks.append(tok)
    for i in range(gen - 1):
        logits, cache = step(params, cache, tok,
                             jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        toks.append(tok)
    return jnp.concatenate(toks, axis=1)


def _request_extras(cfg, key, n: int = 1) -> dict:
    """Modality context (vlm/audio) for one synthetic request batch."""
    extras = {}
    if cfg.family == "vlm":
        extras["img_embed"] = jax.random.normal(
            key, (n, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        extras["frames"] = jax.random.normal(
            key, (n, cfg.n_frames, cfg.d_model), jnp.float32)
    return extras


def synthetic_trace(cfg, n: int, prompt_len: int, gen: int, *,
                    gap_s: float = 0.02, seed: int = 1):
    """Open-loop arrival trace: ``n`` requests arriving ``gap_s`` apart
    (staggered — the shape continuous batching wins on)."""
    from repro.serve import Request
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n):
        reqs.append(Request(
            rid=f"r{i}", arch=cfg.name, prompt_len=prompt_len, max_gen=gen,
            arrival_s=i * gap_s,
            extras=_request_extras(cfg, jax.random.fold_in(key, i))))
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4,
                    help="slot-pool width (concurrent requests)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--trace", type=int, default=0, metavar="N",
                    help="serve a synthetic open-loop trace of N staggered "
                         "arrivals instead of one gang batch")
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.models.lm import Model
    from repro.power import envelope_for
    from repro.serve import ContinuousBatcher, Request

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    cache_len = args.prompt_len + args.gen
    engine = ContinuousBatcher(model, params, n_slots=args.batch,
                               cache_len=cache_len,
                               envelope=envelope_for(None))
    if args.trace:
        reqs = synthetic_trace(cfg, args.trace, args.prompt_len, args.gen)
    else:
        key = jax.random.PRNGKey(1)
        reqs = [Request(rid=f"r{i}", arch=cfg.name,
                        prompt_len=args.prompt_len, max_gen=args.gen,
                        extras=_request_extras(cfg,
                                               jax.random.fold_in(key, i)))
                for i in range(args.batch)]

    t0 = time.perf_counter()
    out = engine.run(reqs)
    dt = time.perf_counter() - t0
    s = engine.metrics.summary()
    n_tok = sum(len(v) for v in out.values())
    print(f"arch={cfg.name} served {len(out)} requests, {n_tok} tokens "
          f"in {dt:.2f}s wall ({n_tok / dt:.1f} tok/s incl. compile); "
          f"ttft_p50={s['ttft_p50_s']}s traces={engine.traces}")
    first = sorted(out)[0]
    print("sample tokens:", out[first][:12].tolist())
    return out


if __name__ == "__main__":
    main()
