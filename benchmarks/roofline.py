"""Generate the §Roofline markdown tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline > experiments/roofline.md
"""
from __future__ import annotations

import json
from pathlib import Path

DRY = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

ORDER = ["granite-3-2b", "h2o-danube-1.8b", "command-r-plus-104b",
         "nemotron-4-15b", "moonshot-v1-16b-a3b", "arctic-480b",
         "recurrentgemma-2b", "mamba2-1.3b", "llama-3.2-vision-90b",
         "seamless-m4t-medium"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x):
    if x >= 1:
        return f"{x:8.2f}"
    return f"{x*1e3:7.2f}m"


def load(arch, shape, mesh, plan=None):
    tag = f"{arch}__{shape}__{mesh}" + (f"__{plan}" if plan else "")
    p = DRY / f"{tag}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def table(mesh: str, plan=None, title=""):
    print(f"\n### {title or ('Roofline — ' + mesh + '-pod baseline')}\n")
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| step s | MODEL_FLOPS | useful/HLO | roofline frac | fits 16GiB "
          "| bottleneck lever |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for arch in ORDER:
        for shape in SHAPES:
            r = load(arch, shape, mesh, plan)
            if r is None:
                continue
            if "skip" in r:
                print(f"| {arch} | {shape} | — | — | — | skip | — | — | — "
                      f"| — | long_500k: full-attention arch |")
                continue
            if "error" in r:
                print(f"| {arch} | {shape} | ERROR | | | | | | | | |")
                continue
            rl = r["roofline"]
            lever = {
                "memory": "remat/microbatch/fused attn kernel",
                "collective": "EP shard_map / comm dedup",
                "compute": "MXU kernel tiling",
            }[rl["dominant"]]
            print(f"| {arch} | {shape} | {fmt_s(rl['compute_s'])} "
                  f"| {fmt_s(rl['memory_s'])} "
                  f"| {fmt_s(rl['collective_s'])} | {rl['dominant']} "
                  f"| {fmt_s(rl['step_time_s'])} "
                  f"| {rl['model_flops']:.2e} "
                  f"| {rl['useful_flops_ratio']:.2f} "
                  f"| {rl['roofline_fraction']:.3f} "
                  f"| {'yes' if r['fits_16GiB'] else 'NO'} | {lever} |")


def main():
    table("single")
    table("multi")
    # optimized train cells if present (best of the opt variants per cell)
    any_opt = any((DRY / f"{a}__train_4k__single__opt.json").exists()
                  for a in ORDER)
    if any_opt:
        print("\n### Roofline — optimized plans, train_4k "
              "(best of: opt = remat full + microbatch 4 + MoE shard_map "
              "EP; opt8 = microbatch 8; opt8sp = + sequence parallel; "
              "opt16spbf = microbatch 16 + bf16 Adam moments)\n")
        print("| arch | mesh | baseline step s | optimized step s | plan "
              "| speedup | frac before→after | fits before→after |")
        print("|---|---|---|---|---|---|---|---|")
        for arch in ORDER:
            for mesh in ("single", "multi"):
                base = load(arch, "train_4k", mesh)
                variants = [(v, load(arch, "train_4k", mesh, v))
                            for v in ("opt", "opt8", "opt8sp", "opt16sp",
                                      "opt16spbf")]
                variants = [(v, r) for v, r in variants
                            if r and "roofline" in r]
                if not base or "roofline" not in base or not variants:
                    continue
                # best = fits first, then step time
                vname, opt = min(
                    variants,
                    key=lambda vr: (not vr[1]["fits_16GiB"],
                                    vr[1]["roofline"]["step_time_s"]))
                b, o = base["roofline"], opt["roofline"]
                print(f"| {arch} | {mesh} | {fmt_s(b['step_time_s'])} "
                      f"| {fmt_s(o['step_time_s'])} | {vname} "
                      f"| {b['step_time_s']/o['step_time_s']:.2f}x "
                      f"| {b['roofline_fraction']:.3f}→"
                      f"{o['roofline_fraction']:.3f} "
                      f"| {'yes' if base['fits_16GiB'] else 'NO'}→"
                      f"{'yes' if opt['fits_16GiB'] else 'NO'} |")

    any_popt = any((DRY / f"{a}__prefill_32k__single__popt.json").exists()
                   for a in ORDER)
    if any_popt:
        print("\n### Roofline — prefill variants (popt = seq-parallel + "
              "ungrouped GQA + MoE shard_map EP + int8 cache out)\n")
        print("| arch | baseline step s | popt step s | verdict |")
        print("|---|---|---|---|")
        for arch in ORDER:
            base = load(arch, "prefill_32k", "single")
            opt = load(arch, "prefill_32k", "single", "popt")
            if not base or not opt or "roofline" not in base \
                    or "roofline" not in opt:
                continue
            b, o = base["roofline"], opt["roofline"]
            verdict = ("CONFIRMED (EP)" if o["step_time_s"]
                       < b["step_time_s"] * 0.95 else
                       "REFUTED for dense prefill (no bwd => seq-parallel "
                       "adds gathers without the residual-save win)")
            print(f"| {arch} | {fmt_s(b['step_time_s'])} "
                  f"| {fmt_s(o['step_time_s'])} | {verdict} |")

    any_kvq = any((DRY / f"{a}__decode_32k__single__kvq8.json").exists()
                  for a in ORDER)
    if any_kvq:
        print("\n### Roofline — int8 KV cache (kvq8), decode_32k "
              "(the decode cells that exceeded 16 GiB at baseline)\n")
        print("| arch | baseline step s | kvq8 step s | speedup "
              "| peak GiB before→after | fits before→after |")
        print("|---|---|---|---|---|---|")
        for arch in ORDER:
            base = load(arch, "decode_32k", "single")
            opt = load(arch, "decode_32k", "single", "kvq8")
            if not base or not opt or "roofline" not in (base or {}) \
                    or "roofline" not in (opt or {}):
                continue
            b, o = base["roofline"], opt["roofline"]
            pb = base["memory"]["peak_estimate_bytes"] / 2**30
            po = opt["memory"]["peak_estimate_bytes"] / 2**30
            print(f"| {arch} | {fmt_s(b['step_time_s'])} "
                  f"| {fmt_s(o['step_time_s'])} "
                  f"| {b['step_time_s']/o['step_time_s']:.2f}x "
                  f"| {pb:.1f}→{po:.1f} "
                  f"| {'yes' if base['fits_16GiB'] else 'NO'}→"
                  f"{'yes' if opt['fits_16GiB'] else 'NO'} |")


if __name__ == "__main__":
    main()
