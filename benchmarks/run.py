"""Benchmark harness — one function per paper table/figure.

  * ``table_fig3``        — paper Fig. 3: mixed-destination offload of 3mm /
                            NAS.BT / tdFIR (measured on this machine's
                            verification environment).
  * ``table_ga_convergence`` — GA search trace (paper §II.B.1 behaviour).
  * ``table_kernels``     — Pallas kernels vs jnp oracles (us/call,
                            interpret mode: correctness-path timing).
  * ``table_roofline``    — §Roofline summary read from the dry-run JSONs.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRYRUN_DIR = ROOT / "experiments" / "dryrun"
OUT_DIR = ROOT / "experiments"

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


# ---------------------------------------------------------------- fig. 3
def bench_inputs(app_name, app):
    """Benchmark sizes: full paper shapes where tractable on one core;
    tdFIR reduced to keep interpret-mode Pallas verification bounded."""
    if app_name == "tdFIR":
        import jax, jax.numpy as jnp
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 4)
        f, n, taps = 32, 2048, 64
        return {
            "x_re": jax.random.normal(ks[0], (f, n), jnp.float32),
            "x_im": jax.random.normal(ks[1], (f, n), jnp.float32),
            "h_re": jax.random.normal(ks[2], (f, taps), jnp.float32) * .1,
            "h_im": jax.random.normal(ks[3], (f, taps), jnp.float32) * .1,
        }
    return app.make_inputs(seed=0)


def table_fig3(policy: str = "host-time"):
    from repro.apps import APPS
    from repro.core.ga import GAConfig
    from repro.core.measure import TimedRunner
    from repro.core.planner import UserTarget, plan_offload

    results = {}
    for name in ("3mm", "NAS.BT", "tdFIR"):
        app = APPS[name]()
        inputs = bench_inputs(name, app)
        t0 = time.time()
        report = plan_offload(
            app, UserTarget(), inputs=inputs,
            runner=TimedRunner(repeats=1),
            ga_cfg=GAConfig.for_gene_length(app.gene_length, seed=0),
            policy=policy)
        sel = report.selected
        emit(f"fig3/{name}/single_core", report.ref_time_s * 1e6,
             "reference")
        if sel is None:      # every candidate wrong/penalized on this host
            emit(f"fig3/{name}/selected", float("nan"),
                 f"no-correct-candidate|policy={report.policy}")
            results[name] = {
                "ref_time_s": report.ref_time_s, "policy": report.policy,
                "plan_elapsed_s": time.time() - t0,
                "records": [r.__dict__ | {"choice": dict(r.choice)}
                            for r in report.records],
                "selected": None,
                "summary_rows": report.summary_rows(),
            }
            continue
        reused = sum(r.cache_stats.get("reused", 0) for r in report.records)
        emit(f"fig3/{name}/selected", sel.best_time_s * 1e6,
             f"{sel.paper_analogue}|{sel.method}|"
             f"improvement={sel.improvement:.1f}x|policy={report.policy}|"
             f"reused={reused}")
        others = sorted((r for r in report.records if r is not sel
                         and r.best_time_s < float("inf")),
                        key=lambda r: r.best_time_s)
        if others:
            o = others[0]
            emit(f"fig3/{name}/second_best", o.best_time_s * 1e6,
                 f"{o.paper_analogue}|{o.method}|"
                 f"improvement={o.improvement:.1f}x")
        results[name] = {
            "ref_time_s": report.ref_time_s,
            "policy": report.policy,
            "plan_elapsed_s": time.time() - t0,
            "records": [r.__dict__ | {"choice": dict(r.choice)}
                        for r in report.records],
            "selected": sel.__dict__ | {"choice": dict(sel.choice)},
            "summary_rows": report.summary_rows(),
        }
    (OUT_DIR / "fig3_results.json").write_text(
        json.dumps(results, indent=1, default=str))
    return results


# ----------------------------------------------------- GA convergence
def table_ga_convergence():
    import jax
    from repro.apps import APPS
    from repro.core.destinations import MANY_CORE
    from repro.core.ga import GAConfig
    from repro.core.loop_offload import ga_search
    from repro.core.measure import TimedRunner

    app = APPS["3mm"]()
    inputs = app.make_inputs(seed=0)
    ref_out = jax.jit(app.reference_fn())(inputs)
    res = ga_search(app, MANY_CORE, TimedRunner(repeats=1), inputs, ref_out,
                    ga_cfg=GAConfig.for_gene_length(app.gene_length,
                                                    seed=0))
    for h in res.history:
        emit(f"ga/3mm/gen{h['generation']}", h["best_time_s"] * 1e6,
             f"n_correct={h['n_correct']}")
    (OUT_DIR / "ga_convergence.json").write_text(
        json.dumps(res.history, indent=1, default=str))
    return res.history


# ------------------------------------------------------------- kernels
def table_kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels import matmul as mm
    from repro.kernels import tdfir as fir
    from repro.kernels import flash_attention as fa

    def timeit(fn, *args, repeats=3):
        out = jax.block_until_ready(fn(*args))     # compile
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        return min(ts) * 1e6, out

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    a = jax.random.normal(k1, (256, 256), jnp.float32)
    b = jax.random.normal(k2, (256, 256), jnp.float32)
    us_ref, want = timeit(jax.jit(ref.matmul_ref), a, b)
    us_pal, got = timeit(jax.jit(
        lambda a, b: mm.matmul(a, b, interpret=True)), a, b)
    err = float(jnp.abs(want - got).max())
    emit("kernel/matmul/ref", us_ref, "jnp oracle 256x256x256")
    emit("kernel/matmul/pallas_interpret", us_pal, f"max_err={err:.2e}")

    x = jax.random.normal(k1, (8, 1024), jnp.float32)
    h = jax.random.normal(k2, (8, 32), jnp.float32)
    us_ref, want = timeit(jax.jit(ref.tdfir_ref), x, h)
    us_pal, got = timeit(jax.jit(
        lambda x, h: fir.tdfir(x, h, block_n=256, interpret=True)), x, h)
    err = float(jnp.abs(want - got).max())
    emit("kernel/tdfir/ref", us_ref, "jnp oracle 8x1024 k=32")
    emit("kernel/tdfir/pallas_interpret", us_pal, f"max_err={err:.2e}")

    q = jax.random.normal(k1, (4, 256, 64), jnp.float32)
    kk = jax.random.normal(k2, (4, 256, 64), jnp.float32)
    v = jax.random.normal(k3, (4, 256, 64), jnp.float32)
    us_ref, want = timeit(jax.jit(
        lambda q, k, v: ref.mha_ref(q, k, v, causal=True)), q, kk, v)
    us_pal, got = timeit(jax.jit(
        lambda q, k, v: fa.flash_attention(q, k, v, block_q=128,
                                           block_kv=128, interpret=True)),
        q, kk, v)
    err = float(jnp.abs(want - got).max())
    emit("kernel/flash_attention/ref", us_ref, "jnp oracle 4x256x64")
    emit("kernel/flash_attention/pallas_interpret", us_pal,
         f"max_err={err:.2e}")


# ------------------------------------------------------------ roofline
def table_roofline():
    if not DRYRUN_DIR.exists():
        emit("roofline/missing", 0.0, "run repro.launch.dryrun first")
        return
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(f.read_text())
        tag = f"roofline/{r.get('arch')}/{r.get('shape')}/{r.get('mesh')}"
        if r.get("plan") not in (None, "auto", "baseline"):
            tag += f"/{r['plan']}"
        if "skip" in r:
            emit(tag, 0.0, "skip:sub-quadratic-only")
            continue
        if "error" in r:
            emit(tag, 0.0, "ERROR")
            continue
        rl = r["roofline"]
        emit(tag, rl["step_time_s"] * 1e6,
             f"dominant={rl['dominant']}|frac={rl['roofline_fraction']:.3f}"
             f"|fits16GiB={r['fits_16GiB']}")


def table_modeled_fig3():
    """Pod-scale modeled destinations (subprocess: needs 512 fake devices;
    this process must keep exactly 1)."""
    import subprocess
    import sys
    out = OUT_DIR / "modeled_fig3.json"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.modeled", str(out)],
        capture_output=True, text=True, timeout=900,
        cwd=str(ROOT), env=dict(os.environ, PYTHONPATH=str(ROOT / "src")))
    if r.returncode != 0:
        emit("modeled/error", 0.0, r.stderr[-200:].replace(",", ";"))
        return
    for line in r.stdout.splitlines():
        if line.startswith("modeled/"):
            print(line)
            parts = line.split(",")
            ROWS.append((parts[0], float(parts[1]), parts[2]))


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="host-time",
                    help="destination-selection policy for the fig. 3 "
                         "table (repro.backends.policy): host-time | "
                         "modeled | price-weighted | power (modeled "
                         "joules, repro.power) | edp")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    table_kernels()
    table_ga_convergence()
    table_fig3(policy=args.policy)
    table_modeled_fig3()
    table_roofline()


if __name__ == "__main__":
    main()
