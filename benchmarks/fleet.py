"""Fleet benchmark: GA placement vs greedy vs static round-robin.

The real planner flow end to end: ``plan_offload(..., publish=lookup)``
verifies each paper app once and publishes its per-destination rooflines
(including one *forced failure* verdict), then the fleet planner places a
multi-app fleet over the shared pool three ways and compares
joules-per-request-served:

  * ``round_robin`` — the static capacity- and verdict-blind baseline;
  * ``greedy``      — the planner's bin-packing seed;
  * ``ga``          — ``FleetPlanner.plan`` (GA seeded with greedy).

Emits ``BENCH_fleet.json`` (a CI artifact next to BENCH_energy.json) and
exits 1 if the GA or greedy placement is infeasible, ever places an app on
a backend with a published failure verdict, or does worse than the static
baseline on the power objective — the invariants the CI step gates on.

    PYTHONPATH=src python benchmarks/fleet.py [--out BENCH_fleet.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, "src")
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

APPS_UNDER_TEST = ("3mm", "NAS.BT", "tdFIR")
# the pair the verification environment is scripted to "prove wrong":
# the benchmark asserts no planner ever places this app on this backend
FORCED_FAILURE = ("tdFIR", "xla_dp")


def _placement_row(name, p, lookup_failures):
    row = {
        "strategy": name,
        "feasible": p.feasible,
        "by_app": p.by_app,
        "objective_w": p.objective,
        "fleet_draw_w": p.fleet_draw_w,
        "joules_per_request": p.joules_per_request,
        "violations": p.violations,
    }
    row["placed_on_failed_verdict"] = sorted(
        app for app, backend in p.by_app.items()
        if (backend, app.split("#")[0]) in lookup_failures)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--replicas", type=int, default=2,
                    help="fleet copies of each verified app")
    args = ap.parse_args()

    from repro.backends import DEFAULT_REGISTRY
    from repro.core.ga import GAConfig
    from repro.core.measure import TimedRunner
    from repro.core.plan_lookup import PlanLookup, serve_key
    from repro.core.planner import UserTarget, plan_offload
    from repro.fleet import (FleetApp, FleetPlanner, PoolBackend,
                             round_robin)
    from repro.apps import APPS

    lookup = PlanLookup()
    failures = []
    plan_elapsed = {}
    for name in APPS_UNDER_TEST:
        app = APPS[name]()
        inputs = app.make_inputs(seed=0, small=True)
        t0 = time.time()
        report = plan_offload(
            app, UserTarget(), inputs=inputs,
            runner=TimedRunner(repeats=1),
            ga_cfg=GAConfig.for_gene_length(min(app.gene_length, 6),
                                            seed=0),
            policy="power", publish=lookup)
        plan_elapsed[name] = round(time.time() - t0, 2)
        if report.selected is None:
            failures.append(f"{name}: plan_offload selected nothing")

    # the forced failure verdict: the verification environment "proved"
    # this (backend, app) pair wrong — published exactly like plan_offload
    # publishes real failures, so the planner must statically refuse it
    fail_app, fail_backend = FORCED_FAILURE
    lookup.register_failure(serve_key(fail_backend, fail_app),
                            "benchmark: forced wrong-result verdict")
    lookup_failures = {(fail_backend, fail_app)}

    pool = [PoolBackend(name=b.name, backend=b, n_chips=1, slots=64.0)
            for b in DEFAULT_REGISTRY]
    fleet = [FleetApp(name=f"{name}#{i}", arch=name, load_rps=2.0,
                      tokens_per_request=8.0)
             for name in APPS_UNDER_TEST
             for i in range(args.replicas)]
    planner = FleetPlanner(pool, lookup, policy="power",
                           ga_cfg=GAConfig(population=8, generations=8,
                                           seed=0))

    t0 = time.time()
    ga_p = planner.plan(fleet)
    plan_s = time.time() - t0
    greedy_genes = planner.greedy(fleet)
    greedy_p = (planner.evaluate(fleet, greedy_genes)
                if greedy_genes is not None else None)
    rr_p = planner.evaluate(fleet, round_robin(fleet, pool))

    rows = [_placement_row("round_robin", rr_p, lookup_failures)]
    if greedy_p is not None:
        rows.append(_placement_row("greedy", greedy_p, lookup_failures))
    else:
        failures.append("greedy found no feasible placement")
    rows.append(_placement_row("ga", ga_p, lookup_failures))

    for row in rows:
        if row["strategy"] == "round_robin":
            continue                     # the baseline is allowed to be bad
        if not row["feasible"]:
            failures.append(f"{row['strategy']}: infeasible placement: "
                            f"{row['violations']}")
        if row["placed_on_failed_verdict"]:
            failures.append(
                f"{row['strategy']}: placed "
                f"{row['placed_on_failed_verdict']} on a backend with a "
                f"published failure verdict")
    if greedy_p is not None and ga_p.feasible \
            and ga_p.objective > greedy_p.objective + 1e-9:
        failures.append(
            f"ga objective {ga_p.objective:.4f} W worse than its greedy "
            f"seed {greedy_p.objective:.4f} W")
    if rr_p.feasible and ga_p.feasible \
            and ga_p.joules_per_request > rr_p.joules_per_request + 1e-9:
        failures.append(
            f"ga joules/request {ga_p.joules_per_request:.4f} worse than "
            f"static round-robin {rr_p.joules_per_request:.4f}")

    for row in rows:
        print(f"fleet/{row['strategy']:12s}: "
              f"{row['joules_per_request']:.4f} J/request, "
              f"draw {row['fleet_draw_w']:.2f} W, "
              f"feasible={row['feasible']}")
    out = {
        "bench": "fleet",
        "apps": list(APPS_UNDER_TEST),
        "replicas": args.replicas,
        "forced_failure": {"app": fail_app, "backend": fail_backend},
        "plan_offload_elapsed_s": plan_elapsed,
        "fleet_plan_elapsed_s": round(plan_s, 3),
        "placements": rows,
        "failures": failures,
    }
    Path(args.out).write_text(json.dumps(out, indent=1))
    print(f"wrote {args.out}")
    if failures:
        print("FAIL:", *failures, sep="\n  ")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
