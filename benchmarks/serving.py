"""Serving benchmark: continuous vs static batching on an open-loop trace.

The same staggered-arrival request trace is served two ways on one virtual
tick timeline (repro.serve.ContinuousBatcher's deterministic clock):

  * **continuous** — requests are admitted the tick they arrive and join
    the running decode batch at decode-step granularity;
  * **static** — the gang-scheduled baseline (what ``launch.serve`` did
    before repro.serve): no request starts until the *last* arrival, then
    all decode in lock-step.  Modeled here by gating every admission at
    the trace's final arrival time on the same engine, so the comparison
    shares one clock, one model, one slot pool.

Emits ``BENCH_serving.json`` (a CI artifact next to BENCH_search.json /
BENCH_energy.json) with tok/s, p50/p95 TTFT and joules/request for both
modes, and exits 1 when an invariant breaks:

  * continuous batching must beat static batching on tok/s for a staggered
    trace (the whole point of admitting at tick granularity);
  * the jitted decode step must have traced exactly once per engine;
  * every request must complete with exactly ``max_gen`` tokens.

    PYTHONPATH=src python benchmarks/serving.py [--out BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, "src")
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

ARCH = "granite-3-2b"


def run_mode(model, params, reqs, *, n_slots, cache_len, gate_s=None):
    """Serve one copy of the trace; ``gate_s`` delays every admission to
    that time (the static-batching gang gate) while submit timestamps —
    and therefore TTFT — stay at the true arrivals."""
    from repro.power import GENERIC
    from repro.serve import ContinuousBatcher

    engine = ContinuousBatcher(model, params, n_slots=n_slots,
                               cache_len=cache_len, envelope=GENERIC)
    gated = reqs
    if gate_s is not None:
        gated = [dataclasses.replace(r, arrival_s=max(r.arrival_s, gate_s))
                 for r in reqs]
        for g, r in zip(gated, reqs):
            # TTFT is measured from the true arrival, not the gang gate
            engine.metrics.on_submit(g.rid, r.arrival_s)
    t0 = time.perf_counter()
    out = engine.run(gated)
    wall = time.perf_counter() - t0
    s = engine.metrics.summary()
    s["wall_s"] = wall
    s["traces"] = dict(engine.traces)
    return engine, out, s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--arch", default=ARCH)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--gap-ticks", type=float, default=3.0,
                    help="arrival spacing in decode ticks (staggered trace)")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models.lm import Model
    from repro.serve import Request
    from repro.serve.batching import DEFAULT_TICK_S, synth_tokens

    cfg = get_config(args.arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = args.prompt_len + args.gen

    gap_s = args.gap_ticks * DEFAULT_TICK_S
    reqs = [Request(rid=f"r{i}", arch=cfg.name,
                    prompt_len=args.prompt_len, max_gen=args.gen,
                    arrival_s=i * gap_s,
                    tokens=synth_tokens(f"r{i}", args.prompt_len,
                                        cfg.vocab_size))
            for i in range(args.requests)]
    last_arrival = max(r.arrival_s for r in reqs)

    failures = []
    modes = {}
    outputs = {}
    for mode, gate in (("continuous", None), ("static", last_arrival)):
        engine, out, summary = run_mode(
            model, params, reqs, n_slots=args.slots, cache_len=cache_len,
            gate_s=gate)
        modes[mode] = summary
        outputs[mode] = out
        if summary["traces"]["decode_step"] != 1:
            failures.append(f"{mode}: decode step traced "
                            f"{summary['traces']['decode_step']}x (want 1)")
        if summary["completed"] != args.requests:
            failures.append(f"{mode}: {summary['completed']} of "
                            f"{args.requests} requests completed")
        for r in reqs:
            if len(out.get(r.rid, ())) != r.max_gen:
                failures.append(f"{mode}: {r.rid} returned "
                                f"{len(out.get(r.rid, ()))} tokens "
                                f"(want {r.max_gen})")
                break

    # greedy decode must not depend on the admission schedule
    for rid in outputs["continuous"]:
        if not np.array_equal(outputs["continuous"][rid],
                              outputs["static"][rid]):
            failures.append(f"tokens diverge between modes for {rid}")
            break

    cont, stat = modes["continuous"], modes["static"]
    if not (cont["tok_per_s"] and stat["tok_per_s"]
            and cont["tok_per_s"] > stat["tok_per_s"]):
        failures.append(
            f"continuous batching does not beat static on tok/s: "
            f"{cont['tok_per_s']} vs {stat['tok_per_s']}")
    if not (cont["ttft_p50_s"] and stat["ttft_p50_s"]
            and cont["ttft_p50_s"] <= stat["ttft_p50_s"]):
        failures.append(
            f"continuous batching worsens p50 TTFT: "
            f"{cont['ttft_p50_s']} vs {stat['ttft_p50_s']}")

    report = {
        "bench": "serving",
        "arch": cfg.name,
        "config": {"requests": args.requests, "slots": args.slots,
                   "prompt_len": args.prompt_len, "gen": args.gen,
                   "arrival_gap_s": gap_s, "tick_s": DEFAULT_TICK_S,
                   "cache_len": cache_len},
        "modes": modes,
        "speedup_tok_per_s": (cont["tok_per_s"] / stat["tok_per_s"]
                              if cont["tok_per_s"] and stat["tok_per_s"]
                              else None),
        "failures": failures,
    }
    Path(args.out).write_text(json.dumps(report, indent=2))
    print(json.dumps({k: report[k] for k in
                      ("bench", "arch", "speedup_tok_per_s", "failures")},
                     indent=2))
    for mode in ("continuous", "static"):
        m = modes[mode]
        print(f"{mode:11s} tok/s={m['tok_per_s']:.1f} "
              f"ttft_p50={m['ttft_p50_s']:.3f}s "
              f"ttft_p95={m['ttft_p95_s']:.3f}s "
              f"J/req={m['joules_per_request']:.2f}")
    if failures:
        print("FAIL:", *failures, sep="\n  ", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
