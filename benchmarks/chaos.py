"""Chaos benchmark: kill an endpoint mid-trace, measure the recovery.

The online control loop (``repro.runtime.control``) under its acceptance
scenario as a measured artifact: a synthetic two-destination world (fast
power-hungry vs slow frugal, both warm in one ``PlanLookup``), an open-loop
request trace, and a fault plan that kills the fast endpoint mid-trace and
revives it later.  The run reports:

  * requests dropped (**must be 0** — failed requests re-queue and drain
    through the admission ledger) and double completions (**must be 0**);
  * recovery time in ticks: from the circuit opening (quarantine) to the
    half-open probe that closes it (recovered);
  * joules-per-request before the kill vs after recovery — the energy
    price of degrading onto the frugal destination and back;
  * whether any controller replan placed the app on a backend with a
    published failure verdict (**must not happen**).

Emits ``BENCH_chaos.json`` (a CI artifact next to BENCH_fleet.json) plus
a full ``repro.obs`` trace of the run — ``chaos_events.jsonl`` (the
post-mortem input for ``python -m repro.obs.report``) and
``chaos_trace.json`` (Chrome trace-event JSON, loadable in Perfetto) —
and exits 1 on any dropped request, any double completion, a
never-recovered circuit, or a replan onto a failure-verdict backend.

    PYTHONPATH=src python benchmarks/chaos.py [--out BENCH_chaos.json]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, "src")
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

TICK_S = 0.01


class SyntheticBackend:
    """Duck-typed repro.backends.Backend: name + power envelope."""

    def __init__(self, name, power):
        self.name = name
        self.price = 1.0
        self.paper_analogue = ""
        self.power = power


def build_world():
    from repro.core.cost_model import PEAK_FLOPS
    from repro.core.ga import GAConfig
    from repro.core.plan_lookup import PlanLookup, serve_key
    from repro.fleet import FleetApp, FleetPlanner, PoolBackend
    from repro.power import PowerEnvelope
    from repro.serve import Endpoint, HealthConfig, Router

    from repro.obs import get_tracer

    lookup = PlanLookup()
    hot_b = SyntheticBackend("hot", PowerEnvelope("hot", idle_w=100.0,
                                                  peak_w=200.0))
    cool_b = SyntheticBackend("cool", PowerEnvelope("cool", idle_w=5.0,
                                                    peak_w=10.0))
    # per-decode-step rooflines: hot is 4x faster but ~20x the draw.
    # Registering the warm roofline is this synthetic world's stand-in for
    # offline verification, so it carries the same plan/verify span the
    # real planner emits — the post-mortem's per-backend table reads these.
    for order, (name, step_t) in enumerate((("hot", 0.005),
                                            ("cool", 0.02))):
        with get_tracer().span("verify", cat="plan",
                               track=f"backend:{name}", backend=name,
                               method="roofline-register",
                               order=order) as vspan:
            lookup.register(serve_key(name, "app"),
                            {"flops": step_t * PEAK_FLOPS, "bytes": 0.0,
                             "collective_bytes": 0.0})
            vspan.set(best_time_s=step_t, correct=True, compile_s=0.0,
                      cache_hit=True)
    endpoints = [
        Endpoint(name="hot0", backend=hot_b, arch="app", n_slots=8),
        Endpoint(name="cool0", backend=cool_b, arch="app", n_slots=8),
    ]
    router = Router(endpoints, lookup, policy="modeled",
                    health_cfg=HealthConfig(error_threshold=1,
                                            backoff_ticks=4,
                                            backoff_mult=2.0,
                                            probe_quota=1,
                                            probe_successes=1))
    pool = [PoolBackend(name="hot", backend=hot_b, slots=16.0),
            PoolBackend(name="cool", backend=cool_b, slots=16.0)]
    apps = [FleetApp(name="app#0", arch="app", load_rps=1.0,
                     tokens_per_request=2.0)]
    planner = FleetPlanner(pool, lookup,
                           ga_cfg=GAConfig(population=4, generations=4,
                                           seed=0, cardinalities=[2]))
    return router, planner, apps, lookup


def run_scenario(requests: int = 120, kill_at: int = 20,
                 revive_at: int = 60, tracer=None) -> dict:
    """The kill -> quarantine -> drain -> probe -> recover scenario, end to
    end, with every layer's spans landing on ``tracer`` (or nowhere when
    None).  Reused by the determinism pin in tests/test_control.py: the
    same arguments must yield a byte-identical JSONL trace."""
    from repro.obs import NULL_TRACER, use_tracer
    from repro.runtime.control import (ControlLoop, Fault, FaultInjector,
                                       FleetController)
    from repro.serve import Request

    tr = tracer if tracer is not None else NULL_TRACER
    with use_tracer(tr):
        # pin the clock before the world exists so the pre-loop records
        # (verify spans, the fleet plan, GA generations) are deterministic
        tr.set_time(0.0)
        router, planner, apps, lookup = build_world()
        placement = planner.plan(apps)
        controller = FleetController(router, planner, apps,
                                     placement=placement, tick_s=TICK_S)
        trace = [Request(rid=f"r{i:04d}", arch="app", prompt_len=8,
                         max_gen=1, arrival_s=i * TICK_S)
                 for i in range(requests)]
        injector = FaultInjector([Fault(kind="kill", endpoint="hot0",
                                        at_tick=kill_at,
                                        until_tick=revive_at)])
        loop = ControlLoop(router, trace, controller=controller,
                           injector=injector, tick_s=TICK_S,
                           max_ticks=50 * requests)
        misses0 = lookup.stats.misses
        summary = loop.run()
        tr.clear_time()
    return {"router": router, "controller": controller, "lookup": lookup,
            "trace": trace, "placement": placement, "summary": summary,
            "misses0": misses0}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_chaos.json")
    ap.add_argument("--trace-out", default="chaos_trace.json",
                    help="Chrome trace-event JSON (Perfetto-loadable); "
                         "'' disables")
    ap.add_argument("--events-out", default="chaos_events.jsonl",
                    help="JSONL event log for python -m repro.obs.report; "
                         "'' disables")
    ap.add_argument("--requests", type=int, default=120,
                    help="open-loop trace length (one request per tick)")
    ap.add_argument("--kill-at", type=int, default=20)
    ap.add_argument("--revive-at", type=int, default=60)
    args = ap.parse_args()

    from repro import obs
    from repro.serve.health import HEALTHY, QUARANTINED

    tracer = obs.Tracer()
    world = run_scenario(requests=args.requests, kill_at=args.kill_at,
                         revive_at=args.revive_at, tracer=tracer)
    router, controller, lookup = (world["router"], world["controller"],
                                  world["lookup"])
    trace, summary, misses0 = (world["trace"], world["summary"],
                               world["misses0"])

    failures = []
    if summary["dropped"]:
        failures.append(f"{len(summary['dropped'])} requests dropped: "
                        f"{summary['dropped'][:5]}")
    if summary["double_completed"]:
        failures.append(f"{summary['double_completed']} double completions")
    if summary["unrouted"]:
        failures.append(f"{summary['unrouted']} requests never routed")
    if lookup.stats.misses != misses0:
        failures.append("the control loop compiled something "
                        f"({lookup.stats.misses - misses0} new misses)")

    # recovery time: circuit open (first quarantine) -> recovered
    health = router.health["hot0"]
    opened = [t["tick"] for t in health.transitions
              if t["to"] == QUARANTINED]
    recovered = [t["tick"] for t in health.transitions
                 if t["to"] == HEALTHY and t["from"] != HEALTHY]
    if not opened:
        failures.append("the kill never opened the circuit")
    if health.recoveries < 1 or not recovered:
        failures.append("the circuit never recovered after the fault "
                        "window")
    recovery_ticks = (recovered[-1] - opened[0]) \
        if opened and recovered else None

    # replans must never land on a failure-verdict backend
    replans = [e for e in controller.events if e["event"] == "replan"]
    for e in replans:
        for app_name, backend in e["by_app"].items():
            from repro.core.plan_lookup import serve_key
            payload = lookup.lookup(serve_key(backend, "app"))
            if payload is not None and "error" in payload:
                failures.append(f"replan at tick {e['tick']} placed "
                                f"{app_name} on failure-verdict backend "
                                f"{backend}")

    # joules/request before the kill vs after recovery, from the realized
    # per-request energy charges in the serve metrics
    def joules_over(rids):
        ms = [router.metrics.requests[r] for r in rids
              if r in router.metrics.requests]
        ms = [m for m in ms if m.service_s is not None]
        return (sum(m.energy_j for m in ms) / len(ms)) if ms else None

    pre = [r.rid for r in trace if r.arrival_s < args.kill_at * TICK_S]
    post = [r.rid for r in trace
            if recovery_ticks is not None
            and r.arrival_s > recovered[-1] * TICK_S]
    j_pre, j_post = joules_over(pre), joules_over(post)

    out = {
        "bench": "chaos",
        "requests": args.requests,
        "kill_at_tick": args.kill_at,
        "revive_at_tick": args.revive_at,
        "ticks": summary["ticks"],
        "completed": summary["completed"],
        "failed_attempts": summary["failed"],
        "dropped": summary["dropped"],
        "double_completed": summary["double_completed"],
        "dispatches": summary["dispatches"],
        "refusals": summary["refusals"],
        "recovery_ticks": recovery_ticks,
        "probe_cycles": len(opened),
        "replans": len(replans),
        "joules_per_request_before_kill": j_pre,
        "joules_per_request_after_recovery": j_post,
        "fleet_draw_w_max": summary["fleet_draw_w_max"],
        "fleet_draw_w_min": summary["fleet_draw_w_min"],
        "endpoint_summary": router.metrics.endpoint_summary(),
        "failures": failures,
    }
    if args.events_out:
        obs.write_jsonl(tracer.records, args.events_out)
        out["events_jsonl"] = args.events_out
        print(f"wrote {args.events_out} "
              f"(post-mortem: python -m repro.obs.report {args.events_out})")
    if args.trace_out:
        obs.write_chrome_trace(tracer.records, args.trace_out)
        out["chrome_trace"] = args.trace_out
        print(f"wrote {args.trace_out} (load in Perfetto / about:tracing)")
    Path(args.out).write_text(json.dumps(out, indent=1))
    print(f"chaos: {summary['completed']}/{args.requests} completed, "
          f"0 dropped expected (got {len(summary['dropped'])}), "
          f"recovery {recovery_ticks} ticks over {len(opened)} "
          f"probe cycle(s)")
    print(f"chaos: joules/request {j_pre if j_pre is not None else 'n/a'}"
          f" (before kill) -> "
          f"{j_post if j_post is not None else 'n/a'} (after recovery)")
    print(f"wrote {args.out}")
    if failures:
        print("FAIL:", *failures, sep="\n  ")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
