import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Modeled-TPU mixed-destination table: each paper app is compiled per
destination on the production (16,16) mesh and scored with the three-term
roofline — the pod-scale counterpart of Fig. 3 (run as a subprocess by
benchmarks.run so the main bench process keeps 1 device).

Destinations:
  * xla_dp      — all-parallel-safe nests on the dp impl, inputs sharded on
                  the data axes only.
  * sharded_tp  — tp impls, inputs row-sharded on data and contraction
                  dims on model.
  * pallas      — analytic MXU-kernel model: max(flops/peak,
                  io_bytes/hbm_bw) per offloaded nest + xla for the rest
                  (kernel "synthesis" replaces XLA lowering, so its cost is
                  modeled from the kernel's tile dataflow, not from the CPU
                  interpreter's HLO).
"""
import json
import sys
from pathlib import Path


def main():
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.apps import APPS
    from repro.core import cost_model, jaxpr_tools
    from repro.core import search_cache as sc
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=False)
    n_chips = mesh.size
    rows = []

    def roofline_of(fn, inputs, shardings):
        jitted = jax.jit(fn, in_shardings=(shardings,))
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), inputs)
        comp = jitted.lower(sds).compile()
        # memoized per artifact (repro.core.search_cache): the HLO text is
        # parsed once even when a destination's roofline is re-derived
        a = sc.analyze_compiled(comp)
        return cost_model.roofline_from_analysis(a, n_chips=n_chips)

    def shard_state(inputs, axis):
        size = 1
        for a in (axis if isinstance(axis, tuple) else (axis,)):
            size *= mesh.shape[a]
        out = {}
        for k, v in inputs.items():
            if v.ndim >= 1 and v.shape[0] % size == 0:
                out[k] = NamedSharding(mesh, P(axis))
            elif v.ndim >= 1 and v.shape[0] % 16 == 0:
                out[k] = NamedSharding(mesh, P("data"))
            else:
                out[k] = NamedSharding(mesh, P())
        return out

    for name in ("3mm", "NAS.BT", "tdFIR"):
        app = APPS[name]()
        inputs = app.make_inputs(seed=0)
        safe = lambda key: {n.name: key for n in app.nests
                            if n.parallel_safe and key in n.impls}

        # xla_dp: data-axis sharding (many-core analogue)
        rl_dp = roofline_of(app.build(safe("dp")), inputs,
                            shard_state(inputs, "data"))
        rows.append((name, "many-core CPU|xla_dp", rl_dp))
        # sharded_tp: data+model sharding with tp impls (GPU analogue)
        rl = roofline_of(app.build(safe("tp")), inputs,
                         shard_state(inputs, ("data", "model")))
        rows.append((name, "GPU|sharded_tp", rl))

        # pallas (FPGA analogue): analytic MXU kernel model for offloadable
        # nests; remaining nests use the xla_dp roofline proportionally.
        state = dict(inputs)
        kern_s = 0.0
        covered = 0
        for nest in app.nests:
            fl = jaxpr_tools.flop_estimate(nest.impls["seq"], state)
            by = jaxpr_tools.byte_estimate(nest.impls["seq"], state)
            state = jax.jit(nest.impls["seq"])(state)
            if "pallas" in nest.impls:
                kern_s += max(fl / (cost_model.PEAK_FLOPS * n_chips),
                              by / (cost_model.HBM_BW * n_chips))
                covered += 1
        if covered:
            # same artifact as the xla_dp row — reuse its roofline instead
            # of lowering and compiling the dp build a second time
            base = rl_dp
            pallas_step = base.step_time_s * 0.5 + kern_s
            rows.append((name, "FPGA|pallas",
                         cost_model.roofline_terms(
                             base.flops_per_device,
                             base.bytes_per_device * 0.5,
                             base.collective_bytes_per_device,
                             n_chips=n_chips)))
            rows[-1][2].step_time_s = pallas_step

    out = []
    for name, dest, rl in rows:
        out.append({"app": name, "destination": dest,
                    "step_time_s": rl.step_time_s,
                    "dominant": rl.dominant,
                    "compute_s": rl.compute_s, "memory_s": rl.memory_s,
                    "collective_s": rl.collective_s})
        print(f"modeled/{name}/{dest},{rl.step_time_s*1e6:.3f},"
              f"dominant={rl.dominant}")
    Path(sys.argv[1] if len(sys.argv) > 1 else
         "experiments/modeled_fig3.json").write_text(
        json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
