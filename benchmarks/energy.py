"""Energy benchmark: power-aware vs host-time destination selection.

For each paper app the planner runs the full verification pipeline once,
then both objectives are applied to the *same* records (selection is pure
ranking, so no re-search is needed):

  * ``host_time`` — the paper's fastest-correct rule;
  * ``power``     — lowest modeled joules per step (repro.power: each
    record is charged its backend envelope x roofline utilization, or
    envelope x host time when only a host measurement exists);
  * ``power_slowdown`` — the power follow-up's headline evaluation: lowest
    energy among destinations within MAX_SLOWDOWN of the fastest.

Emits ``BENCH_energy.json`` (a CI artifact next to BENCH_search.json) and
exits 1 if the power policy ever selects an incorrect record, or if any
correct finite record is missing its energy charge — the invariant the CI
step gates on.

    PYTHONPATH=src python benchmarks/energy.py [--out BENCH_energy.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, "src")
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

MAX_SLOWDOWN = 1.3          # the follow-up's "allowed slowdown" knob
APPS_UNDER_TEST = ("3mm", "NAS.BT", "tdFIR")


def _sel_row(rec):
    if rec is None:
        return None
    return {
        "destination": rec.destination,
        "paper_analogue": rec.paper_analogue,
        "method": rec.method,
        "time_s": rec.best_time_s,
        "energy_j": rec.energy_j,
        "avg_watts": rec.avg_watts,
        "correct": rec.correct,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_energy.json")
    ap.add_argument("--max-slowdown", type=float, default=MAX_SLOWDOWN)
    args = ap.parse_args()

    from repro.apps import APPS
    from repro.backends import get_policy
    from repro.core.ga import GAConfig
    from repro.core.measure import TimedRunner
    from repro.core.planner import UserTarget, plan_offload

    host_pol = get_policy("host-time")
    power_pol = get_policy("power")
    rows = {}
    failures = []
    for name in APPS_UNDER_TEST:
        app = APPS[name]()
        inputs = app.make_inputs(seed=0, small=True)
        t0 = time.time()
        report = plan_offload(
            app, UserTarget(), inputs=inputs,
            runner=TimedRunner(repeats=1),
            ga_cfg=GAConfig.for_gene_length(min(app.gene_length, 6),
                                            seed=0),
            policy="power")
        correct = [r for r in report.records
                   if r.correct and r.best_time_s < float("inf")]
        for r in correct:
            if r.energy_j is None or r.avg_watts is None:
                failures.append(f"{name}: correct record "
                                f"{r.destination}/{r.method} has no "
                                f"energy charge")
        host_sel = host_pol.select(report.records)
        power_sel = report.selected
        slowdown_sel = power_pol.select(
            report.records, max_slowdown=args.max_slowdown)
        for tag, sel in (("power", power_sel),
                         ("power_slowdown", slowdown_sel)):
            if sel is not None and not sel.correct:
                failures.append(f"{name}: {tag} selected an INCORRECT "
                                f"record ({sel.destination})")
        saving = None
        if (host_sel is not None and power_sel is not None
                and host_sel.energy_j and power_sel.energy_j is not None):
            saving = (1.0 - power_sel.energy_j / host_sel.energy_j) * 100.0
        rows[name] = {
            "plan_elapsed_s": round(time.time() - t0, 2),
            "ref_time_s": report.ref_time_s,
            "host_time_choice": _sel_row(host_sel),
            "power_choice": _sel_row(power_sel),
            "power_within_slowdown_choice": _sel_row(slowdown_sel),
            "max_slowdown": args.max_slowdown,
            "energy_saving_pct_vs_host_choice": saving,
            "records": report.summary_rows(),
        }
        h = rows[name]["host_time_choice"] or {}
        p = rows[name]["power_choice"] or {}
        saving_tag = "n/a" if saving is None else f"{saving:.1f}%"
        print(f"energy/{name}: host-time -> {h.get('paper_analogue')} "
              f"({(h.get('energy_j') or 0):.2f} J) | power -> "
              f"{p.get('paper_analogue')} ({(p.get('energy_j') or 0):.2f} J)"
              f" | saving {saving_tag}")

    out = {
        "bench": "energy",
        "max_slowdown": args.max_slowdown,
        "apps": rows,
        "failures": failures,
    }
    Path(args.out).write_text(json.dumps(out, indent=1))
    print(f"wrote {args.out}")
    if failures:
        print("FAIL:", *failures, sep="\n  ")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
