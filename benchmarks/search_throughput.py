"""Search-throughput benchmark: structure-keyed cache vs per-candidate cost.

Scores the *same* gene population three ways and emits ``BENCH_search.json``:

  * ``uncached`` — the pre-cache baseline: every candidate is traced,
    XLA-compiled and its HLO re-parsed individually;
  * ``cached_cold`` — ``repro.core.search_cache`` with an empty disk file:
    the generation is deduped by ``Plan.structural_key()`` first, so only
    unique structural artifacts compile (the schedule genes ride for free);
  * ``cached_warm`` — a fresh process against the disk layer the cold run
    wrote: zero compiles, pure roofline arithmetic.

A fourth section (``linted``) crosses the population with every
``microbatches`` gene value under a batch-6 shape and evaluates it with the
``repro.analysis`` plan linter off vs on: infeasible values are structural
(each costs a real compile unlinted) and must be statically pruned before
any trace — the section reports the pruned count and candidates/second both
ways.

The population is deliberately schedule-heavy (every structural base is
crossed with all pipeline_schedule x virtual_stages combinations) — the
exact redundancy the GA exhibits, since the model-only genes multiply the
candidate count but not the artifact count.

    PYTHONPATH=src python benchmarks/search_throughput.py \
        [--structural 2] [--out BENCH_search.json]
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, "src")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def build_population(n_structural: int):
    """n_structural bases x every model-only schedule combination."""
    from repro.dist.plan import Plan

    base = [0] * len(Plan.GENE_SPACE)
    idx = {g.field: i for i, g in enumerate(Plan.GENE_SPACE)}
    structural_flips = [("remat", 1), ("remat", 2), ("attn_block_q", 1),
                        ("vocab_chunk", 1)]
    bases = [list(base)]
    for f, v in structural_flips[:max(n_structural - 1, 0)]:
        g = list(base)
        g[idx[f]] = v
        bases.append(g)

    sched_i, virt_i = idx["pipeline_schedule"], idx["virtual_stages"]
    n_sched = len(Plan.GENE_SPACE[sched_i].choices)
    n_virt = len(Plan.GENE_SPACE[virt_i].choices)
    population = []
    for b in bases:
        for s in range(n_sched):
            for v in range(n_virt):
                g = list(b)
                g[sched_i], g[virt_i] = s, v
                population.append(tuple(g))
    return population


def make_lower_plan():
    """A small-but-real train step whose artifact depends on the structural
    genes (remat toggles checkpointing, attn_block_q the hidden width,
    vocab_chunk the loss chunking) — compile cost is genuine XLA work."""
    import jax
    import jax.numpy as jnp

    def lower_plan(plan):
        width = plan.attn_block_q
        chunk = plan.vocab_chunk or 0

        def loss_fn(w1, w2, x):
            h = jnp.tanh(x @ w1)
            out = h @ w2
            if chunk:
                parts = jnp.split(out, 2, axis=-1)
                return sum(jnp.sum(p ** 2) for p in parts)
            return jnp.sum(out ** 2)

        inner = (jax.checkpoint(loss_fn) if plan.remat != "none"
                 else loss_fn)

        def step(w1, w2, x):
            loss, grads = jax.value_and_grad(inner, argnums=(0, 1))(
                w1, w2, x)
            return loss, grads

        sds = (jax.ShapeDtypeStruct((64, width), jnp.float32),
               jax.ShapeDtypeStruct((width, 64), jnp.float32),
               jax.ShapeDtypeStruct((32, 64), jnp.float32))
        return jax.jit(step).lower(*sds)

    return lower_plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--structural", type=int, default=2,
                    help="unique structural bases in the population")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--out", default="BENCH_search.json")
    ap.add_argument("--cache-file", default=None,
                    help="disk-cache path (default: a fresh temp file)")
    args = ap.parse_args()

    import tempfile

    from repro.core import cost_model
    from repro.core import search_cache as sc
    from repro.core.hlo_analysis import analyze_hlo
    from repro.core.measure import CompiledCostRunner
    from repro.dist.plan import Plan

    population = build_population(args.structural)
    unique_keys = {Plan.from_genes(list(g)).structural_key()
                   for g in population}
    lower_plan = make_lower_plan()
    runner = CompiledCostRunner(n_chips=1)
    print(f"population: {len(population)} candidates, "
          f"{len(unique_keys)} unique structural keys")

    # --- uncached baseline: per-candidate lower + compile + HLO reparse
    t0 = time.perf_counter()
    for genes in population:
        plan = Plan.from_genes(list(genes))
        compiled = lower_plan(plan).compile()
        analyzed = analyze_hlo(compiled.as_text())
        runner.score_analysis(
            analyzed,
            bubble_fraction=cost_model.plan_bubble_fraction(plan, 2))
    uncached_s = time.perf_counter() - t0

    cache_file = args.cache_file or os.path.join(
        tempfile.mkdtemp(prefix="bench-search-"), "cache.json")

    def cached_pass():
        cache = sc.SearchCache(cache_file)
        evaluate_batch = sc.make_cached_batch_evaluator(
            lower_plan, runner, cache, key_extra=("bench", "mlp"),
            pipe_ranks=2, workers=args.workers)
        t0 = time.perf_counter()
        evs = evaluate_batch(list(population))
        dt = time.perf_counter() - t0
        assert all(e.correct for e in evs), \
            [e.info.get("error") for e in evs if not e.correct]
        return dt, cache.stats

    cold_s, cold_stats = cached_pass()
    warm_s, warm_stats = cached_pass()

    # --- tracer-overhead guard (repro.obs): the warm pass is the search
    # hot path, so it must not slow down when instrumented.  Interleaved
    # min-of-N damps scheduler noise; "disabled" overhead (the ambient
    # NULL_TRACER's no-op spans vs no instrumentation at all) is bounded
    # by microbenchmarking the null span and scaling by the span count an
    # enabled pass actually emits.
    from repro import obs

    REPEATS = 5
    disabled_best = enabled_best = float("inf")
    recording = obs.Tracer()
    for _ in range(REPEATS):
        disabled_best = min(disabled_best, cached_pass()[0])
        recording.records.clear()
        with obs.use_tracer(recording):
            enabled_best = min(enabled_best, cached_pass()[0])
    spans_per_pass = len(recording.records)
    t0 = time.perf_counter()
    NULL_ITERS = 100_000
    for _ in range(NULL_ITERS):
        with obs.get_tracer().span("x", cat="search"):
            pass
    null_span_s = (time.perf_counter() - t0) / NULL_ITERS
    disabled_overhead_pct = round(
        100.0 * (spans_per_pass * null_span_s) / disabled_best, 4)
    enabled_overhead_pct = round(
        100.0 * (enabled_best - disabled_best) / disabled_best, 2)

    # --- linted pass (repro.analysis): cross the population with every
    # microbatches gene value under a batch-6 shape — values that don't
    # divide the batch are statically infeasible, and the linter must prune
    # them before any trace/compile (microbatches is structural, so without
    # the linter each infeasible value costs a real XLA compile)
    from repro.analysis import lint_plan
    from repro.configs.base import ShapeConfig

    idx = {g.field: i for i, g in enumerate(Plan.GENE_SPACE)}
    mb_i = idx["microbatches"]
    lint_pop = []
    for g in population:
        for m in range(len(Plan.GENE_SPACE[mb_i].choices)):
            gg = list(g)
            gg[mb_i] = m
            lint_pop.append(tuple(gg))
    lint_shape = ShapeConfig("bench_b6", seq_len=32, global_batch=6,
                             kind="train")

    def linted_pass(lint):
        cache = sc.SearchCache()        # memory-only, fresh per pass
        evaluate_batch = sc.make_cached_batch_evaluator(
            lower_plan, runner, cache, key_extra=("bench", "mlp-lint"),
            pipe_ranks=2, workers=args.workers, lint=lint)
        t0 = time.perf_counter()
        evaluate_batch(list(lint_pop))
        return time.perf_counter() - t0, cache.stats

    lint_off_s, lint_off_stats = linted_pass(None)
    lint_on_s, lint_on_stats = linted_pass(
        lambda plan: lint_plan(plan, shape=lint_shape))
    assert lint_on_stats.static_pruned > 0
    assert lint_on_stats.unique_compiles < lint_off_stats.unique_compiles, \
        (lint_on_stats.unique_compiles, lint_off_stats.unique_compiles)

    n = len(population)
    n_lint = len(lint_pop)
    result = {
        "candidates": n,
        "unique_structural_keys": len(unique_keys),
        "uncached": {"wall_s": round(uncached_s, 3), "compiles": n,
                     "candidates_per_s": round(n / uncached_s, 3)},
        "cached_cold": {"wall_s": round(cold_s, 3),
                        "compiles": cold_stats.unique_compiles,
                        "hit_rate": round(cold_stats.hit_rate, 4),
                        "candidates_per_s": round(n / cold_s, 3)},
        "cached_warm": {"wall_s": round(warm_s, 3),
                        "compiles": warm_stats.unique_compiles,
                        "hit_rate": round(warm_stats.hit_rate, 4),
                        "disk_hits": warm_stats.disk_hits,
                        "candidates_per_s": round(n / warm_s, 3)},
        "speedup_cold": round(uncached_s / cold_s, 2),
        "speedup_warm": round(uncached_s / warm_s, 2),
        "tracer_overhead": {
            "repeats": REPEATS,
            "spans_per_pass": spans_per_pass,
            "null_span_ns": round(null_span_s * 1e9, 1),
            "disabled_cps": round(n / disabled_best, 3),
            "enabled_cps": round(n / enabled_best, 3),
            "disabled_overhead_pct": disabled_overhead_pct,
            "enabled_overhead_pct": enabled_overhead_pct,
        },
        "linted": {
            "candidates": n_lint,
            "shape": {"global_batch": lint_shape.global_batch,
                      "kind": lint_shape.kind},
            "off": {"wall_s": round(lint_off_s, 3),
                    "compiles": lint_off_stats.unique_compiles,
                    "static_pruned": lint_off_stats.static_pruned,
                    "candidates_per_s": round(n_lint / lint_off_s, 3)},
            "on": {"wall_s": round(lint_on_s, 3),
                   "compiles": lint_on_stats.unique_compiles,
                   "static_pruned": lint_on_stats.static_pruned,
                   "candidates_per_s": round(n_lint / lint_on_s, 3)},
            "speedup": round(lint_off_s / lint_on_s, 2),
        },
    }
    Path(args.out).write_text(json.dumps(result, indent=1))

    print("name,us_per_call,derived")
    for k in ("uncached", "cached_cold", "cached_warm"):
        r = result[k]
        print(f"search/{k},{r['wall_s'] / n * 1e6:.1f},"
              f"compiles={r['compiles']}|cps={r['candidates_per_s']}")
    for k in ("off", "on"):
        r = result["linted"][k]
        print(f"search/lint_{k},{r['wall_s'] / n_lint * 1e6:.1f},"
              f"compiles={r['compiles']}|pruned={r['static_pruned']}"
              f"|cps={r['candidates_per_s']}")
    print(f"search/speedup,{result['speedup_cold']},"
          f"warm={result['speedup_warm']}x "
          f"lint={result['linted']['speedup']}x -> {args.out}")
    ov = result["tracer_overhead"]
    print(f"search/tracer_overhead,disabled={ov['disabled_overhead_pct']}%,"
          f"enabled={ov['enabled_overhead_pct']}% "
          f"({ov['spans_per_pass']} spans/pass)")
    # acceptance: the cached path scores >= 3x candidates/second on the
    # same population (cold already: 6 schedule combos share one compile)
    if result["speedup_cold"] < 3.0 and result["speedup_warm"] < 3.0:
        print("WARNING: cached speedup below 3x", file=sys.stderr)
        return 1
    # acceptance: instrumentation is free when disabled (<=2% of the warm
    # pass) and cheap when recording (<=10% candidates/sec regression)
    if ov["disabled_overhead_pct"] > 2.0:
        print("WARNING: null-tracer overhead above 2%", file=sys.stderr)
        return 1
    if ov["enabled_overhead_pct"] > 10.0:
        print("WARNING: enabled-tracer overhead above 10%", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
