"""Regression tests for the planner / GA verification-flow bugs.

  * residual rule: a no-match FPGA FB verification (verification 3) used to
    `continue` past the pinning block, so loop searches ignored the winning
    many-core / GPU FB patterns;
  * GAConfig.penalty_s was silently dropped (Evaluation hard-coded the
    module constant);
  * the single-core reference was compiled and executed twice;
  * TimedRunner only enforced timeout_s on the first call — steady-state
    repeats ran unbounded;
  * outputs_close cast integer results through float64 (lossy above 2**53).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.function_blocks import FunctionBlockEntry, Registry
from repro.core.ga import (Evaluation, GAConfig, PENALTY_TIME_S, run_ga)
from repro.core.measure import TimedRunner
from repro.core.offloadable import LoopNest, OffloadableApp
from repro.core.planner import UserTarget, plan_offload


class ScriptedRunner:
    """Deterministic verification environment: the app encodes its own
    "processing time" in the output scalar, so planner ordering logic can
    be tested without wall-clock noise."""

    def measure(self, fn, inputs, reference_out):
        out = fn(inputs)
        return Evaluation(time_s=float(out), correct=True,
                          info={"output": out})


def _scripted_app_and_registry():
    """One nest, FB impls for dp/tp only (no pallas) -> verification 3 has
    no offloadable function block.  seq=1.0, loop impls=0.8, FB impls=0.5
    (times are the output values ScriptedRunner reads back)."""

    def stage(value):
        def impl(state):
            s = dict(state)
            s["out"] = jnp.float32(value)
            return s
        return impl

    nest = LoopNest(name="conv_stage",
                    impls={"seq": stage(1.0), "dp": stage(0.8),
                           "tp": stage(0.8), "pallas": stage(0.8)})
    app = OffloadableApp(
        name="scripted",
        nests=[nest],
        make_inputs=lambda seed=0, small=False: {"x": jnp.ones((4,))})

    registry = Registry()
    registry.register(FunctionBlockEntry(
        name="convblock",
        match_names=("conv",),
        ref_fn=lambda state: state["x"],
        example_args=lambda: ({"x": jnp.ones((4,))},),
        impls={"dp": stage(0.5), "tp": stage(0.5)}))   # no pallas FB
    return app, registry


def test_fb_pinned_when_verification_3_has_no_match():
    app, registry = _scripted_app_and_registry()
    report = plan_offload(app, UserTarget(), runner=ScriptedRunner(),
                          ga_cfg=GAConfig(population=2, generations=2),
                          registry=registry)
    assert len(report.records) == 6
    fb3 = report.records[2]
    assert fb3.method == "function_block"
    assert fb3.best_time_s == float("inf")          # no pallas FB impl
    assert "no offloadable function block" in fb3.note
    # the dp FB win (0.5 < ref 1.0) must be pinned into the loop searches
    for rec in report.records[3:]:
        assert rec.method == "loop"
        assert rec.choice.get("conv_stage", "").startswith("fb_convblock_"), \
            (rec.order, rec.choice)


def test_reference_executed_once():
    """plan_offload reuses the measured reference output instead of
    compiling + running the reference a second time."""
    app, registry = _scripted_app_and_registry()
    calls = {"ref": 0}
    orig_build = app.build

    def counting_build(choice):
        fn = orig_build(choice)
        if not choice:                              # the reference pattern
            def wrapped(state):
                calls["ref"] += 1
                return fn(state)
            return wrapped
        return fn

    app.build = counting_build
    plan_offload(app, UserTarget(), runner=ScriptedRunner(),
                 ga_cfg=GAConfig(population=2, generations=2),
                 registry=registry)
    assert calls["ref"] == 1


def test_timed_runner_returns_output_and_reference_is_correct():
    ev = TimedRunner(repeats=1).measure(
        lambda s: s["x"] * 2.0, {"x": jnp.arange(4.0)}, None)
    assert ev.correct                      # reference run: trivially correct
    assert "output" in ev.info
    assert float(jax.numpy.sum(ev.info["output"])) == pytest.approx(12.0)


def test_timed_runner_timeout_covers_steady_state_repeats():
    """A candidate whose steady-state repeats hang must hit the penalty
    path after the first hanging repeat instead of running repeats x hang
    unbounded (timeout_s was only checked on the first call).  The budget
    is per call, so slow-but-correct candidates under timeout_s per run
    keep their old ranking."""
    calls = {"n": 0}

    def slow(s):
        def hang(x):
            calls["n"] += 1
            if calls["n"] > 1:                      # steady state hangs
                time.sleep(1.5)
            return x
        return jax.pure_callback(
            hang, jax.ShapeDtypeStruct(s["x"].shape, s["x"].dtype), s["x"])

    runner = TimedRunner(timeout_s=1.0, repeats=10)
    t0 = time.perf_counter()
    ev = runner.measure(slow, {"x": jnp.arange(4.0)}, jnp.arange(4.0))
    elapsed = time.perf_counter() - t0
    assert ev.timed_out and not ev.correct
    assert ev.effective_time == ev.penalty_s        # paper's 1000 s path
    assert elapsed < 10.0, "repeats ran unbounded past timeout_s"


def test_outputs_close_integer_leaves_compare_exactly():
    from repro.core.measure import outputs_close

    big = np.array([2 ** 53], dtype=np.int64)
    # differs by 1, but float64 cannot represent the difference
    assert not outputs_close(big, big + 1)
    assert outputs_close(big, big.copy())
    assert not outputs_close(np.array([True, False]),
                             np.array([True, True]))
    # float leaves keep the tolerance-based comparison
    assert outputs_close(np.float32([1.0]), np.float32([1.001]))
    # mixed int/float pairs still compare numerically
    assert outputs_close(np.int32([2]), np.float64([2.0]))


# ------------------------------------------------------------- GA penalty
def test_custom_penalty_changes_effective_time():
    assert Evaluation(time_s=1.0, correct=False).effective_time \
        == PENALTY_TIME_S
    assert Evaluation(time_s=1.0, correct=False,
                      penalty_s=7.0).effective_time == 7.0
    assert Evaluation(time_s=1.0, correct=False, penalty_s=7.0).fitness \
        == pytest.approx(7.0 ** -0.5)
    # correct evaluations are unaffected
    assert Evaluation(time_s=1.0, correct=True,
                      penalty_s=7.0).effective_time == 1.0


def test_run_ga_threads_config_penalty():
    def evaluate(genes):
        # gene (1,) is "correct" and slow; everything else is wrong
        if genes == (1,):
            return Evaluation(time_s=50.0, correct=True)
        return Evaluation(time_s=0.001, correct=False)

    cfg = GAConfig(population=2, generations=2, penalty_s=10.0, seed=0)
    res = run_ga(1, evaluate, cfg)
    wrong = [e for e in res.evaluations.values() if not e.correct]
    assert wrong, "expected the all-zeros baseline to be evaluated"
    for e in wrong:
        assert e.effective_time == 10.0     # not the 1000 s module default
    # the configured penalty shapes selection pressure, but a wrong result
    # must never WIN the search, even with penalty 10 < 50
    assert res.best_genes == (1,)
    assert res.best_eval.correct and res.best_eval.effective_time == 50.0


def test_run_ga_all_wrong_falls_back_to_penalized_best():
    def evaluate(genes):
        return Evaluation(time_s=0.001, correct=False)

    cfg = GAConfig(population=2, generations=2, penalty_s=10.0, seed=0)
    res = run_ga(1, evaluate, cfg)
    assert not res.best_eval.correct
    assert res.best_eval.effective_time == 10.0


def test_penalty_threads_through_planner_measurements():
    """Every verification in one plan_offload run sees the configured
    penalty scale, not only the GA-internal evaluations."""
    app, registry = _scripted_app_and_registry()

    class WrongRunner(ScriptedRunner):
        def measure(self, fn, inputs, reference_out):
            ev = super().measure(fn, inputs, reference_out)
            if reference_out is not None:      # every candidate is "wrong"
                ev.correct = False
            return ev

    report = plan_offload(app, UserTarget(), runner=WrongRunner(),
                          ga_cfg=GAConfig(population=2, generations=2,
                                          penalty_s=7.0),
                          registry=registry)
    finite = [r for r in report.records if r.best_time_s < float("inf")]
    assert finite
    for rec in finite:                        # FB, GA-loop and FPGA-loop
        assert rec.best_time_s == 7.0, (rec.order, rec.best_time_s)
        assert not rec.correct
    # and a penalized wrong result is never the selected destination
    assert report.selected is None
