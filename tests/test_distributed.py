"""Multi-device behaviour on a forced 8-device host (subprocess per test so
the main pytest process keeps exactly 1 device, per the task spec)."""
from helpers import run_multidevice


def test_sharded_train_step_runs_and_matches_single_device():
    run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.dist.plan import Plan
from repro.dist.sharding import Rules, tree_shardings
from repro.launch.mesh import make_test_mesh
from repro.models.lm import Model, param_axes
from repro.train import optimizer, train_step as ts

cfg = get_config('granite-3-2b').reduced()
mesh = make_test_mesh((4, 2))
plan = Plan(vocab_chunk=8)
tcfg = TrainConfig(lr=1e-3, warmup_steps=1)
batch = {'tokens': jnp.ones((8, 16), jnp.int32),
         'labels': jnp.ones((8, 16), jnp.int32)}

def run(rules_mesh):
    rules = Rules(rules_mesh, plan) if rules_mesh is not None else None
    from repro.dist.sharding import NullRules
    model = Model(cfg, plan, rules or NullRules())
    params = model.init(jax.random.PRNGKey(0))
    opt = optimizer.init(params, tcfg)
    step = ts.make_train_step(model, tcfg)
    if rules_mesh is not None:
        p_sds = jax.eval_shape(lambda: params)
        p_sh = tree_shardings(rules, param_axes(cfg), p_sds)
        params = jax.device_put(params, p_sh)
        opt = jax.device_put(opt, jax.tree.map(
            lambda _: None, opt, is_leaf=lambda x: False) or opt)
        step = jax.jit(step)
    else:
        step = jax.jit(step)
    p2, o2, m = step(params, opt, batch, jnp.int32(0))
    return float(m['loss'])

l_multi = run(mesh)
l_single = run(None)
assert abs(l_multi - l_single) < 1e-3, ('FAIL', l_multi, l_single)
print('ok', l_multi, l_single)
""")


def test_rules_divisibility_fallback():
    run_multidevice("""
import jax
from jax.sharding import PartitionSpec as P
from repro.dist.plan import Plan
from repro.dist.sharding import Rules
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 4))
rules = Rules(mesh, Plan())
# heads=10 not divisible by model=4 -> replicated; ff=16 divisible -> sharded
spec = rules.spec(("embed", "heads", None), dims=(64, 10, 7))
assert spec == P(("data",)), ('FAIL', spec)
spec = rules.spec(("embed", "ff"), dims=(64, 16))
assert spec == P(("data",), "model"), ('FAIL', spec)
# duplicate axis: kv_seq takes model first, kv_heads falls back
plan = Plan(decode_kv_seq_shard=True)
rules = Rules(mesh, plan)
spec = rules.spec(("batch", "kv_seq", "kv_heads", None),
                  dims=(8, 32, 8, 4))
assert spec == P(("data",), "model"), ('FAIL', spec)
print('ok')
""")


def test_checkpoint_reshard_on_restore():
    run_multidevice("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpointer import Checkpointer
from repro.launch.mesh import make_test_mesh

mesh_a = make_test_mesh((4, 2))
mesh_b = make_test_mesh((2, 2))    # "after losing half the slice"
x = jnp.arange(64.0).reshape(8, 8)
xa = jax.device_put(x, NamedSharding(mesh_a, P('data', 'model')))
with tempfile.TemporaryDirectory() as d:
    ck = Checkpointer(d)
    ck.save(1, {'x': xa})
    got, _ = ck.restore(1, shardings={'x': NamedSharding(mesh_b,
                                                         P('data', None))})
    assert got['x'].sharding.spec == P('data', None), 'FAIL spec'
    np.testing.assert_array_equal(np.asarray(got['x']), np.asarray(x))
print('ok')
""")


def test_compressed_psum_close_to_plain():
    run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_test_mesh
from repro.train.grad_compression import (compressed_psum, plain_psum,
                                          init_error_feedback)

from repro.dist.compat import shard_map

mesh = make_test_mesh((8,), ('pod',))

def body(g, ef):
    out, new_ef = compressed_psum({'g': g}, {'g': ef}, 'pod')
    exact = plain_psum({'g': g}, 'pod')
    return out['g'], new_ef['g'], exact['g']

g = jax.random.normal(jax.random.PRNGKey(0), (8, 256)) * 0.1
ef = jnp.zeros((8, 256))
f = shard_map(body, mesh=mesh, in_specs=(P('pod'), P('pod')),
              out_specs=(P('pod'), P('pod'), P('pod')))
out, new_ef, exact = f(g, ef)
rel = float(jnp.abs(out - exact).max() / (jnp.abs(exact).max() + 1e-9))
assert rel < 0.05, ('FAIL rel', rel)
# error feedback captures the residual: ef + deq == pre-quant grads
assert float(jnp.abs(new_ef).max()) > 0, 'FAIL ef empty'
# second step with error feedback reduces accumulated bias
out2, ef2, exact2 = f(g, new_ef)
print('ok', rel)
""")


def test_decode_kv_seq_sharding_lowers():
    run_multidevice("""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.dist.plan import Plan
from repro.dist.sharding import Rules, tree_shardings
from repro.launch.mesh import make_test_mesh
from repro.models.lm import Model, param_axes, cache_axes, init_cache
from repro.train import train_step as ts

cfg = get_config('granite-3-2b').reduced()
mesh = make_test_mesh((2, 4))
plan = Plan(decode_kv_seq_shard=True, remat='none')
rules = Rules(mesh, plan)
model = Model(cfg, plan, rules)
params_sds = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,),
                                                             jnp.uint32))
p_sh = tree_shardings(rules, param_axes(cfg), params_sds)
cache_sds = jax.eval_shape(lambda: init_cache(cfg, 8, 64))
c_sh = tree_shardings(rules, cache_axes(cfg), cache_sds)
fn = ts.make_serve_step(model)
jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, None, None))
comp = jitted.lower(params_sds, cache_sds,
                    jax.ShapeDtypeStruct((8, 1), jnp.int32),
                    jax.ShapeDtypeStruct((), jnp.int32)).compile()
txt = comp.as_text()
assert ('all-reduce' in txt) or ('all-gather' in txt), 'FAIL no collectives'
print('ok')
""")


def test_pod_parallel_train_step_with_compression():
    run_multidevice("""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.dist.plan import Plan
from repro.dist.sharding import Rules
from repro.models.lm import Model
from repro.train import optimizer, train_step as ts
from repro.dist.compat import AxisType, mesh_from_devices, set_mesh
mesh = mesh_from_devices(jax.devices(), (2, 2, 2),
                         ('pod', 'data', 'model'),
                         axis_types=(AxisType.Auto,) * 3)
cfg = get_config('granite-3-2b').reduced()
plan = Plan(grad_compression=True, vocab_chunk=8)
tcfg = TrainConfig(lr=1e-3, warmup_steps=1)
model = Model(cfg, plan, Rules(mesh, plan))
params = model.init(jax.random.PRNGKey(0))
opt = optimizer.init(params, tcfg)
opt['ef'] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
batch = {'tokens': jnp.ones((8, 16), jnp.int32),
         'labels': jnp.ones((8, 16), jnp.int32)}
step = ts.make_pod_parallel_train_step(model, tcfg, mesh)
with set_mesh(mesh):
    p2, o2, m = jax.jit(step)(params, opt, batch, jnp.int32(0))
import math
assert math.isfinite(float(m['loss'])), 'FAIL loss'
print('ok', float(m['loss']))
""", n_devices=8)


def test_moe_ep_shardmap_matches_gspmd():
    run_multidevice("""
import jax, jax.numpy as jnp
from repro.configs import ARCHS
from repro.models import moe as moe_mod
from repro.dist.plan import Plan
from repro.dist.sharding import Rules
from repro.launch.mesh import make_test_mesh

cfg = ARCHS['moonshot-v1-16b-a3b'].reduced()
mesh = make_test_mesh((2, 4))
rules = Rules(mesh, Plan())
p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                      jnp.float32)
y1, a1 = jax.jit(lambda p, x: moe_mod.apply_moe(p, cfg, x, rules))(p, x)
y2, a2 = jax.jit(lambda p, x: moe_mod.apply_moe_ep(p, cfg, x, rules))(p, x)
d = float(jnp.abs(y1 - y2).max())
assert d < 1e-4, ('FAIL ydiff', d)
# aux is a per-shard estimator: close but not identical
assert abs(float(a1) - float(a2)) < 0.05, ('FAIL aux', float(a1), float(a2))
# grads flow through the shard_map path
g = jax.grad(lambda p, x: moe_mod.apply_moe_ep(p, cfg, x, rules)[0].sum())(p, x)
gn = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
assert gn > 0, 'FAIL zero grads'
print('ok', d)
""")


def test_pipeline_schedules_grad_equivalence():
    """fwd + jax.grad of every schedule vs sequential_apply across
    m in {1, S, 4S}, plus the fallback path (batch not divisible)."""
    run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.dist.compat import AxisType, mesh_from_devices
from repro.dist.pipeline import pipeline_apply, sequential_apply

S, B, D = 4, 16, 8
ws = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (B, D))

def stage_fn(w, h):
    return jnp.tanh(h @ w)

want = sequential_apply(stage_fn, ws, x)
gwant = jax.grad(lambda ws: sequential_apply(stage_fn, ws, x).sum())(ws)
mesh4 = mesh_from_devices(jax.devices()[:4], (4,), ('pod',),
                          axis_types=(AxisType.Auto,))
mesh2 = mesh_from_devices(jax.devices()[:2], (2,), ('pod',),
                          axis_types=(AxisType.Auto,))
cases = [('gpipe', mesh4, 1), ('one_f_one_b', mesh4, 1),
         ('interleaved', mesh2, 2)]
for sched, mesh, v in cases:
    for m in (1, S, 4 * S):
        f = lambda ws, x: pipeline_apply(stage_fn, ws, x, mesh,
                                         microbatches=m, schedule=sched,
                                         virtual_stages=v)
        got = jax.jit(f)(ws, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f'{sched} fwd m={m}')
        g = jax.jit(jax.grad(lambda ws: f(ws, x).sum()))(ws)
        per_stage = np.asarray(jnp.abs(g).sum(axis=(1, 2)))
        assert (per_stage > 0).all(), ('FAIL grads', sched, m, per_stage)
        np.testing.assert_allclose(np.asarray(g), np.asarray(gwant),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f'{sched} grad m={m}')
# fallback: B % m != 0 must still match (and differentiate) sequentially
f = lambda ws: pipeline_apply(stage_fn, ws, x, mesh4, microbatches=3,
                              schedule='one_f_one_b').sum()
g = jax.jit(jax.grad(f))(ws)
np.testing.assert_allclose(np.asarray(g), np.asarray(gwant), rtol=1e-4,
                           atol=1e-5)
print('ok')
""", n_devices=4, timeout=600)


def test_pipeline_train_step_consumes_plan_genes():
    """make_pipeline_train_step trains a stage-stacked model under each
    schedule and matches the sequential step's loss."""
    run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import TrainConfig
from repro.dist.compat import AxisType, mesh_from_devices
from repro.dist.pipeline import sequential_apply
from repro.dist.plan import Plan
from repro.train import optimizer, train_step as ts

mesh4 = mesh_from_devices(jax.devices()[:4], (4,), ('pod',),
                          axis_types=(AxisType.Auto,))
mesh2 = mesh_from_devices(jax.devices()[:2], (2,), ('pod',),
                          axis_types=(AxisType.Auto,))
S, B, D = 4, 8, 8
ws = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
y = jax.random.normal(jax.random.PRNGKey(2), (B, D))
tcfg = TrainConfig(lr=1e-2, warmup_steps=1)

def stage_fn(w, h):
    return jnp.tanh(h @ w)

def run(plan, mesh):
    step = ts.make_pipeline_train_step(stage_fn, tcfg, mesh, plan)
    opt = optimizer.init(ws, tcfg)
    p2, o2, m = jax.jit(step)(ws, opt, (x, y), jnp.int32(0))
    return float(m['loss']), p2

ref_loss = float(jnp.mean(
    (sequential_apply(stage_fn, ws, x) - y) ** 2))
losses = {}
params = {}
for sched, mesh, v in [('gpipe', mesh4, 1), ('one_f_one_b', mesh4, 1),
                       ('interleaved', mesh2, 2)]:
    plan = Plan(microbatches=4, pipeline_schedule=sched, virtual_stages=v)
    losses[sched], params[sched] = run(plan, mesh)
for sched, l in losses.items():
    assert abs(l - ref_loss) < 1e-5, ('FAIL loss', sched, l, ref_loss)
# all schedules take the same optimizer step (same grads)
for sched in ('one_f_one_b', 'interleaved'):
    d = float(np.abs(np.asarray(params[sched])
                     - np.asarray(params['gpipe'])).max())
    assert d < 1e-5, ('FAIL step', sched, d)
print('ok', ref_loss)
""", n_devices=4, timeout=600)


def test_pipeline_parallel_matches_sequential():
    run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.dist.compat import AxisType, mesh_from_devices
from repro.dist.pipeline import pipeline_apply, sequential_apply

mesh = mesh_from_devices(jax.devices()[:4], (4,), ('pod',),
                         axis_types=(AxisType.Auto,))
S, B, D = 4, 8, 16
ws = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (B, D))

def stage_fn(w, h):
    return jnp.tanh(h @ w)

want = sequential_apply(stage_fn, ws, x)
got = jax.jit(lambda ws, x: pipeline_apply(stage_fn, ws, x, mesh,
                                           microbatches=4))(ws, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=1e-5, atol=1e-5)

# differentiable: grads flow to every stage's params
g = jax.jit(jax.grad(lambda ws: pipeline_apply(
    stage_fn, ws, x, mesh, microbatches=4).sum()))(ws)
per_stage = np.asarray(jnp.abs(g).sum(axis=(1, 2)))
assert (per_stage > 0).all(), ('FAIL grads', per_stage)
# matches sequential grads
g2 = jax.jit(jax.grad(lambda ws: sequential_apply(
    stage_fn, ws, x).sum()))(ws)
np.testing.assert_allclose(np.asarray(g), np.asarray(g2), rtol=1e-4,
                           atol=1e-5)
print('ok')
""", n_devices=4)
