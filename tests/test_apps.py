"""Paper apps: correctness of destination impls + the many-core hazard."""
import jax
import pytest

from repro.apps import APPS
from repro.core.destinations import MANY_CORE, FPGA
from repro.core.ga import GAConfig
from repro.core.loop_offload import ga_search, fpga_search
from repro.core.measure import TimedRunner, outputs_close


@pytest.fixture(scope="module")
def small_states():
    return {name: APPS[name]().make_inputs(seed=0, small=True)
            for name in APPS}


@pytest.mark.parametrize("name", list(APPS))
def test_safe_nests_parallelize_correctly(name, small_states):
    app = APPS[name]()
    st = small_states[name]
    ref = jax.jit(app.reference_fn())(st)
    for dest_key in ("dp", "tp"):
        choice = {n.name: dest_key for n in app.nests
                  if n.parallel_safe and dest_key in n.impls}
        out = jax.jit(app.build(choice))(st)
        assert outputs_close(out, ref), (name, dest_key)


def test_nasbt_unsafe_nest_changes_result(small_states):
    app = APPS["NAS.BT"]()
    st = small_states["NAS.BT"]
    ref = jax.jit(app.reference_fn())(st)
    out = jax.jit(app.build({"seidel_relax": "dp"}))(st)
    assert not outputs_close(out, ref)


def test_nasbt_ga_rejects_unsafe_gene(small_states):
    app = APPS["NAS.BT"]()
    st = small_states["NAS.BT"]
    ref = jax.jit(app.reference_fn())(st)
    res = ga_search(app, MANY_CORE, TimedRunner(repeats=1), st, ref,
                    ga_cfg=GAConfig(population=6, generations=6, seed=1))
    assert res.best_choice["seidel_relax"] == "seq"


def test_mm3_pallas_nests_correct(small_states):
    app = APPS["3mm"]()
    st = small_states["3mm"]
    ref = jax.jit(app.reference_fn())(st)
    choice = {n.name: "pallas" for n in app.nests if "pallas" in n.impls}
    out = jax.jit(app.build(choice))(st)
    assert outputs_close(out, ref)


def test_tdfir_pallas_fb_correct(small_states):
    app = APPS["tdFIR"]()
    st = small_states["tdFIR"]
    ref = jax.jit(app.reference_fn())(st)
    out = jax.jit(app.build({"tdfir_filter_bank": "pallas"}))(st)
    assert outputs_close(out, ref)


def test_fpga_narrowing_prefers_high_intensity(small_states):
    from repro.core.intensity import narrow
    app = APPS["3mm"]()
    st = small_states["3mm"]
    cands = narrow(app, st)
    names = [p.nest.name for p in cands]
    # the three matmul nests dominate arithmetic intensity
    assert all(n.startswith("mm") for n in names), names


def test_fpga_search_measures_at_most_four_patterns(small_states):
    app = APPS["3mm"]()
    st = small_states["3mm"]
    ref = jax.jit(app.reference_fn())(st)
    res = fpga_search(app, FPGA, TimedRunner(repeats=1), st, ref, st)
    assert res.n_measurements <= 4
