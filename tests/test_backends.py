"""Pluggable offload-backend API (repro.backends): registry-derived
verification order, selection policies, custom backend registration, and the
legacy repro.core.destinations shim."""
import jax.numpy as jnp
import pytest

from repro.backends import (Backend, BackendRegistry, DEFAULT_REGISTRY,
                            SelectionPolicy, get_policy, register_policy)
from repro.backends.builtin import ga_loop_search
from repro.core.function_blocks import Registry
from repro.core.ga import Evaluation, GAConfig
from repro.core.offloadable import LoopNest, OffloadableApp
from repro.core.planner import UserTarget, plan_offload


class ScriptedRunner:
    """Deterministic verification environment: the app encodes its own
    "processing time" in the output scalar."""

    def measure(self, fn, inputs, reference_out):
        out = fn(inputs)
        return Evaluation(time_s=float(out), correct=True,
                          info={"output": out})


def _stage(value):
    def impl(state):
        s = dict(state)
        s["out"] = jnp.float32(value)
        return s
    return impl


def _scripted_app(times):
    """One nest whose impl 'times' dict maps impl key -> scripted time."""
    nest = LoopNest(name="stage",
                    impls={k: _stage(v) for k, v in times.items()})
    return OffloadableApp(
        name="scripted",
        nests=[nest],
        make_inputs=lambda seed=0, small=False: {"x": jnp.ones((4,))})


class FakeCostRunner:
    """Scripted mesh verification: modeled time per backend key."""

    def __init__(self, mesh_times):
        self.mesh_times = mesh_times


def _fake_mesh_verify(backend, cost_runner, fn, inputs):
    t = cost_runner.mesh_times.get(backend.key)
    if t is None:
        return None
    return Evaluation(time_s=t, correct=True, info={"scripted": True})


def _dp_tp_registry():
    dp = Backend(key="dp", name="xla_dp", paper_analogue="many-core CPU",
                 price=1.2, verify_time=1.0, mesh_role="data",
                 search_fn=ga_loop_search,
                 mesh_verify_fn=_fake_mesh_verify)
    tp = Backend(key="tp", name="sharded_tp", paper_analogue="GPU",
                 price=1.0, verify_time=1.5, mesh_role="model",
                 search_fn=ga_loop_search,
                 mesh_verify_fn=_fake_mesh_verify)
    return BackendRegistry([dp, tp])


# ------------------------------------------------------------------ order
def test_registry_derives_papers_six_verification_order():
    order = DEFAULT_REGISTRY.verification_order()
    assert [(b.paper_analogue, m) for b, m in order] == [
        ("many-core CPU", "function_block"),
        ("GPU", "function_block"),
        ("FPGA", "function_block"),
        ("many-core CPU", "loop"),
        ("GPU", "loop"),
        ("FPGA", "loop"),
    ]


def test_order_respects_verify_time_not_registration_order():
    a = Backend(key="a", name="a", paper_analogue="A", price=1.0,
                verify_time=5.0, search_fn=ga_loop_search)
    b = Backend(key="b", name="b", paper_analogue="B", price=1.0,
                verify_time=1.0, search_fn=ga_loop_search)
    reg = BackendRegistry([a, b])        # registered slow-to-verify first
    order = reg.verification_order()
    assert [x.key for x, m in order if m == "loop"] == ["b", "a"]
    assert [x.key for x, m in order if m == "function_block"] == ["b", "a"]
    # FB phase strictly before loop phase
    methods = [m for _, m in order]
    assert methods == ["function_block"] * 2 + ["loop"] * 2


def test_register_duplicate_key_requires_replace():
    reg = _dp_tp_registry()
    clone = reg.get("dp").with_(price=9.0)
    with pytest.raises(ValueError):
        reg.register(clone)
    reg.register(clone, replace=True)
    assert reg.get("dp").price == 9.0
    assert len(reg) == 2


# ----------------------------------------------------------------- shims
def test_legacy_destinations_shim_importable():
    from repro.core.destinations import (ALL, BY_ANALOGUE, BY_NAME,
                                         Destination, FPGA, GPU, MANY_CORE,
                                         VERIFICATION_ORDER)
    assert len(VERIFICATION_ORDER) == 6
    assert Destination is Backend
    assert [d.key for d in ALL] == ["dp", "tp", "pallas"]
    assert BY_NAME["pallas_kernel"] is FPGA
    assert BY_ANALOGUE["GPU"] is GPU
    assert MANY_CORE.mesh_role == "data"
    # the shim order IS the derived order
    derived = DEFAULT_REGISTRY.verification_order()
    assert [(d.key, m) for d, m in VERIFICATION_ORDER] == \
        [(b.key, m) for b, m in derived]


def test_legacy_loop_search_result_alias():
    from repro.backends.base import SearchResult
    from repro.core.loop_offload import LoopSearchResult
    assert LoopSearchResult is SearchResult


# --------------------------------------------------------------- policies
def test_policy_lookup_and_unknown_policy():
    assert get_policy("host-time").name == "host-time"
    assert get_policy(None).name == "host-time"
    pol = get_policy("modeled")
    assert get_policy(pol) is pol
    with pytest.raises(ValueError, match="unknown selection policy"):
        get_policy("does-not-exist")


def test_policy_scores():
    from repro.power import GENERIC
    host, modeled = get_policy("host-time"), get_policy("modeled")
    price, power = get_policy("price-weighted"), get_policy("power")
    assert host.score_parts(2.0, price=3.0, modeled_s=0.5) == 2.0
    assert modeled.score_parts(2.0, price=3.0, modeled_s=0.5) == 0.5
    assert modeled.score_parts(2.0, price=3.0, modeled_s=None) == 2.0
    assert price.score_parts(2.0, price=3.0, modeled_s=0.5) == 6.0
    # the energy policies keep every path joule-scale (generic peak draw
    # x modeled-or-host time x relative price)
    assert power.score_parts(2.0, price=3.0, modeled_s=0.5) == \
        GENERIC.peak_w * 0.5 * 3.0
    assert power.score_parts(2.0, price=3.0, modeled_s=None) == \
        GENERIC.peak_w * 2.0 * 3.0
    edp = get_policy("edp")
    assert edp.score_parts(2.0, price=3.0, modeled_s=0.5) == \
        GENERIC.peak_w * 0.25 * 3.0


def test_modeled_policy_flips_selection_on_comm_bound_candidate():
    """Acceptance: with a cost_runner recording mesh times, policy="modeled"
    selects by mesh_time_s — the host-fastest tp candidate is comm-bound on
    the mesh, so modeled selection flips to dp; host-time keeps tp."""
    app = _scripted_app({"seq": 1.0, "dp": 0.8, "tp": 0.5})
    # tp is fastest on the host but comm-bound once compiled for the mesh
    cost_runner = FakeCostRunner({"dp": 0.1, "tp": 2.0})
    common = dict(runner=ScriptedRunner(),
                  ga_cfg=GAConfig(population=2, generations=2),
                  registry=Registry(),           # no function blocks
                  backends=_dp_tp_registry(), cost_runner=cost_runner)

    host = plan_offload(app, UserTarget(), policy="host-time", **common)
    assert host.policy == "host-time"
    assert host.selected.destination == "sharded_tp"
    assert host.selected.best_time_s == pytest.approx(0.5)

    modeled = plan_offload(app, UserTarget(), policy="modeled", **common)
    assert modeled.policy == "modeled"
    assert modeled.selected.destination == "xla_dp"
    assert modeled.selected.mesh_time_s == pytest.approx(0.1)
    # the comm-bound evidence is on the record the policy rejected
    tp_rec = next(r for r in modeled.records
                  if r.destination == "sharded_tp" and r.method == "loop")
    assert tp_rec.mesh_time_s == pytest.approx(2.0)


def test_default_policy_reproduces_host_time_selection():
    app = _scripted_app({"seq": 1.0, "dp": 0.8, "tp": 0.5})
    report = plan_offload(app, UserTarget(), runner=ScriptedRunner(),
                          ga_cfg=GAConfig(population=2, generations=2),
                          registry=Registry(), backends=_dp_tp_registry())
    assert report.policy == "host-time"
    assert report.selected.destination == "sharded_tp"


def test_price_weighted_policy_uses_declared_price():
    # dp: 0.8 x price 1.2 = 0.96; tp: 0.9 x price 1.0 = 0.90 -> tp wins
    # even though host-time alone is nearly tied
    app = _scripted_app({"seq": 1.0, "dp": 0.8, "tp": 0.9})
    report = plan_offload(app, UserTarget(), runner=ScriptedRunner(),
                          ga_cfg=GAConfig(population=2, generations=2),
                          registry=Registry(), backends=_dp_tp_registry(),
                          policy="price-weighted")
    assert report.selected.destination == "sharded_tp"
    host = plan_offload(app, UserTarget(), runner=ScriptedRunner(),
                        ga_cfg=GAConfig(population=2, generations=2),
                        registry=Registry(), backends=_dp_tp_registry())
    assert host.selected.destination == "xla_dp"


def test_custom_policy_registrable():
    class WorstCase(SelectionPolicy):
        name = "test-worst-case"

        def score_parts(self, time_s, price=1.0, modeled_s=None):
            return -time_s          # deliberately picks the slowest

    register_policy(WorstCase())
    try:
        app = _scripted_app({"seq": 1.0, "dp": 0.8, "tp": 0.5})
        report = plan_offload(app, UserTarget(), runner=ScriptedRunner(),
                              ga_cfg=GAConfig(population=2, generations=2),
                              registry=Registry(),
                              backends=_dp_tp_registry(),
                              policy="test-worst-case")
        # slowest correct+finite record wins under the custom objective
        assert report.selected.best_time_s == max(
            r.best_time_s for r in report.records
            if r.best_time_s < float("inf"))
    finally:
        from repro.backends.policy import POLICIES
        POLICIES.pop("test-worst-case", None)


# ------------------------------------------------------- custom backends
def test_custom_backend_registered_without_planner_surgery():
    """Acceptance: a new destination slots into the verification order and
    shows up in PlanReport without editing planner.py."""

    def scripted_search(backend, app, ctx):
        from repro.backends.base import SearchResult
        choice = {n.name: backend.key for n in app.nests
                  if backend.key in n.impls}
        ev = ctx.measure(app, choice)
        return SearchResult(destination=backend.name,
                            best_choice=choice,
                            best_time_s=ev.effective_time,
                            n_measurements=1, verify_elapsed_s=0.0,
                            best_correct=ev.correct)

    npu = Backend(key="npu", name="npu_offload", paper_analogue="NPU",
                  price=0.5, verify_time=0.1,      # cheapest to verify
                  search_fn=scripted_search)
    reg = _dp_tp_registry()
    reg.register(npu)

    app = _scripted_app({"seq": 1.0, "dp": 0.8, "tp": 0.5, "npu": 0.2})
    report = plan_offload(app, UserTarget(), runner=ScriptedRunner(),
                          ga_cfg=GAConfig(population=2, generations=2),
                          registry=Registry(), backends=reg)
    # 3 backends x 2 methods
    assert len(report.records) == 6
    # verify_time=0.1 puts the NPU first in both phases
    assert report.records[0].destination == "npu_offload"
    assert report.records[3].destination == "npu_offload"
    assert report.records[3].method == "loop"
    # and it wins selection under the default policy
    assert report.selected.destination == "npu_offload"
    assert report.selected.best_time_s == pytest.approx(0.2)
    assert {r.paper_analogue for r in report.records} == \
        {"NPU", "many-core CPU", "GPU"}


def test_summary_rows_include_mesh_time_and_correct():
    app = _scripted_app({"seq": 1.0, "dp": 0.8, "tp": 0.5})
    report = plan_offload(app, UserTarget(), runner=ScriptedRunner(),
                          ga_cfg=GAConfig(population=2, generations=2),
                          registry=Registry(), backends=_dp_tp_registry(),
                          cost_runner=FakeCostRunner({"dp": 0.1, "tp": 2.0}))
    rows = report.summary_rows()
    assert all("mesh_time_s" in row and "correct" in row for row in rows)
    by_dest = {(row["destination"], row["method"]): row for row in rows}
    assert by_dest[("many-core CPU", "loop")]["mesh_time_s"] == \
        pytest.approx(0.1)
    assert by_dest[("GPU", "loop")]["mesh_time_s"] == pytest.approx(2.0)
    assert all(row["correct"] for row in rows
               if row["time_s"] < float("inf"))
