"""repro.serve.health: the per-endpoint state machine, unit by unit.

Pins every edge of healthy -> degraded -> quarantined -> probing ->
(recovered) healthy on a hand-driven tick clock: latency-EWMA degradation
with hysteresis, the consecutive-error circuit breaker, exponential
half-open backoff with escalation on failed probes, the probe quota, and
the watchdog reset on recovery.  Everything here is pure arithmetic — no
jax import anywhere on the path.
"""
import pytest

from repro.serve.health import (DEGRADED, HEALTH_STATES, HEALTHY, PROBING,
                                QUARANTINED, EndpointHealth, HealthConfig)


def make(**kw):
    defaults = dict(ewma_alpha=1.0, degrade_factor=2.0, recover_factor=1.2,
                    error_threshold=2, backoff_ticks=4, backoff_mult=2.0,
                    max_backoff_ticks=64, probe_quota=1, probe_successes=1)
    defaults.update(kw)
    return EndpointHealth("ep", HealthConfig(**defaults))


def test_states_and_config_validation():
    assert HEALTH_STATES == (HEALTHY, DEGRADED, QUARANTINED, PROBING)
    with pytest.raises(ValueError):
        HealthConfig(degraded_penalty=0.5)
    with pytest.raises(ValueError):
        HealthConfig(error_threshold=0)
    with pytest.raises(ValueError):
        HealthConfig(backoff_ticks=0)


def test_latency_degrade_and_recover_hysteresis():
    """EWMA above degrade_factor x baseline degrades; it must come back
    under the *tighter* recover_factor to re-enter healthy (hysteresis:
    no flapping at the boundary)."""
    h = make()                           # alpha=1.0: ewma == last sample
    h.observe_latency(1.0)               # seeds the baseline
    assert h.state == HEALTHY and h.baseline_s == pytest.approx(1.0)
    h.observe_latency(1.9)               # below 2x: still healthy
    assert h.state == HEALTHY
    h.observe_latency(3.0)               # 3x baseline: degraded
    assert h.state == DEGRADED
    assert h.penalty == pytest.approx(1.5)
    h.observe_latency(1.5)               # 1.5x > recover_factor: stays
    assert h.state == DEGRADED
    h.observe_latency(1.1)               # within 1.2x: recovered
    assert h.state == HEALTHY
    assert h.penalty == 1.0
    assert [t["to"] for t in h.transitions] == [DEGRADED, HEALTHY]


def test_baseline_is_best_ever_seen_never_ratcheted_up_by_a_fault():
    h = make()
    h.observe_latency(2.0)
    h.observe_latency(0.5)               # faster: the honest baseline
    assert h.baseline_s == pytest.approx(0.5)
    h.observe_latency(10.0)              # a fault window cannot raise it
    assert h.baseline_s == pytest.approx(0.5)
    assert h.state == DEGRADED


def test_consecutive_errors_open_the_circuit():
    h = make(error_threshold=2)
    h.observe_error("boom")
    assert h.state == HEALTHY            # one error is noise
    h.observe_success()                  # success resets the streak
    h.observe_error("boom")
    assert h.state == HEALTHY
    h.observe_error("boom")
    assert h.state == QUARANTINED
    assert not h.available               # the router must skip it
    assert h.errors == 3


def test_backoff_elapses_into_half_open_probing():
    h = make(error_threshold=1, backoff_ticks=4)
    h.on_tick(10)
    h.observe_error("died")
    assert h.state == QUARANTINED
    h.on_tick(13)                        # 3 < 4 ticks: still closed
    assert h.state == QUARANTINED and not h.available
    h.on_tick(14)                        # backoff elapsed: half-open
    assert h.state == PROBING
    assert h.available and h.probe_free


def test_probe_quota_limits_half_open_concurrency():
    h = make(error_threshold=1, backoff_ticks=1, probe_quota=1)
    h.observe_error("died")
    h.on_tick(5)
    assert h.state == PROBING and h.available
    h.on_probe_dispatch()
    assert not h.probe_free and not h.available   # quota exhausted
    h.observe_success(probe=True)                 # probe came back
    assert h.state == HEALTHY


def test_failed_probe_requarantines_with_escalated_backoff():
    h = make(error_threshold=1, backoff_ticks=4, backoff_mult=2.0,
             max_backoff_ticks=16)
    h.on_tick(0)
    h.observe_error("died")              # quarantine: backoff 4
    h.on_tick(4)
    assert h.state == PROBING
    h.on_probe_dispatch()
    h.observe_error("still dead", probe=True)
    assert h.state == QUARANTINED        # escalated: backoff now 8
    h.on_tick(11)
    assert h.state == QUARANTINED
    h.on_tick(12)
    assert h.state == PROBING
    h.on_probe_dispatch()
    h.observe_error("still dead", probe=True)
    h.on_tick(12 + 16)                   # 8 * 2 = 16 (capped there)
    assert h.state == PROBING
    # a further failure cannot push the backoff past max_backoff_ticks
    h.on_probe_dispatch()
    h.observe_error("still dead", probe=True)
    h.on_tick(28 + 16)
    assert h.state == PROBING


def test_probe_success_recovers_and_resets_backoff_and_watchdog():
    h = make(error_threshold=1, backoff_ticks=4, probe_successes=1)
    for t in range(8):
        h.observe_latency(1.0)
    h.on_tick(0)
    h.observe_error("died")
    h.on_tick(4)
    h.on_probe_dispatch()
    h.observe_success(probe=True)
    assert h.state == HEALTHY and h.recoveries == 1
    assert len(h.watchdog.times) == 0    # fresh window post-recovery
    assert h.watchdog.ewma is None
    # backoff is back to its base: the next quarantine reopens in 4 ticks
    h.observe_error("died again")
    h.on_tick(8)
    assert h.state == PROBING
    last = h.transitions[-1]
    assert last["from"] == QUARANTINED and last["to"] == PROBING


def test_multi_probe_successes_required_to_close():
    h = make(error_threshold=1, backoff_ticks=1, probe_quota=2,
             probe_successes=2)
    h.observe_error("died")
    h.on_tick(2)
    assert h.state == PROBING
    h.on_probe_dispatch()
    h.observe_success(probe=True)
    assert h.state == PROBING            # one success is not enough
    h.on_probe_dispatch()
    h.observe_success(probe=True)
    assert h.state == HEALTHY


def test_explicit_quarantine_and_transition_log():
    h = make()
    h.on_tick(7)
    h.quarantine("operator request")
    assert h.state == QUARANTINED
    tr = h.transitions[-1]
    assert tr == {"tick": 7, "from": HEALTHY, "to": QUARANTINED,
                  "reason": "operator request",
                  "observed": {"backoff_ticks": 4}}
    h.quarantine("again")                # idempotent: no new transition
    assert len(h.transitions) == 1
