"""GA engine: paper-exact behaviour + hypothesis invariants."""
import pytest

pytest.importorskip("hypothesis")   # minimal envs: skip, don't fail collect
from hypothesis import given, settings, strategies as st

from repro.core.ga import Evaluation, GAConfig, PENALTY_TIME_S, run_ga


def eval_from_time(t, correct=True, timeout=False):
    return Evaluation(time_s=t, correct=correct, timed_out=timeout)


def test_fitness_is_inverse_sqrt_time():
    e = eval_from_time(4.0)
    assert e.fitness == pytest.approx(0.5)
    assert eval_from_time(1.0).fitness == pytest.approx(1.0)


def test_wrong_result_gets_penalty_time():
    e = eval_from_time(0.001, correct=False)
    assert e.effective_time == PENALTY_TIME_S
    assert e.fitness == pytest.approx(PENALTY_TIME_S ** -0.5)


def test_timeout_gets_penalty_time():
    e = eval_from_time(500.0, correct=True, timeout=True)
    assert e.effective_time == PENALTY_TIME_S


def test_ga_finds_all_ones_optimum():
    # time decreases with number of offloaded loops -> optimum all-ones
    def evaluate(genes):
        return eval_from_time(10.0 / (1 + sum(genes)))

    cfg = GAConfig(population=8, generations=8, seed=0)
    res = run_ga(8, evaluate, cfg)
    assert sum(res.best_genes) >= 7            # near-optimal
    assert res.best_eval.effective_time <= 10.0 / 8 * 1.3


def test_ga_avoids_unsafe_gene():
    # gene 2 is "wrong parallelization": fast but incorrect
    def evaluate(genes):
        if genes[2] == 1:
            return eval_from_time(0.01, correct=False)
        return eval_from_time(1.0 / (1 + sum(genes)))

    cfg = GAConfig(population=6, generations=6, seed=1)
    res = run_ga(6, evaluate, cfg)
    assert res.best_genes[2] == 0
    assert res.best_eval.correct


def test_population_rule_from_gene_length():
    cfg = GAConfig.for_gene_length(6)
    assert cfg.population == 6 and cfg.generations == 6   # paper: tdFIR 6/6
    cfg = GAConfig.for_gene_length(120)
    assert cfg.population <= 20                           # paper: NAS.BT 20


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 10), st.integers(0, 10_000))
def test_ga_best_is_min_over_all_evaluations(gene_len, seed):
    """The reported best equals the true min over every measured pattern."""
    import random
    r = random.Random(seed)
    table = {}

    def evaluate(genes):
        if genes not in table:
            table[genes] = eval_from_time(r.uniform(0.1, 10.0),
                                          correct=r.random() > 0.2)
        return table[genes]

    cfg = GAConfig(population=min(gene_len, 6),
                   generations=min(gene_len, 6), seed=seed)
    res = run_ga(gene_len, evaluate, cfg)
    true_best = min(e.effective_time for e in res.evaluations.values())
    assert res.best_eval.effective_time == true_best


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_ga_elite_monotone_best(seed):
    """Per-generation best time never increases (elite selection)."""
    import random
    r = random.Random(seed)

    def evaluate(genes):
        return eval_from_time(r.uniform(0.1, 10.0))

    res = run_ga(6, evaluate, GAConfig(population=6, generations=6,
                                       seed=seed))
    bests = [h["best_time_s"] for h in res.history]
    assert all(b2 <= b1 + 1e-12 for b1, b2 in zip(bests, bests[1:]))


def test_ga_deterministic_given_seed():
    def evaluate(genes):
        return eval_from_time(1.0 + sum(genes) * 0.1)

    a = run_ga(5, evaluate, GAConfig(population=5, generations=5, seed=42))
    b = run_ga(5, evaluate, GAConfig(population=5, generations=5, seed=42))
    assert a.best_genes == b.best_genes
    assert [h["best_time_s"] for h in a.history] == \
        [h["best_time_s"] for h in b.history]


def test_ga_history_records_fresh_evaluations():
    """history[i]["n_fresh"] is the generation's verification cost: gen 0
    pays for the whole population, later generations only for unseen gene
    strings, and the sum equals the total measurements."""
    def evaluate(genes):
        return eval_from_time(1.0 + sum(genes) * 0.1)

    res = run_ga(5, evaluate, GAConfig(population=5, generations=5, seed=3))
    fresh = [h["n_fresh"] for h in res.history]
    assert fresh[0] == 5                      # initial population is unseen
    assert all(0 <= f <= 5 for f in fresh)
    assert sum(fresh) == res.n_measurements


def test_ga_categorical_genes():
    cards = [3, 4, 2]

    def evaluate(genes):
        return eval_from_time(1.0 + abs(genes[0] - 2) + abs(genes[1] - 3)
                              + genes[2])

    cfg = GAConfig(population=6, generations=10, seed=0,
                   cardinalities=cards)
    res = run_ga(3, evaluate, cfg)
    assert res.best_genes == (2, 3, 0)
