"""repro.dist.schedules: tick-plan structure, the closed-form cost-model
terms pinned to the built plans, schedule execution on a 1-rank pod mesh,
fallback paths, and the GA searching the pipeline genes.

Multi-device grad equivalence for all three schedules lives in
tests/test_distributed.py; everything here runs in-process on 1 device.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost_model
from repro.core.ga import Evaluation, GAConfig, run_ga
from repro.dist.compat import AxisType, make_mesh
from repro.dist.plan import Plan
from repro.dist.schedules import (SCHEDULES, Schedule, get_schedule,
                                  register_schedule)


# ---------------------------------------------------------------- structure
def test_gpipe_plan_shape():
    plan = SCHEDULES["gpipe"].build(n_stages=4, n_ranks=4, microbatches=8)
    assert plan is not None
    assert plan.total_ticks == 8 + 4 - 1
    assert plan.busy_ticks == 8
    assert plan.bubble_ticks == 3
    assert plan.in_flight == 8                      # all m held to backward
    # drain ticks feed nothing (the mb[m-1] re-feed bug)
    for t in range(8, plan.total_ticks):
        assert plan.ticks[t].feed_mb == -1
        assert plan.ticks[t].feed_buf == -1


def test_one_f_one_b_caps_in_flight():
    g = SCHEDULES["gpipe"].build(n_stages=4, n_ranks=4, microbatches=16)
    f = SCHEDULES["one_f_one_b"].build(n_stages=4, n_ranks=4,
                                       microbatches=16)
    # identical forward tick order; the cap is what changes
    assert [t.feed_mb for t in f.ticks] == [t.feed_mb for t in g.ticks]
    assert [t.capture_out for t in f.ticks] == \
        [t.capture_out for t in g.ticks]
    assert f.in_flight == 4 and g.in_flight == 16


def test_interleaved_bubble_shrinks():
    # S=4 stages on 2 ranks x V=2 chunks, m >= ranks: bubble = ranks-1
    plan = SCHEDULES["interleaved"].build(n_stages=4, n_ranks=2,
                                          microbatches=4, virtual_stages=2)
    assert plan is not None
    assert plan.busy_ticks == 8                     # V passes over m
    assert plan.bubble_ticks == plan.n_ranks - 1 == 1
    # every wrapped chunk output is stashed before (or at) the tick that
    # feeds it back
    stash_tick = {t.stash_buf: i for i, t in enumerate(plan.ticks)
                  if t.stash_buf >= 0}
    for i, t in enumerate(plan.ticks):
        if t.feed_buf >= 0:
            assert stash_tick[t.feed_buf] <= i


@pytest.mark.parametrize("name,v", [("gpipe", 1), ("one_f_one_b", 1),
                                    ("interleaved", 2), ("interleaved", 3)])
@pytest.mark.parametrize("m", [1, 2, 4, 8])
def test_tick_plan_invariants(name, v, m):
    ranks = 2
    plan = SCHEDULES[name].build(n_stages=ranks * v, n_ranks=ranks,
                                 microbatches=m, virtual_stages=v)
    assert plan is not None
    feeds = [t.feed_mb for t in plan.ticks if t.feed_mb >= 0]
    captures = [t.capture_out for t in plan.ticks if t.capture_out >= 0]
    assert sorted(feeds) == list(range(m))          # each mb fed once
    assert sorted(captures) == list(range(m))       # each out captured once
    for t in plan.ticks:                            # feeds are exclusive
        assert not (t.feed_mb >= 0 and t.feed_buf >= 0)
    assert sum(t.phase == "warmup" for t in plan.ticks) == ranks - 1
    assert sum(t.phase == "cooldown" for t in plan.ticks) == ranks - 1
    # the closed forms in cost_model match the built plan exactly
    assert cost_model.pipeline_bubble_fraction(name, ranks, m, v) == \
        pytest.approx(plan.bubble_fraction)
    assert cost_model.pipeline_in_flight(name, ranks, m, v) == plan.in_flight


def test_interleaved_v2_beats_gpipe_at_m_equals_s():
    """Acceptance: modeled bubble for interleaved(V=2) strictly below gpipe
    at m = S."""
    S = 4
    g = cost_model.pipeline_bubble_fraction("gpipe", S, S)
    i = cost_model.pipeline_bubble_fraction("interleaved", S, S,
                                            virtual_stages=2)
    assert 0.0 < i < g
    # and the same holds for the built tick plans
    gp = SCHEDULES["gpipe"].build(n_stages=S, n_ranks=S, microbatches=S)
    ip = SCHEDULES["interleaved"].build(n_stages=2 * S, n_ranks=S,
                                        microbatches=S, virtual_stages=2)
    assert ip.bubble_fraction < gp.bubble_fraction


def test_bubble_stretches_roofline_step_time():
    base = cost_model.roofline_terms(1e12, 1e9, 0.0, n_chips=4)
    bub = cost_model.roofline_terms(1e12, 1e9, 0.0, n_chips=4,
                                    bubble_fraction=0.5)
    assert bub.step_time_s == pytest.approx(2 * base.step_time_s)
    assert bub.pipeline_s == pytest.approx(base.step_time_s)
    assert base.bubble_fraction == 0.0 and bub.bubble_fraction == 0.5


def test_plan_bubble_fraction_reads_genes():
    assert cost_model.plan_bubble_fraction(Plan(), 1) == 0.0
    p = Plan(microbatches=8, pipeline_schedule="interleaved",
             virtual_stages=2)
    assert cost_model.plan_bubble_fraction(p, 4) == \
        cost_model.pipeline_bubble_fraction("interleaved", 4, 8, 2)
    # virtual_stages is ignored by non-interleaved schedules
    q = Plan(microbatches=8, pipeline_schedule="gpipe", virtual_stages=2)
    assert cost_model.plan_bubble_fraction(q, 4) == \
        cost_model.pipeline_bubble_fraction("gpipe", 4, 8)


# ---------------------------------------------------------------- registry
def test_get_schedule_and_register():
    assert get_schedule("gpipe") is SCHEDULES["gpipe"]
    assert get_schedule("nope") is None
    sched = SCHEDULES["interleaved"]
    assert get_schedule(sched) is sched             # instances pass through

    class Custom(Schedule):
        name = "custom-test"

        def build(self, *, n_stages, n_ranks, microbatches,
                  virtual_stages=1):
            return None

    register_schedule(Custom())
    try:
        assert get_schedule("custom-test") is not None
        with pytest.raises(ValueError):
            register_schedule(Custom())
    finally:
        del SCHEDULES["custom-test"]


# --------------------------------------------------------------- execution
def test_single_rank_pod_mesh_runs_every_schedule():
    """A 1-rank pod mesh exercises the real shard_map executor (including
    the interleaved recirculation buffer) in-process."""
    from repro.dist.pipeline import pipeline_apply, sequential_apply

    mesh = make_mesh((1,), ("pod",), axis_types=(AxisType.Auto,))
    S, B, D = 3, 4, 8
    ws = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D))

    def stage_fn(w, h):
        return jnp.tanh(h @ w)

    want = sequential_apply(stage_fn, ws, x)
    # interleaved hosts all 3 stages on the single rank (V = 3); gpipe and
    # 1F1B cannot (stages != ranks) and must fall back to sequential
    got = jax.jit(lambda ws, x: pipeline_apply(
        stage_fn, ws, x, mesh, microbatches=2, schedule="interleaved",
        virtual_stages=3))(ws, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    for name in ("gpipe", "one_f_one_b"):
        got = pipeline_apply(stage_fn, ws, x, mesh, microbatches=2,
                             schedule=name)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


def test_unknown_schedule_and_bad_shapes_fall_back():
    from repro.dist.pipeline import pipeline_apply, sequential_apply

    mesh = make_mesh((1, 1), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    ws = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 8)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))

    def stage_fn(w, h):
        return jnp.tanh(h @ w)

    want = np.asarray(sequential_apply(stage_fn, ws, x))
    for kw in ({"schedule": "no-such-schedule"},
               {"schedule": "interleaved", "virtual_stages": 2},
               {"microbatches": 3}):              # 4 % 3 != 0
        got = pipeline_apply(stage_fn, ws, x, mesh,
                             **{"microbatches": 2, **kw})
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6,
                                   atol=1e-6)


# ----------------------------------------------------------------- dryrun
def test_dryrun_default_plan_named_plus_schedule_override():
    """--plan <named> + --schedule must patch the named plan, not silently
    rebuild the auto baseline under the named plan's tag (subprocess: the
    dryrun module forces a 512-device XLA flag at import)."""
    import os
    import subprocess
    import sys

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = """
import sys
sys.path.insert(0, {src!r})
from repro.launch.dryrun import default_plan
from repro.configs import get_config, get_shape
cfg = get_config("granite-3-2b")
shape = get_shape("train_4k")
p = default_plan(cfg, shape, "train-tight-mem",
                 {{"pipeline_schedule": "interleaved", "virtual_stages": 2}})
assert p.remat == "full" and p.microbatches == 4, p   # named fields kept
assert p.pipeline_schedule == "interleaved" and p.virtual_stages == 2, p
q = default_plan(cfg, shape, "train-tight-mem", None)
assert q.remat == "full" and q.pipeline_schedule == "gpipe", q
print("ok")
""".format(src=os.path.abspath(src))
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "ok" in proc.stdout


# ------------------------------------------------------------- GA search
def _modeled_evaluate(n_ranks, mem_weight):
    """Modeled step time from the pipeline genes alone: roofline busy time
    (constant across candidates) stretched by the schedule bubble, plus a
    memory term charging the schedule's in-flight activations."""

    def evaluate(genes):
        plan = Plan.from_genes(list(genes))
        bubble = cost_model.plan_bubble_fraction(plan, n_ranks)
        t = 1.0 / (1.0 - bubble)
        mem = cost_model.pipeline_in_flight(
            plan.pipeline_schedule, n_ranks,
            max(plan.microbatches, 1), plan.virtual_stages)
        return Evaluation(time_s=t + mem_weight * mem, correct=True)

    return evaluate


def _ga_best_plan(mem_weight):
    n = len(Plan.gene_cardinalities())
    cfg = GAConfig(population=16, generations=16, seed=3,
                   cardinalities=Plan.gene_cardinalities())
    res = run_ga(n, _modeled_evaluate(n_ranks=4, mem_weight=mem_weight), cfg)
    return Plan.from_genes(list(res.best_genes))


def test_ga_flips_schedule_gene_on_bubble_vs_memory():
    """The GA's all-zeros baseline is gpipe; when the bubble term dominates
    it must flip pipeline_schedule to interleaved, and when the memory term
    dominates to the 1F1B in-flight cap."""
    bubble_bound = _ga_best_plan(mem_weight=0.0)
    assert bubble_bound.pipeline_schedule == "interleaved"
    assert bubble_bound.virtual_stages == 2
    assert bubble_bound.microbatches == 8           # deepest overlap wins

    memory_bound = _ga_best_plan(mem_weight=0.5)
    assert memory_bound.pipeline_schedule == "one_f_one_b"
    assert memory_bound.microbatches == 8           # cap makes m=8 free
