"""repro.obs: tracer null-object contract, span nesting, deterministic
exporters, the metrics registry's consolidated snapshot, the post-mortem
report sections, and the ceil-based nearest-rank percentile fix.

The tier-1 pins here are behavioral, not cosmetic: the ambient tracer
must default to a no-op (instrumented call sites run in every existing
test with zero behavior change), a pinned-clock trace must serialize
byte-identically, and the Chrome export must be loadable trace-event
JSON (ph X/i/M, one lane per track).
"""
import json

import pytest

from repro import obs
from repro.obs import (NULL_SPAN, NULL_TRACER, MetricsRegistry, Tracer,
                       chrome_trace, get_tracer, jsonl_line, set_tracer,
                       text_summary, use_tracer)
from repro.obs.report import render


# ------------------------------------------------------- null-object tracer
def test_ambient_tracer_defaults_to_null():
    assert get_tracer() is NULL_TRACER
    assert not get_tracer().enabled


def test_null_tracer_is_a_complete_noop():
    tr = NULL_TRACER
    with tr.span("x", cat="c", track="t", foo=1) as sp:
        assert sp is NULL_SPAN
        assert sp.set(bar=2) is sp          # chainable, records nothing
    assert tr.complete_span("x", 0.0, 1.0) is None
    assert tr.event("x") is None
    tr.set_time(3.0)
    tr.clear_time()                          # all accepted, all ignored


def test_null_span_swallows_nothing():
    # exceptions still propagate through the disabled context manager
    with pytest.raises(RuntimeError):
        with NULL_TRACER.span("x"):
            raise RuntimeError("boom")


def test_use_tracer_scopes_and_restores():
    tr = Tracer()
    assert get_tracer() is NULL_TRACER
    with use_tracer(tr):
        assert get_tracer() is tr
        with use_tracer(None):               # None = explicitly disabled
            assert get_tracer() is NULL_TRACER
        assert get_tracer() is tr
    assert get_tracer() is NULL_TRACER


def test_set_tracer_none_restores_null():
    tr = Tracer()
    assert set_tracer(tr) is tr
    assert get_tracer() is tr
    assert set_tracer(None) is NULL_TRACER
    assert get_tracer() is NULL_TRACER


# ------------------------------------------------------------ span recording
def test_spans_nest_and_record_parents():
    tr = Tracer(clock=lambda: 0.0)
    with tr.span("outer", cat="a") as outer:
        with tr.span("inner", cat="a") as inner:
            inner.set(k=1)
        outer.set(done=True)
    # completion order: inner first
    names = [r["name"] for r in tr.records]
    assert names == ["inner", "outer"]
    inner_r, outer_r = tr.records
    assert inner_r["parent"] == outer_r["id"]
    assert outer_r["parent"] is None
    assert inner_r["attrs"] == {"k": 1}
    assert outer_r["attrs"] == {"done": True}


def test_span_records_exactly_once():
    tr = Tracer(clock=lambda: 0.0)
    sp = tr.span("x")
    sp.finish()
    sp.finish()                              # idempotent
    assert len(tr.records) == 1


def test_span_exception_lands_in_attrs_and_propagates():
    tr = Tracer(clock=lambda: 0.0)
    with pytest.raises(ValueError):
        with tr.span("x"):
            raise ValueError("bad gene")
    assert len(tr.records) == 1
    assert "bad gene" in tr.records[0]["attrs"]["error"]


def test_set_time_pins_the_clock():
    ticks = iter([1.0, 2.0, 3.0])
    tr = Tracer(clock=lambda: next(ticks))
    tr.set_time(0.25)
    ev = tr.event("e")
    with tr.span("s") as sp:
        pass
    assert ev["t"] == 0.25
    assert (tr.records[-1]["t0"], tr.records[-1]["t1"]) == (0.25, 0.25)
    tr.clear_time()
    assert tr.event("e2")["t"] == 1.0        # back on the supplied clock


def test_complete_span_uses_explicit_window():
    tr = Tracer()
    rec = tr.complete_span("request", 0.10, 0.35, cat="serve",
                           track="endpoint:hot0", rid="r1", ok=True)
    assert rec["t0"] == 0.10 and rec["t1"] == 0.35
    assert rec["parent"] is None
    assert tr.records == [rec]


def test_attrs_are_clamped_to_json():
    tr = Tracer(clock=lambda: 0.0)
    tr.event("e", weird=object(), nested={"k": (1, 2)})
    attrs = tr.records[0]["attrs"]
    json.dumps(attrs)                        # round-trips
    assert attrs["nested"] == {"k": [1, 2]}
    assert isinstance(attrs["weird"], str)


# ---------------------------------------------------------------- exporters
def make_records():
    tr = Tracer(clock=lambda: 0.0)
    tr.set_time(0.0)
    with tr.span("verify", cat="plan", track="backend:hot", backend="hot"):
        pass
    tr.set_time(0.01)
    tr.event("tick", cat="loop", track="loop", tick=1)
    tr.complete_span("request", 0.0, 0.01, cat="serve",
                     track="endpoint:hot0", ok=True)
    return tr.records


def test_jsonl_lines_are_byte_stable():
    a = [jsonl_line(r) for r in make_records()]
    b = [jsonl_line(r) for r in make_records()]
    assert a == b
    for line in a:
        rec = json.loads(line)
        assert rec["type"] in ("span", "event")
        assert line == jsonl_line(rec)       # canonical re-encode


def test_jsonl_roundtrip_through_files(tmp_path):
    recs = make_records()
    p = obs.write_jsonl(recs, tmp_path / "events.jsonl")
    assert obs.read_jsonl(p) == recs


def test_chrome_trace_is_perfetto_shaped():
    trace = chrome_trace(make_records())
    evs = trace["traceEvents"]
    phases = {e["ph"] for e in evs}
    assert phases == {"M", "X", "i"}
    # one thread_name metadata row per distinct track, names preserved
    meta = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert meta == {"backend:hot", "loop", "endpoint:hot0"}
    # µs timestamps: the 0.01 s request span is 10_000 µs long
    req = next(e for e in evs if e["ph"] == "X" and e["name"] == "request")
    assert req["ts"] == 0.0 and req["dur"] == pytest.approx(10_000.0)
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["s"] == "t" and inst["ts"] == pytest.approx(10_000.0)
    json.dumps(trace)                        # loadable JSON


def test_text_summary_counts_spans_and_events():
    s = text_summary(make_records())
    assert "2 spans, 1 events" in s
    assert "plan/verify" in s and "loop/tick" in s


# ---------------------------------------------------------- metrics registry
def test_registry_instruments_are_get_or_create():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    assert reg.gauge("g") is reg.gauge("g")
    assert reg.histogram("h") is reg.histogram("h")
    reg.counter("a").inc(2)
    reg.gauge("g").set(7.5)
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.histogram("h").observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 2.0
    assert snap["gauges"]["g"] == 7.5
    h = snap["histograms"]["h"]
    assert h["count"] == 4 and h["mean"] == 2.5
    assert h["min"] == 1.0 and h["max"] == 4.0
    assert h["p50"] == 2.0                   # ceil nearest-rank
    with pytest.raises(ValueError):
        reg.counter("a").inc(-1)


def test_registry_consolidates_existing_faces():
    from repro.core.search_cache import SearchCache
    from repro.serve.health import EndpointHealth, HealthConfig
    from repro.serve.metrics import ServeMetrics

    reg = MetricsRegistry()
    cache = SearchCache()
    cache.stats.candidates = 3
    reg.attach_cache_stats("search", cache.stats)
    reg.attach_serve_metrics("serve", ServeMetrics())
    h = EndpointHealth("ep0", HealthConfig(error_threshold=1))
    h.observe_error("died")
    reg.attach_health("health", {"ep0": h})
    snap = reg.snapshot()["collected"]
    assert snap["search"]["candidates"] == 3
    assert snap["serve"]["completed"] == 0
    assert snap["health"]["ep0"]["state"] == "quarantined"
    assert snap["health"]["ep0"]["transitions"] == 1
    # and the public faces are untouched
    assert cache.stats.to_dict()["candidates"] == 3
    assert h.transitions[0]["observed"]["consecutive_errors"] == 1


def test_registry_dead_collector_cannot_sink_snapshot():
    reg = MetricsRegistry()
    reg.register_collector("ok", lambda: 1)
    reg.register_collector("dead", lambda: 1 / 0)
    snap = reg.snapshot()["collected"]
    assert snap["ok"] == 1
    assert "ZeroDivisionError" in snap["dead"]["error"]


# ------------------------------------------------------------------- report
def test_report_sections_render_from_a_trace(tmp_path):
    tr = Tracer()
    tr.set_time(0.0)
    with tr.span("verify", cat="plan", track="backend:hot", backend="hot",
                 compile_s=1.5, cache_hit=False, correct=True,
                 best_time_s=0.005) as sp:
        pass
    with tr.span("route", cat="serve", track="router") as sp:
        sp.set(reason="ok", explain=[
            {"endpoint": "hot0", "verdict": "chosen"},
            {"endpoint": "cool0", "verdict": "over-budget"}])
    tr.event("transition", cat="health", track="endpoint:hot0",
             endpoint="hot0", **{"from": "healthy", "to": "quarantined"},
             reason="died", observed={"errors": 1})
    for tick, (lk, hit) in enumerate([(10, 5), (20, 15)]):
        tr.set_time(tick * 0.01)
        tr.event("tick", cat="loop", track="loop", tick=tick, completed=tick,
                 lookups=lk, lookup_hits=hit, energy_j=1.0 * tick,
                 draw_w=30.0)
    out = render(tr.records)
    assert "hot" in out and "verification times per backend" in out
    assert "chosen x1" in out and "over-budget x1" in out
    assert "healthy -> quarantined" in out and "errors=1" in out
    assert "trends over the run" in out
    # the CLI renders the same text from the archived JSONL
    from repro.obs.report import main
    p = obs.write_jsonl(tr.records, tmp_path / "events.jsonl")
    assert main([p, "--section", "health"]) == 0


def test_report_sections_degrade_gracefully_when_empty():
    out = render([], sections=["routing", "verification", "health",
                               "trends"])
    assert "no route spans" in out and "no plan/verify spans" in out
    assert "no transitions" in out and "no loop/tick events" in out


# ------------------------------------- percentile (ceil-based nearest-rank)
def test_percentile_is_ceil_based_nearest_rank():
    from repro.serve.metrics import percentile
    # the old implementation used round() (banker's rounding): p50 of four
    # values picked index round(2.0)-1 via round-half-even surprises; the
    # nearest-rank definition is ceil(p/100 * n)
    assert percentile([1, 2, 3, 4], 50) == 2
    assert percentile([10, 20], 50) == 10
    assert percentile([1, 2, 3], 25) == 1
    assert percentile([1, 2, 3], 100) == 3
    assert percentile([1, 2, 3], 0) == 1
    assert percentile([5], 95) == 5
    assert percentile([], 50) is None
    assert percentile([3, 1, 2], 66.7) == 3  # sorts first; rank ceil(2.0)=3
    xs = list(range(1, 101))
    assert percentile(xs, 95) == 95
    assert percentile(xs, 95.1) == 96


def test_obs_package_never_imports_jax():
    import subprocess
    import sys
    code = ("import sys; import repro.obs, repro.obs.report; "
            "assert 'jax' not in sys.modules, 'repro.obs pulled in jax'")
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr
