"""Function-block discovery: DB name matching + Deckard-style similarity."""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")   # minimal envs: skip, don't fail collect
from hypothesis import given, settings, strategies as st

from repro.apps import APPS
from repro.core import jaxpr_tools
from repro.core.function_blocks import detect, apply_matches
from repro.core.measure import outputs_close


def test_detect_tdfir_by_name():
    app = APPS["tdFIR"]()
    matches = detect(app)
    assert any(m.entry.name == "tdfir" and m.method == "name"
               for m in matches)


def test_detect_tdfir_by_similarity_when_renamed():
    """Deckard path: strip the name, detection must still find it."""
    app = APPS["tdFIR"]()
    fir_nest = app.nests[0]
    fir_nest.name = "mystery_block_A"           # defeat name matching
    small = app.make_inputs(seed=0, small=True)
    matches = detect(app, small_state=small)
    hit = [m for m in matches if m.entry.name == "tdfir"]
    assert hit and hit[0].method == "similarity", \
        [(m.entry.name, m.method, m.score) for m in matches]
    assert hit[0].score >= 0.55


def test_apply_matches_replaces_and_stays_correct():
    app = APPS["tdFIR"]()
    small = app.make_inputs(seed=0, small=True)
    ref = jax.jit(app.reference_fn())(small)
    matches = detect(app, small_state=small)
    choice = apply_matches(app, matches, "pallas")
    assert choice is not None
    out = jax.jit(app.build(choice))(small)
    assert outputs_close(out, ref)


def test_similarity_identical_is_one():
    def f(x):
        return jnp.tanh(x @ x.T).sum()
    a = jaxpr_tools.fn_fingerprint(f, jnp.ones((4, 4)))
    assert jaxpr_tools.similarity(a, a) == 1.0


def test_similarity_unrelated_is_low():
    def f(x):
        return jnp.tanh(x @ x.T).sum()

    def g(x):
        return jnp.sort(x, axis=0)[0]
    a = jaxpr_tools.fn_fingerprint(f, jnp.ones((4, 4)))
    b = jaxpr_tools.fn_fingerprint(g, jnp.ones((4, 4)))
    assert jaxpr_tools.similarity(a, b) < 0.3


@settings(max_examples=20, deadline=None)
@given(st.lists(st.sampled_from("abcdefg"), min_size=1, max_size=30),
       st.lists(st.sampled_from("abcdefg"), min_size=1, max_size=30))
def test_similarity_bounds_and_symmetry(s1, s2):
    f1 = jaxpr_tools.fingerprint(s1)
    f2 = jaxpr_tools.fingerprint(s2)
    s12 = jaxpr_tools.similarity(f1, f2)
    s21 = jaxpr_tools.similarity(f2, f1)
    assert 0.0 <= s12 <= 1.0
    assert s12 == s21
    if s1 == s2:
        assert s12 == 1.0


def test_flop_estimate_counts_matmul():
    def f(a, b):
        return a @ b
    fl = jaxpr_tools.flop_estimate(f, jnp.ones((8, 16)), jnp.ones((16, 4)))
    assert fl == pytest.approx(2 * 8 * 16 * 4, rel=0.2)
