"""Prefill/decode consistency: the vectorized prefill cache must produce the
same logits as building the cache token-by-token from position 0."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.lm import Model

FAMS = ["granite-3-2b",          # dense GQA
        "h2o-danube-1.8b",       # SWA
        "moonshot-v1-16b-a3b",   # MoE
        "recurrentgemma-2b",     # hybrid
        "mamba2-1.3b",           # ssm
        "llama-3.2-vision-90b",  # vlm
        "seamless-m4t-medium"]   # audio enc-dec


def _batch(cfg, b, s, key):
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size),
             "labels": jnp.zeros((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["img_embed"] = jax.random.normal(
            k2, (b, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            k3, (b, cfg.n_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", FAMS)
def test_prefill_matches_incremental_decode(arch):
    cfg = ARCHS[arch].reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    cache_len = s + 4
    batch = _batch(cfg, b, s, jax.random.PRNGKey(7))

    # path A: vectorized prefill
    last_a, cache_a = jax.jit(
        lambda p, bt: model.prefill(p, bt, cache_len))(params, batch)

    # path B: token-by-token decode from scratch (cross K/V precomputed —
    # they are a function of the modality context, not of decoded tokens)
    cache_b = jax.jit(
        lambda p, bt: model.init_context_cache(p, bt, b, cache_len))(
        params, batch)
    step = jax.jit(model.decode_step)
    for pos in range(s):
        last_b, cache_b = step(params, cache_b,
                               batch["tokens"][:, pos:pos + 1],
                               jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(last_a), np.asarray(last_b),
                               rtol=2e-3, atol=2e-3)

    # one more decode step from each cache must also agree
    tok = jnp.argmax(last_a, -1)[:, None].astype(jnp.int32)
    la, _ = step(params, cache_a, tok, jnp.int32(s))
    lb, _ = step(params, cache_b, tok, jnp.int32(s))
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=2e-3, atol=2e-3)


def test_chunked_loss_matches_full_loss():
    """Property: chunked-vocab xent == full-logit xent."""
    import dataclasses
    from repro.dist.plan import Plan
    cfg = ARCHS["granite-3-2b"].reduced()
    for chunk in (0, 4, 8, 16):
        plan = Plan(vocab_chunk=chunk,
                    blockwise_attn_threshold=10**9)
        model = Model(cfg, plan)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, 2, 16, jax.random.PRNGKey(1))
        loss, _ = jax.jit(model.train_loss)(params, batch)
        if chunk == 0:
            base = float(loss)
        else:
            assert float(loss) == pytest.approx(base, rel=1e-5), chunk


def test_blockwise_plan_matches_dense_plan():
    from repro.dist.plan import Plan
    cfg = ARCHS["granite-3-2b"].reduced()
    batch = _batch(cfg, 2, 32, jax.random.PRNGKey(2))
    dense = Model(cfg, Plan(blockwise_attn_threshold=10**9))
    block = Model(cfg, Plan(blockwise_attn_threshold=1, attn_block_q=16,
                            attn_block_kv=16))
    params = dense.init(jax.random.PRNGKey(0))
    l1, _ = jax.jit(dense.train_loss)(params, batch)
    l2, _ = jax.jit(block.train_loss)(params, batch)
    assert float(l1) == pytest.approx(float(l2), rel=2e-4)


def test_swa_window_actually_masks():
    """SWA logits must differ from full attention when S > window."""
    import dataclasses
    cfg = ARCHS["h2o-danube-1.8b"].reduced()
    cfg_full = dataclasses.replace(cfg, attn_kind="full", window=0)
    cfg_swa = dataclasses.replace(cfg, attn_kind="swa", window=8)
    m1, m2 = Model(cfg_full), Model(cfg_swa)
    params = m1.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, 1, 32, jax.random.PRNGKey(3))
    l1, _ = jax.jit(m1.train_loss)(params, batch)
    l2, _ = jax.jit(m2.train_loss)(params, batch)
    assert abs(float(l1) - float(l2)) > 1e-6


def test_int8_kv_cache_close_to_exact():
    """Quantized-cache decode matches exact decode within int8 tolerance."""
    from repro.dist.plan import Plan
    cfg = ARCHS["granite-3-2b"].reduced()
    b, s = 2, 12
    batch = _batch(cfg, b, s, jax.random.PRNGKey(9))
    exact = Model(cfg, Plan())
    quant = Model(cfg, Plan(kv_cache_quant=True))
    params = exact.init(jax.random.PRNGKey(0))
    la, ca = jax.jit(lambda p, bt: exact.prefill(p, bt, s + 4))(params,
                                                               batch)
    lq, cq = jax.jit(lambda p, bt: quant.prefill(p, bt, s + 4))(params,
                                                                batch)
    assert cq["attn"]["k"].dtype == jnp.int8
    pa = jax.nn.softmax(la, -1)
    pq = jax.nn.softmax(lq, -1)
    assert float(jnp.abs(pa - pq).max()) < 0.05
    # one decode step from each cache stays close
    tok = jnp.argmax(la, -1)[:, None].astype(jnp.int32)
    step_a = jax.jit(exact.decode_step)
    step_q = jax.jit(quant.decode_step)
    la2, _ = step_a(params, ca, tok, jnp.int32(s))
    lq2, _ = step_q(params, cq, tok, jnp.int32(s))
    assert float(jnp.abs(jax.nn.softmax(la2, -1)
                         - jax.nn.softmax(lq2, -1)).max()) < 0.05


def test_ring_place_preserves_last_tokens():
    """Property: ring placement keeps exactly the last W tokens, each at
    slot t % W."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.models.lm import _ring_place

    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 12), st.integers(1, 24))
    def check(w, s):
        k = jnp.arange(s, dtype=jnp.float32)[None, :, None, None]
        k = jnp.broadcast_to(k, (1, s, 2, 3))
        out = _ring_place(k, w, s, jnp.float32)
        assert out.shape[1] == w
        for t in range(max(0, s - w), s):
            np.testing.assert_array_equal(
                np.asarray(out[0, t % w, 0, 0]), float(t))

    check()


def test_quantize_kv_error_bound():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.models.layers import quantize_kv

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 1000))
    def check(seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (4, 8, 16)) \
            * (seed % 5 + 0.1)
        q, scale = quantize_kv(x)
        err = jnp.abs(q.astype(jnp.float32) * scale - x)
        assert float((err <= scale * 0.51).all()), float(err.max())

    check()
