"""Test helpers: run code in a subprocess with a forced multi-device host.

Smoke tests and benches must see 1 device (the task spec forbids setting the
device-count flag globally), so anything needing a mesh runs via this
helper.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 420):
    """Execute `code` in a fresh python with n_devices fake host devices.

    The snippet should print its assertions' outcomes; non-zero exit or
    'FAIL' in output fails the calling test.
    """
    prelude = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={n_devices}'\n"
        "import sys\n"
        f"sys.path.insert(0, {SRC!r})\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout,
        env={**os.environ, "PYTHONPATH": SRC},
    )
    assert proc.returncode == 0, (
        f"subprocess failed\nSTDOUT:\n{proc.stdout[-3000:]}\n"
        f"STDERR:\n{proc.stderr[-3000:]}")
    assert "FAIL" not in proc.stdout, proc.stdout[-3000:]
    return proc.stdout
