"""End-to-end behaviour tests for the paper's system."""
import jax

from repro.apps import APPS
from repro.core.ga import GAConfig
from repro.core.measure import TimedRunner
from repro.core.planner import UserTarget, plan_offload


def test_end_to_end_mixed_destination_selection():
    """The headline behaviour (paper Fig.3): each app gets a destination and
    the selected pattern is correct + modeled no slower than single-core.

    The performance margin is asserted on the CompiledCostRunner's roofline
    of the compiled artifacts, not wall clock — min-of-k timings of sub-ms
    apps stayed flaky on loaded CI hosts, while the modeled comparison is
    deterministic.
    """
    from repro.core.measure import CompiledCostRunner
    cost = CompiledCostRunner()
    for name in APPS:
        app = APPS[name]()
        inputs = app.make_inputs(0, small=True)
        report = plan_offload(
            app, UserTarget(), inputs=inputs,
            runner=TimedRunner(repeats=1),
            ga_cfg=GAConfig(population=3, generations=3, seed=0))
        assert report.selected is not None, name
        assert report.selected.correct, name
        assert len(report.records) == 6, name
        sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), inputs)
        ref_ev = cost.measure(app.reference_fn(), sds)
        sel_ev = cost.measure(app.build(dict(report.selected.choice)), sds)
        assert ref_ev.correct and sel_ev.correct, name
        assert sel_ev.time_s <= ref_ev.time_s * 1.5, \
            (name, sel_ev.time_s, ref_ev.time_s)


def test_training_loss_decreases_end_to_end(tmp_path):
    """Reduced-model training through the fault-tolerant runtime."""
    from repro.launch.train import main
    res = main(["--arch", "granite-3-2b", "--reduced", "--steps", "25",
                "--batch", "4", "--seq", "64", "--save-every", "10",
                "--ckpt-dir", str(tmp_path), "--log-every", "100"])
    losses = [h["loss"] for h in res.metrics_history if "loss" in h]
    assert losses[-1] < losses[0] - 0.2


def test_training_resumes_from_checkpoint(tmp_path):
    from repro.launch.train import main
    main(["--arch", "granite-3-2b", "--reduced", "--steps", "10",
          "--batch", "2", "--seq", "32", "--save-every", "5",
          "--ckpt-dir", str(tmp_path), "--log-every", "100"])
    # second invocation resumes at step 10 and continues to 15
    res = main(["--arch", "granite-3-2b", "--reduced", "--steps", "15",
                "--batch", "2", "--seq", "32", "--save-every", "5",
                "--ckpt-dir", str(tmp_path), "--log-every", "100"])
    steps = [h["step"] for h in res.metrics_history]
    assert steps and min(steps) >= 10


def test_serving_generates_tokens():
    from repro.launch.serve import generate
    from repro.configs import get_config
    from repro.models.lm import Model
    cfg = get_config("h2o-danube-1.8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab_size)}
    out = generate(model, params, batch, prompt_len=8, gen=4,
                   cache_len=16)
    assert out.shape == (2, 4)
    assert int(out.max()) < cfg.padded_vocab


def test_plan_genes_roundtrip():
    from repro.dist.plan import Plan
    p = Plan(remat="full", microbatches=4, grad_compression=True)
    genes = p.to_genes()
    q = Plan.from_genes(genes)
    assert q.remat == "full" and q.microbatches == 4
    assert q.grad_compression is True
    assert len(genes) == len(Plan.gene_cardinalities())
