"""repro.analysis: static plan/kernel lint + gene-contract audit.

Pins the PR-6 contract: statically infeasible candidates are rejected for
the GA penalty *before* any trace/compile (the paper's structure analysis
applied to the framework search), the named plans lint clean on their
documented contexts, the model-only gene flags are *proved* against the
traced artifact, and the built-in Pallas kernel contracts hold.
"""
import json

import pytest

from repro.analysis import (DEVICE_MEMORY_BYTES, Finding, audit_findings,
                            audit_gene_space, check_model, has_errors,
                            lint_kernels, lint_plan, max_severity,
                            sort_findings)
from repro.analysis.kernel_lint import KernelModel, OperandSpec
from repro.configs import get_config, get_shape
from repro.configs.base import ShapeConfig
from repro.dist.plan import NAMED_PLANS, PLAN_CONTEXTS, Plan

SINGLE = {"data": 16, "model": 16}
MULTI = {"pod": 2, "data": 16, "model": 16}
TRAIN = get_shape("train_4k")
DECODE = get_shape("decode_32k")


def rules(findings, rule_id):
    return [f for f in findings if f.rule_id == rule_id]


# ------------------------------------------------------------- findings API
def test_finding_severity_ordering_and_json():
    fs = [Finding("P999", "info", "i"), Finding("P998", "error", "e"),
          Finding("P997", "warning", "w")]
    assert [f.severity for f in sort_findings(fs)] == \
        ["error", "warning", "info"]
    assert has_errors(fs) and max_severity(fs) == "error"
    assert max_severity([]) is None
    d = Finding("P001", "error", "m", plan_field="remat", subject="p",
                context={"x": 1}).to_dict()
    assert d == {"rule_id": "P001", "severity": "error", "message": "m",
                 "plan_field": "remat", "subject": "p", "context": {"x": 1}}
    json.dumps(d)                              # JSON-clean by construction


# --------------------------------------------------------------- plan lint
def test_default_plan_lints_clean_on_train_cell():
    cfg = get_config("granite-3-2b")
    out = lint_plan(Plan(), mesh=SINGLE, cfg=cfg, shape=TRAIN)
    assert not has_errors(out)
    assert not any(f.severity == "warning" for f in out)


def test_p001_nonpositive_gene_short_circuits():
    import dataclasses
    bad = dataclasses.replace(Plan(), microbatches=0, vocab_chunk=-1)
    out = lint_plan(bad, mesh=SINGLE, cfg=get_config("granite-3-2b"),
                    shape=TRAIN)
    assert out and all(f.rule_id == "P001" for f in out)
    assert {f.plan_field for f in out} == {"microbatches", "vocab_chunk"}


def test_p002_microbatch_divisibility_is_an_error_on_train_only():
    import dataclasses
    plan = dataclasses.replace(Plan(), microbatches=3)   # 256 % 3 != 0
    out = lint_plan(plan, shape=TRAIN)
    assert [f.severity for f in rules(out, "P002")] == ["error"]
    # same plan on a decode shape: the gene is inert, not fatal
    out = lint_plan(plan, shape=DECODE)
    assert not rules(out, "P002") and not has_errors(out)
    assert any(f.plan_field == "microbatches" for f in rules(out, "P103"))
    # a dividing microbatch count is silent
    ok = dataclasses.replace(Plan(), microbatches=4)
    assert not rules(lint_plan(ok, shape=TRAIN), "P002")


def test_p003_unknown_schedule_severity_follows_pipelined():
    import dataclasses
    plan = dataclasses.replace(Plan(), pipeline_schedule="zb-h1")
    assert [f.severity for f in rules(lint_plan(plan), "P003")] \
        == ["warning"]
    out = lint_plan(plan, mesh=MULTI, pipelined=True)
    assert [f.severity for f in rules(out, "P003")] == ["error"]
    assert has_errors(out)


def test_p004_unhostable_registered_schedule():
    from repro.dist import schedules as sch

    class NeverHosts(sch.Schedule):
        name = "never-hosts"

        def build(self, **kw):
            return None

    sch.register_schedule(NeverHosts())
    try:
        import dataclasses
        plan = dataclasses.replace(Plan(), pipeline_schedule="never-hosts")
        out = lint_plan(plan, mesh=MULTI, pipelined=True)
        assert [f.severity for f in rules(out, "P004")] == ["error"]
        assert not rules(out, "P003")          # registered, so not unknown
    finally:
        del sch.SCHEDULES["never-hosts"]


def test_p005_p006_p007_pipeline_shape_notes():
    import dataclasses
    plan = dataclasses.replace(Plan(), virtual_stages=2)   # gpipe ignores it
    out = lint_plan(plan, mesh=SINGLE, pipelined=True)
    assert rules(out, "P006") and rules(out, "P005")
    # pod axis present, microbatches < ranks: bubble note with the fraction
    plan = dataclasses.replace(Plan(), microbatches=1)
    out = lint_plan(plan, mesh=MULTI, shape=TRAIN, pipelined=True)
    (f,) = rules(out, "P007")
    assert f.context["bubble_fraction"] > 0
    assert not has_errors(out)


def test_p008_state_floor_overflows_a_single_device():
    cfg = get_config("granite-3-2b")        # ~2.5B params
    out = lint_plan(Plan(), mesh={"data": 1}, cfg=cfg, shape=TRAIN)
    (f,) = rules(out, "P008")
    assert f.severity == "error"
    assert f.context["state_bytes"] > f.context["capacity_bytes"]
    # the production mesh holds it with room to spare
    assert not rules(lint_plan(Plan(), mesh=SINGLE, cfg=cfg, shape=TRAIN),
                     "P008")
    # a raised per-device capacity clears the same cell
    assert not rules(lint_plan(Plan(), mesh={"data": 1}, cfg=cfg,
                               shape=TRAIN,
                               device_memory_bytes=64 * DEVICE_MEMORY_BYTES),
                     "P008")


def test_p009_vocab_chunk_silent_disable():
    import dataclasses
    shape = ShapeConfig("t", seq_len=1000, global_batch=8, kind="train")
    plan = dataclasses.replace(Plan(), vocab_chunk=512)   # 1000 % 512 != 0
    assert [f.severity for f in rules(lint_plan(plan, shape=shape), "P009")] \
        == ["warning"]
    assert not rules(lint_plan(plan, shape=TRAIN), "P009")  # 4096 % 512 == 0


def test_p010_batch_prefix_sharding():
    shape = ShapeConfig("t", 128, 6, "train")       # 6 % 16 != 0
    out = lint_plan(Plan(), mesh=SINGLE, shape=shape)
    assert [f.severity for f in rules(out, "P010")] == ["warning"]
    # partial prefix: 2 % pod(2) == 0 but 2 % (pod*data) != 0 -> info
    shape = ShapeConfig("t", 128, 2, "train")
    assert [f.severity
            for f in rules(lint_plan(Plan(), mesh=MULTI, shape=shape),
                           "P010")] == ["info"]
    # full prefix and singleton batch are both silent
    assert not rules(lint_plan(Plan(), mesh=MULTI, shape=TRAIN), "P010")
    one = ShapeConfig("t", 128, 1, "decode")
    assert not rules(lint_plan(Plan(), mesh=SINGLE, shape=one), "P010")


def test_p012_decode_kv_shard_replication():
    import dataclasses
    plan = dataclasses.replace(Plan(), decode_kv_seq_shard=True)
    shape = ShapeConfig("d", 1000, 8, "decode")     # 1000 % 16 != 0
    assert rules(lint_plan(plan, mesh=SINGLE, shape=shape), "P012")
    assert not rules(lint_plan(plan, mesh=SINGLE, shape=DECODE), "P012")
    # inert on train: P013 note instead
    assert rules(lint_plan(plan, mesh=SINGLE, shape=TRAIN), "P013")


def test_p018_serve_request_overflows_full_attention_cache():
    """Serving context: a request whose prompt+gen exceed cache_len is a
    static error on a full-attention arch (the router prunes the endpoint
    before scoring) and an info note on a sub-quadratic one (window rings
    wrap by design)."""
    full = get_config("granite-3-2b").reduced()          # attn_kind=full
    swa = get_config("h2o-danube-1.8b").reduced()        # attn_kind=swa
    serve = {"n_slots": 2, "cache_len": 64, "prompt_len": 60, "max_gen": 20}
    out = lint_plan(Plan(), cfg=full, serve=serve)
    assert rules(out, "P018") and has_errors(out)
    out = lint_plan(Plan(), cfg=swa, serve=serve)
    assert not has_errors(out)
    assert rules(out, "P104")
    # a fitting request lints clean on both
    ok = {"n_slots": 2, "cache_len": 64, "prompt_len": 8, "max_gen": 8}
    assert not lint_plan(Plan(), cfg=full, serve=ok)


def test_p019_slot_pool_exceeds_capacity_and_quant_hint():
    """A slot pool the endpoint's memory provably cannot host is a static
    error; when int8 KV would fit, the P104 hint names kv_cache_quant."""
    import dataclasses
    cfg = get_config("granite-3-2b")                     # full-size params
    serve = {"n_slots": 64, "cache_len": 131072,
             "prompt_len": 8, "max_gen": 8}
    # 1-device endpoint: pool + params blow straight past 16 GiB
    out = lint_plan(Plan(), cfg=cfg, serve=serve)
    p19 = rules(out, "P019")
    assert p19 and has_errors(out)
    # with quant requested the pool halves; whether or not it then fits,
    # the unquantized lint must carry the hint exactly when quant rescues
    hints = rules(out, "P104")
    quant_out = lint_plan(dataclasses.replace(Plan(), kv_cache_quant=True),
                          cfg=cfg, serve=serve)
    if not rules(quant_out, "P019"):
        assert hints, "quant rescues the pool but no P104 hint was raised"
    # a small pool on a big endpoint lints clean
    small = {"n_slots": 2, "cache_len": 256, "prompt_len": 8, "max_gen": 8}
    assert not rules(lint_plan(Plan(), mesh={"data": 64}, cfg=cfg,
                               serve=small), "P019")


def test_serve_lint_accepts_endpoint_like_objects():
    """The serve context duck-types: the router passes dicts, but any
    object with the four fields works."""
    class Ep:
        n_slots, cache_len, prompt_len, max_gen = 2, 32, 30, 30
    out = lint_plan(Plan(), cfg=get_config("granite-3-2b").reduced(),
                    serve=Ep())
    assert rules(out, "P018")


def test_named_plans_lint_clean_on_documented_contexts():
    """Acceptance (satellite 2): every named plan on its documented mesh and
    shapes carries no error- or warning-severity findings."""
    from repro.analysis.lint import PRODUCTION_MESHES
    from repro.configs import ARCHS, cell_runnable

    for name, plan in NAMED_PLANS.items():
        ctx = PLAN_CONTEXTS[name]
        mesh = PRODUCTION_MESHES[ctx["mesh"]]
        for arch in ARCHS:
            cfg = get_config(arch)
            for shape_name in ctx["shapes"]:
                shape = get_shape(shape_name)
                if not cell_runnable(cfg, shape):
                    continue
                out = lint_plan(plan, mesh=mesh, cfg=cfg, shape=shape)
                bad = [f for f in out if f.severity != "info"]
                assert not bad, (name, arch, shape_name,
                                 [f.to_dict() for f in bad])


# -------------------------------------------------------------- kernel lint
def test_builtin_kernels_lint_without_errors():
    out = lint_kernels()
    assert out                                   # padding/accum notes exist
    assert not has_errors(out), [f.to_dict() for f in out
                                 if f.severity == "error"]
    # the declared accumulations are surfaced, not flagged
    assert any(f.rule_id == "K003" and f.severity == "info" for f in out)


def test_kernel_wrapper_asserts_become_k001_errors():
    from repro.analysis.kernel_lint import (decode_attention_model,
                                            flash_attention_model,
                                            tdfir_model)
    model, errs = flash_attention_model(sq=1000, block_q=512)  # 1000 % 512
    assert model is None and [f.rule_id for f in errs] == ["K001"]
    model, errs = decode_attention_model(s=1000, block_kv=512)
    assert model is None and has_errors(errs)
    model, errs = tdfir_model(n=8, k=16, block_n=8)            # bn < taps
    assert model is None and has_errors(errs)


def _model(grid, out_map, accum=(), in_map=None, dims=(64, 64),
           block=(32, 32)):
    in_map = in_map or out_map
    return KernelModel(
        name="t", grid=grid,
        inputs=[OperandSpec("a", dims, block, in_map)],
        output=OperandSpec("o", dims, block, out_map), accum_dims=accum)


def test_k001_non_dividing_block_is_an_error():
    m = _model((2, 2), lambda i, j: (i, j), dims=(64, 60))  # 60 % 32 != 0
    out = check_model(m)
    assert any(f.rule_id == "K001" and f.severity == "error" for f in out)


def test_k002_out_of_bounds_index_map():
    m = _model((2, 2), lambda i, j: (i, j),
               in_map=lambda i, j: (i + 1, j))     # i=1 -> block 2 of 2
    out = check_model(m)
    assert any(f.rule_id == "K002" and f.severity == "error" for f in out)
    # a raising map is also a K002, not a crash
    def boom(i, j):
        raise ValueError("bad map")
    out = check_model(_model((2, 2), lambda i, j: (i, j), in_map=boom))
    assert any(f.rule_id == "K002" for f in out)


def test_k003_undeclared_and_non_trailing_accumulation():
    # output ignores the trailing grid dim but declares no accumulation
    m = _model((2, 2), lambda i, j: (i, 0))
    out = check_model(m)
    assert any(f.rule_id == "K003" and f.severity == "error"
               and "declares no" in f.message for f in out)
    # declaring it turns the hazard into an info note
    m = _model((2, 2), lambda i, j: (i, 0), accum=(1,))
    out = check_model(m)
    assert [f.severity for f in rules(out, "K003")] == ["info"]
    # revisits across a NON-trailing dim are unsound even if declared
    m = _model((2, 2), lambda i, j: (0, j), accum=(0,))
    out = check_model(m)
    assert any(f.rule_id == "K003" and f.severity == "error"
               and "trailing" in f.message for f in out)


# -------------------------------------------------------------- gene audit
@pytest.fixture(scope="module")
def audit_trace_fn():
    from repro.analysis.gene_audit import default_trace_fn
    return default_trace_fn()


def test_model_only_genes_are_artifact_invariant(audit_trace_fn):
    """Acceptance: audit_gene_space() proves both structural=False genes
    never change the traced artifact — the search-cache identity is sound."""
    audits = audit_gene_space(trace_fn=audit_trace_fn)
    assert {a.field for a in audits} == {"pipeline_schedule",
                                         "virtual_stages"}
    for a in audits:
        assert a.declared_model_only and a.artifact_invariant
        assert not a.violation and a.checked_values
    fs = audit_findings(audits)
    assert [f.rule_id for f in fs] == ["G002", "G002"]
    assert not has_errors(fs)


def test_mislabeled_structural_gene_is_caught(audit_trace_fn):
    """Acceptance: inject a gene space where a genuinely structural gene
    (remat reaches the traced train step) is flagged model-only — the audit
    must detect the unsound cache identity."""
    from repro.dist.plan import Gene
    bad_space = [Gene("remat", ("none", "block", "full"), structural=False)]
    (a,) = audit_gene_space(trace_fn=audit_trace_fn, gene_space=bad_space)
    assert a.declared_model_only and not a.artifact_invariant
    assert a.violation and "changes the artifact" in a.detail
    (f,) = audit_findings([a])
    assert f.rule_id == "G001" and f.severity == "error"


def test_structural_gene_audit_reports_g003(audit_trace_fn):
    # auditing a correctly-labeled structural gene: G003, never an error
    (a,) = audit_gene_space(trace_fn=audit_trace_fn, fields=["remat"])
    assert not a.declared_model_only and not a.artifact_invariant
    assert not a.violation
    (f,) = audit_findings([a])
    assert f.rule_id == "G003" and f.severity == "info"


# ----------------------------------------- prune-before-compile (evaluator)
from repro.core import search_cache as sc  # noqa: E402
from repro.core.ga import Evaluation, GAConfig, run_ga  # noqa: E402
from test_search_cache import genes_with, make_evaluator  # noqa: E402

# batch=6: microbatches gene values 4 and 8 are statically infeasible
# (6 % 4, 6 % 8), 1 and 2 are fine — a population the linter can split
SHAPE_B6 = ShapeConfig("b6", seq_len=32, global_batch=6, kind="train")
SHAPE_B8 = ShapeConfig("b8", seq_len=32, global_batch=8, kind="train")


def lint_for(shape):
    return lambda plan: lint_plan(plan, shape=shape)


def test_evaluator_prunes_infeasible_without_tracing():
    counter = {"lowers": 0, "compiles": 0}
    cache = sc.SearchCache()
    ev = make_evaluator(cache, counter, lint=lint_for(SHAPE_B6))
    evs = ev([genes_with(), genes_with(microbatches=4),
              genes_with(microbatches=8)])
    assert counter["compiles"] == 1             # only the feasible candidate
    assert counter["lowers"] == 1
    assert evs[0].correct
    for e in evs[1:]:
        assert not e.correct and e.info["static_pruned"]
        assert e.info["static_findings"][0]["rule_id"] == "P002"
    assert cache.stats.static_pruned == 2
    assert cache.stats.candidates == 3
    assert cache.stats.to_dict()["static_pruned"] == 2
    # pruned candidates are not hits: only the feasible one was scored
    assert cache.stats.hits == 0 and cache.stats.misses == 1


def test_lint_verdicts_are_memoized_per_individual():
    calls = {"n": 0}

    def counting_lint(plan):
        calls["n"] += 1
        return lint_plan(plan, shape=SHAPE_B6)

    counter = {"lowers": 0, "compiles": 0}
    ev = make_evaluator(sc.SearchCache(), counter, lint=counting_lint)
    gen = [genes_with(microbatches=4), genes_with()]
    ev(gen)
    ev(gen)                                     # second generation: memo
    assert calls["n"] == 2


def test_ga_with_linter_spends_strictly_less_xla_work_same_selection():
    """Acceptance: same GA, same seed, a population containing statically
    infeasible candidates — the linted run attempts strictly fewer
    trace/lower calls (the infeasible ones fail at trace time, exactly like
    ``_split_microbatches``' assert), selects the identical winner, and the
    prunes are visible in the GA history."""
    from repro.core.measure import CompiledCostRunner
    from test_search_cache import FakeLowered

    cards = Plan.gene_cardinalities()
    cfg = GAConfig(population=8, generations=4, seed=3, cardinalities=cards)

    def run(lint):
        counter = {"lowers": 0, "compiles": 0}

        def lower_plan(plan):               # faithful: infeasible plans
            counter["lowers"] += 1          # die at trace, before compile
            assert SHAPE_B6.global_batch % plan.microbatches == 0
            return FakeLowered(counter)

        ev = sc.make_cached_batch_evaluator(
            lower_plan, CompiledCostRunner(n_chips=1), sc.SearchCache(),
            key_extra=("test",), pipe_ranks=2, lint=lint)
        res = run_ga(len(cards), ev.evaluate, cfg, evaluate_batch=ev)
        return counter, res, ev.cache.stats

    base_counter, base_res, _ = run(None)
    lint_counter, lint_res, stats = run(lint_for(SHAPE_B6))
    assert stats.static_pruned > 0
    # both runs see the same fitness landscape (infeasible == penalty either
    # way), so the trajectories match — the linted one just never pays the
    # trace for what it can reject arithmetically
    assert lint_counter["lowers"] < base_counter["lowers"]
    assert lint_counter["compiles"] == base_counter["compiles"]
    assert lint_res.best_genes == base_res.best_genes
    assert sum(h["n_pruned"] for h in lint_res.history) > 0
    # the winner is a genuinely feasible plan
    best = Plan.from_genes(list(lint_res.best_genes))
    assert not has_errors(lint_plan(best, shape=SHAPE_B6))
    assert lint_res.best_eval.correct


def test_ga_with_linter_identical_on_all_feasible_population():
    """Acceptance: when nothing is infeasible (batch divides every
    microbatch gene) the linter changes no outcome and no compile count."""
    cards = Plan.gene_cardinalities()
    cfg = GAConfig(population=8, generations=4, seed=5, cardinalities=cards)

    def run(lint):
        counter = {"lowers": 0, "compiles": 0}
        ev = make_evaluator(sc.SearchCache(), counter, lint=lint)
        res = run_ga(len(cards), ev.evaluate, cfg, evaluate_batch=ev)
        return counter, res, ev.cache.stats

    base_counter, base_res, _ = run(None)
    lint_counter, lint_res, stats = run(lint_for(SHAPE_B8))
    assert stats.static_pruned == 0
    assert lint_counter["compiles"] == base_counter["compiles"]
    assert lint_res.best_genes == base_res.best_genes
    assert lint_res.best_eval.effective_time == \
        base_res.best_eval.effective_time


# ------------------------------------------------- prune in the loop GA
def test_loop_ga_lint_choice_prunes_without_measuring():
    from repro.backends.builtin import MANY_CORE
    from repro.core.loop_offload import ga_search

    class Nest:
        def __init__(self, name, impls):
            self.name = name
            self.impls = impls

    class App:
        name = "lint-app"
        nests = [Nest("a", {"dp": None, "seq": None}),
                 Nest("b", {"dp": None, "seq": None})]

        def build(self, choice):
            return dict(choice)

    class CountingRunner:
        def __init__(self):
            self.calls = []

        def measure(self, fn, inputs, ref_out):
            self.calls.append(dict(fn))
            return Evaluation(time_s=1.0, correct=True)

    def lint_choice(choice):
        # statically reject any pattern offloading nest "a"
        if choice.get("a") == "dp":
            return [Finding("X001", "error", "nest a cannot offload")]
        return []

    runner = CountingRunner()
    res = ga_search(App(), MANY_CORE, runner, inputs=None, ref_out=None,
                    ga_cfg=GAConfig(population=4, generations=4, seed=0),
                    lint_choice=lint_choice)
    assert res.cache_stats["static_pruned"] >= 1
    assert all(c.get("a") != "dp" for c in runner.calls)   # never measured
    assert res.best_choice.get("a") != "dp"
    assert res.best_correct
    assert res.cache_stats["measured"] == len(runner.calls)


def test_fpga_search_lint_prunes_candidate_slots():
    import jax
    from repro.apps import APPS
    from repro.core.destinations import FPGA
    from repro.core.loop_offload import fpga_search
    from repro.core.measure import TimedRunner

    app = APPS["3mm"]()
    st = app.make_inputs(seed=0, small=True)
    ref = jax.jit(app.reference_fn())(st)

    def lint_choice(choice):
        if choice.get("mm1_E_AB") == "pallas":
            return [Finding("X001", "error", "mm1 statically rejected")]
        return []

    res = fpga_search(app, FPGA, TimedRunner(repeats=1), st, ref, st,
                      lint_choice=lint_choice)
    assert res.cache_stats["static_pruned"] >= 1
    assert res.best_choice.get("mm1_E_AB") != "pallas"
    assert res.n_measurements <= 4


# ------------------------------------------------------------------- CLI
def test_lint_cli_clean_and_writes_report(tmp_path, capsys):
    from repro.analysis.lint import main
    out = tmp_path / "findings.json"
    rc = main(["--no-gene-audit", "--strict", "--json", str(out)])
    assert rc == 0, capsys.readouterr().out
    report = json.loads(out.read_text())
    assert report["cells"] > 0
    assert report["severity_counts"]["error"] == 0
    assert report["severity_counts"]["warning"] == 0
    assert report["strict"] is True
    assert isinstance(report["kernel_and_gene_findings"], list)


def test_lint_cli_exits_nonzero_on_infeasible_what_if(capsys):
    from repro.analysis.lint import main
    # train-tight-mem (microbatches=4) forced onto a decode cell with
    # --pipelined on the single mesh: P005 warning -> strict fails
    rc = main(["--plan", "train-tight-mem", "--shape", "decode_32k",
               "--mesh", "single", "--pipelined", "--strict",
               "--no-gene-audit", "--no-kernel-lint"])
    assert rc == 1
    assert "[warning]" in capsys.readouterr().out


def test_lint_cli_unknown_plan_fails():
    from repro.analysis.lint import main
    with pytest.raises(SystemExit):
        main(["--plan", "no-such-plan", "--no-gene-audit",
              "--no-kernel-lint"])


# ------------------------------------------------------------- dryrun cell
def test_dryrun_cell_is_statically_pruned_before_compile():
    """An infeasible plan reaches the cell JSON as lint findings + error
    WITHOUT spending a lower/compile (subprocess: dryrun forces the
    512-device XLA flag at import)."""
    import os
    import subprocess
    import sys

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = """
import sys
sys.path.insert(0, {src!r})
from repro.launch.dryrun import run_cell
res = run_cell("granite-3-2b", "train_4k", "single",
               overrides={{"microbatches": 3}}, use_cache=False)
assert "statically pruned" in res["error"], res
assert any(f["rule_id"] == "P002" for f in res["lint"]), res["lint"]
assert "compile_s" not in res and "roofline" not in res, sorted(res)
print("ok")
""".format(src=os.path.abspath(src))
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "ok" in proc.stdout
