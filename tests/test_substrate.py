"""Substrate: optimizer, data pipeline, checkpointing, fault tolerance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # minimal envs: skip, don't fail collect
from hypothesis import given, settings, strategies as st

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import TrainConfig
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.runtime.fault_tolerance import (StragglerWatchdog, run_resilient)
from repro.train import optimizer


# ------------------------------------------------------------- optimizer
def test_adamw_descends_quadratic():
    tcfg = TrainConfig(lr=0.1, warmup_steps=1, total_steps=200,
                       weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = optimizer.init(params, tcfg)

    @jax.jit
    def step(params, state):
        grads = {"w": 2 * params["w"]}          # d/dw w^2
        return optimizer.update(grads, state, params, tcfg)

    for _ in range(100):
        params, state, metrics = step(params, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5
    assert np.isfinite(float(metrics["grad_norm"]))


def test_grad_clip_bounds_update():
    tcfg = TrainConfig(lr=1.0, warmup_steps=0, grad_clip=1e-3,
                       weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = optimizer.init(params, tcfg)
    grads = {"w": jnp.full(3, 1e6)}
    new_params, _, m = optimizer.update(grads, state, params, tcfg)
    assert float(jnp.abs(new_params["w"]).max()) < 10.0


def test_lr_schedule_warmup_and_decay():
    tcfg = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(optimizer.lr_schedule(tcfg, s)) for s in range(101)]
    assert lrs[1] < lrs[9] <= lrs[11]
    assert lrs[100] < lrs[20]
    assert max(lrs) <= 1e-3 * 1.001


def test_master_copy_mode():
    tcfg = TrainConfig(lr=0.01, warmup_steps=0, use_master_copy=True)
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = optimizer.init(params, tcfg)
    assert "master" in state and state["master"]["w"].dtype == jnp.float32
    grads = {"w": jnp.ones(4, jnp.bfloat16)}
    new_params, new_state, _ = optimizer.update(grads, state, params, tcfg)
    assert new_params["w"].dtype == jnp.bfloat16
    assert new_state["master"]["w"].dtype == jnp.float32


# ------------------------------------------------------------------ data
def test_data_deterministic_and_step_dependent():
    cfg = DataConfig(vocab_size=101, seq_len=16, global_batch=4, seed=3)
    pipe = SyntheticTokens(cfg)
    b1 = pipe.batch(7)
    b2 = pipe.batch(7)
    b3 = pipe.batch(8)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert int(b1["tokens"].max()) < 101
    # labels are next-token shifted structure: learnable recurrence
    assert b1["labels"].shape == (4, 16)


def test_data_resume_from_state():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2, seed=0)
    pipe = SyntheticTokens(cfg)
    st_ = pipe.state_dict(step=42)
    assert SyntheticTokens.resume_step(st_) == 42


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16)},
            "count": jnp.int32(5)}
    ck.save(10, tree, {"next_step": 10})
    got, extra = ck.restore()
    assert extra["next_step"] == 10
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(tree["a"]))
    assert got["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_gc_keeps_last_n(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.ones(2)})
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.async_save(3, {"x": jnp.full(8, 3.0)})
    ck.wait()
    got, _ = ck.restore(3)
    assert float(got["x"][0]) == 3.0


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    ck = Checkpointer(str(tmp_path))
    (tmp_path / "step_99.tmp").mkdir()          # simulated dead writer
    ck.save(1, {"x": jnp.ones(1)})
    assert ck.latest_step() == 1


# -------------------------------------------------------- fault tolerance
def test_resilient_loop_restarts_and_completes(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    faults = {7}

    def fault_hook(step):
        if step in faults:
            faults.discard(step)
            raise RuntimeError("injected node failure")

    def init_state():
        return {"x": jnp.zeros(())}

    def step_fn(state, step):
        return {"x": state["x"] + 1}, {"loss": float(step)}

    res = run_resilient(total_steps=12, checkpointer=ck,
                        init_state=init_state, step_fn=step_fn,
                        save_every=4, fault_hook=fault_hook,
                        async_checkpoint=False)
    assert res.last_step == 12
    assert res.restarts == 1
    state, _ = ck.restore()
    assert float(state["x"]) == 12


def test_resilient_loop_gives_up_after_max_restarts(tmp_path):
    ck = Checkpointer(str(tmp_path))

    def always_fail(state, step):
        raise RuntimeError("dead node")

    with pytest.raises(RuntimeError):
        run_resilient(total_steps=3, checkpointer=ck,
                      init_state=lambda: {"x": jnp.zeros(())},
                      step_fn=always_fail, save_every=1, max_restarts=2,
                      async_checkpoint=False)


def test_straggler_watchdog_flags_outlier():
    wd = StragglerWatchdog(threshold=3.0)
    for i in range(20):
        wd.record(i, 0.1 + 0.001 * (i % 3))
    assert not wd.flagged
    assert wd.record(20, 5.0)                   # 50x slower step
    assert wd.flagged[0]["step"] == 20


# ---------------------------------------------------- grad compression
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 1000))
def test_int8_quantization_error_bound(seed):
    from repro.train.grad_compression import quantize_int8, dequantize_int8
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * (seed % 7 + 1)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-6
