"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as fa
from repro.kernels import matmul as mm
from repro.kernels import tdfir as fir
from repro.kernels import ref


@pytest.mark.parametrize("m,k,n", [(32, 32, 32), (100, 70, 130),
                                   (128, 256, 64), (17, 19, 23)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(m, k, n, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, (m, k), dtype)
    b = jax.random.normal(k2, (k, n), dtype)
    out = mm.matmul(a, b, block_m=32, block_n=32, block_k=32,
                    interpret=True)
    want = ref.matmul_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("f,n,k,bn", [(2, 128, 8, 32), (4, 300, 16, 64),
                                      (8, 256, 32, 128), (1, 512, 4, 256)])
def test_tdfir_shapes(f, n, k, bn):
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    x = jax.random.normal(ks[0], (f, n), jnp.float32)
    h = jax.random.normal(ks[1], (f, k), jnp.float32)
    out = fir.tdfir(x, h, block_n=bn, interpret=True)
    want = ref.tdfir_ref(x, h)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_tdfir_complex():
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    xr = jax.random.normal(ks[0], (2, 128), jnp.float32)
    xi = jax.random.normal(ks[1], (2, 128), jnp.float32)
    hr = jax.random.normal(ks[2], (2, 8), jnp.float32)
    hi = jax.random.normal(ks[3], (2, 8), jnp.float32)
    got_r, got_i = fir.tdfir_complex(xr, xi, hr, hi, block_n=64,
                                     interpret=True)
    want_r, want_i = ref.tdfir_complex_ref(xr, xi, hr, hi)
    np.testing.assert_allclose(np.asarray(got_r), np.asarray(want_r),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(got_i), np.asarray(want_i),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("bh,sq,skv,d,bq,bkv", [
    (2, 64, 64, 16, 32, 32),
    (3, 128, 128, 32, 32, 64),
    (1, 96, 96, 64, 32, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(bh, sq, skv, d, bq, bkv, causal):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (bh, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (bh, skv, d), jnp.float32)
    v = jax.random.normal(ks[2], (bh, skv, d), jnp.float32)
    out = fa.flash_attention(q, k, v, causal=causal, block_q=bq,
                             block_kv=bkv, interpret=True)
    want = ref.mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (2, 64, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 64, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 64, 32), jnp.bfloat16)
    out = fa.flash_attention(q, k, v, block_q=32, block_kv=32,
                             interpret=True)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_blockwise_attention_matches_dense():
    """The model-layer pure-JAX blockwise attention vs dense (GQA+window)."""
    from repro.models import layers
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (2, 128, 8, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, 128, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, 128, 2, 16), jnp.float32)
    for window in (0, 37):
        want = layers.dense_attention(q, k, v, causal=True, window=window)
        got = layers.blockwise_attention(q, k, v, causal=True,
                                         window=window, block_q=32,
                                         block_kv=32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bh,s,d,bkv,clen", [
    (4, 256, 64, 64, 256), (2, 512, 32, 128, 300), (1, 128, 128, 64, 1),
])
def test_decode_attention_kernel(bh, s, d, bkv, clen):
    from repro.kernels import decode_attention as dak
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (bh, d), jnp.float32)
    k = jax.random.normal(ks[1], (bh, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (bh, s, d), jnp.float32)
    got = dak.decode_attention(q, k, v, jnp.int32(clen), block_kv=bkv,
                               interpret=True)
    want = dak.decode_attention_ref(q, k, v, jnp.int32(clen))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
