"""repro.power: energy model, power-aware selection policies, selection
constraints, and the function-blocks-only library backend."""
import jax.numpy as jnp
import pytest

from repro.backends import (Backend, BackendRegistry, DEFAULT_REGISTRY,
                            GPU_LIBRARY, SelectionPolicy, get_policy,
                            registry_with_library_backend)
from repro.backends.builtin import ga_loop_search
from repro.core import cost_model
from repro.core.function_blocks import (FunctionBlockEntry, Registry)
from repro.core.ga import Evaluation, GAConfig
from repro.core.offloadable import LoopNest, OffloadableApp
from repro.core.planner import UserTarget, VerificationRecord, plan_offload
from repro.power import (EnergyModel, FPGA_A10, GENERIC, GPU_T4,
                         MANY_CORE_XEON, PowerEnvelope, energy_for_record,
                         envelope_for)


# ------------------------------------------------- scripted environment
class ScriptedRunner:
    """Deterministic verification environment: the app encodes its own
    "processing time" in the output scalar."""

    def measure(self, fn, inputs, reference_out):
        out = fn(inputs)
        return Evaluation(time_s=float(out), correct=True,
                          info={"output": out})


def _stage(value):
    def impl(state):
        s = dict(state)
        s["out"] = jnp.float32(value)
        return s
    return impl


def _scripted_app(times, nest_name="stage"):
    nest = LoopNest(name=nest_name,
                    impls={k: _stage(v) for k, v in times.items()})
    return OffloadableApp(
        name="scripted",
        nests=[nest],
        make_inputs=lambda seed=0, small=False: {"x": jnp.ones((4,))})


class RooflineCostRunner:
    """Scripted mesh verification: a real Roofline per backend key."""

    def __init__(self, rooflines):
        self.rooflines = rooflines


def _roofline_mesh_verify(backend, cost_runner, fn, inputs):
    rl = cost_runner.rooflines.get(backend.key)
    if rl is None:
        return None
    return Evaluation(time_s=rl.step_time_s, correct=True,
                      info={"roofline": rl.to_dict()})


def _dp_tp_registry(**backend_overrides):
    dp = Backend(key="dp", name="xla_dp", paper_analogue="many-core CPU",
                 price=1.2, verify_time=1.0, mesh_role="data",
                 power=MANY_CORE_XEON, search_fn=ga_loop_search,
                 mesh_verify_fn=_roofline_mesh_verify,
                 **backend_overrides.get("dp", {}))
    tp = Backend(key="tp", name="sharded_tp", paper_analogue="GPU",
                 price=1.0, verify_time=1.5, mesh_role="model",
                 power=GPU_T4, search_fn=ga_loop_search,
                 mesh_verify_fn=_roofline_mesh_verify,
                 **backend_overrides.get("tp", {}))
    return BackendRegistry([dp, tp])


def _plan_kwargs(backends, **extra):
    return dict(runner=ScriptedRunner(),
                ga_cfg=GAConfig(population=2, generations=2),
                registry=Registry(), backends=backends, **extra)


# -------------------------------------------------------------- envelope
def test_envelope_validation():
    with pytest.raises(ValueError):
        PowerEnvelope("bad", idle_w=-1.0, peak_w=10.0)
    with pytest.raises(ValueError):
        PowerEnvelope("bad", idle_w=20.0, peak_w=10.0)
    with pytest.raises(ValueError):
        PowerEnvelope("bad", idle_w=1.0, peak_w=10.0,
                      memory_w_fraction=1.5)
    env = PowerEnvelope("ok", idle_w=10.0, peak_w=70.0)
    assert env.active_w == 60.0
    scaled = env.scaled(4)
    assert scaled.idle_w == 40.0 and scaled.peak_w == 280.0
    assert scaled.memory_w_fraction == env.memory_w_fraction
    with pytest.raises(ValueError):
        env.scaled(0)


def test_envelope_for_resolution():
    # declared envelope wins; built-in calibration by analogue next;
    # generic last
    b = Backend(key="x", name="x", paper_analogue="GPU", price=1.0,
                verify_time=1.0, power=FPGA_A10, search_fn=ga_loop_search)
    assert envelope_for(b) is FPGA_A10
    b2 = b.with_(power=None)
    assert envelope_for(b2) is GPU_T4
    b3 = b.with_(power=None, paper_analogue="quantum annealer")
    assert envelope_for(b3) is GENERIC


# ---------------------------------------------------------- energy model
def test_roofline_carries_utilization_terms():
    rl = cost_model.roofline_terms(1e12, 1e11, 1e9, n_chips=4)
    step = rl.step_time_s
    assert rl.compute_util == pytest.approx(rl.compute_s / step)
    assert rl.memory_util == pytest.approx(rl.memory_s / step)
    assert rl.collective_util == pytest.approx(rl.collective_s / step)
    # the dominant term saturates its utilization when there is no bubble
    assert max(rl.compute_util, rl.memory_util,
               rl.collective_util) == pytest.approx(1.0)
    # a bubble stretches the step, so every utilization shrinks
    rb = cost_model.roofline_terms(1e12, 1e11, 1e9, n_chips=4,
                                   bubble_fraction=0.5)
    assert rb.memory_util == pytest.approx(rl.memory_util * 0.5)


def test_energy_monotone_in_bubble_fraction():
    model = EnergyModel(GPU_T4)
    energies = []
    for bubble in (0.0, 0.2, 0.4, 0.6):
        rl = cost_model.roofline_terms(1e12, 1e11, 1e9, n_chips=4,
                                       bubble_fraction=bubble)
        energies.append(model.from_roofline(rl).energy_j)
    assert energies == sorted(energies)
    assert energies[0] < energies[-1]
    # watts fall with the bubble (the device idles more of the step) even
    # though the total joules rise
    w0 = model.from_roofline(
        cost_model.roofline_terms(1e12, 1e11, 1e9, n_chips=4)).avg_watts
    w6 = model.from_roofline(
        cost_model.roofline_terms(1e12, 1e11, 1e9, n_chips=4,
                                  bubble_fraction=0.6)).avg_watts
    assert w6 < w0


def test_host_time_fallback_charges_peak_watts():
    model = EnergyModel(GPU_T4)
    rep = model.from_time(0.5)
    assert rep.source == "host-time"
    assert rep.avg_watts == pytest.approx(GPU_T4.peak_w)
    assert rep.energy_j == pytest.approx(GPU_T4.peak_w * 0.5)
    assert rep.edp == pytest.approx(rep.energy_j * 0.5)
    assert rep.perf_per_watt == pytest.approx(1.0 / rep.energy_j)
    assert model.from_time(float("inf")) is None
    assert model.from_time(0.0) is None


def test_energy_for_record_prefers_roofline_over_host_time():
    rl = cost_model.roofline_terms(1e12, 1e11, 1e9, n_chips=4)
    rec = VerificationRecord(
        order=1, destination="x", paper_analogue="GPU", method="loop",
        best_time_s=0.5, improvement=2.0, price=1.0, n_measurements=1,
        verify_elapsed_s=0.0, met_target=False,
        mesh_info={"roofline": rl.to_dict()})
    rep = energy_for_record(rec, GPU_T4)
    assert rep.source == "roofline"
    assert rep.step_time_s == pytest.approx(rl.step_time_s)
    rec.mesh_info = {}
    assert energy_for_record(rec, GPU_T4).source == "host-time"
    rec.correct = False
    assert energy_for_record(rec, GPU_T4) is None


# ----------------------------------------------------- power-aware planner
def _comm_bound_setup():
    """tp wins on the host but is comm-bound on the mesh; dp is a lean
    compute-bound candidate."""
    app = _scripted_app({"seq": 1.0, "dp": 0.8, "tp": 0.5})
    rl_dp = cost_model.roofline_terms(2e13, 1e10, 1e8, n_chips=4)
    rl_tp = cost_model.roofline_terms(2e13, 1e11, 5e10, n_chips=4)
    assert rl_tp.dominant == "collective" and rl_dp.dominant == "compute"
    cost_runner = RooflineCostRunner({"dp": rl_dp, "tp": rl_tp})
    return app, cost_runner, rl_dp, rl_tp


def test_power_policy_flips_comm_bound_winner():
    """Acceptance: the comm-bound candidate wins under host-time and loses
    under power — and the power ranking is modeled joules, not the old
    price x time stub."""
    app, cost_runner, rl_dp, rl_tp = _comm_bound_setup()
    common = _plan_kwargs(_dp_tp_registry(), cost_runner=cost_runner)

    host = plan_offload(app, UserTarget(), policy="host-time", **common)
    assert host.selected.destination == "sharded_tp"

    power = plan_offload(app, UserTarget(), policy="power", **common)
    assert power.policy == "power"
    assert power.selected.destination == "xla_dp"
    # records carry the modeled charge the policy ranked
    dp_rec = next(r for r in power.records
                  if r.destination == "xla_dp" and r.method == "loop")
    tp_rec = next(r for r in power.records
                  if r.destination == "sharded_tp" and r.method == "loop")
    assert dp_rec.energy_j == pytest.approx(
        EnergyModel(MANY_CORE_XEON).from_roofline(rl_dp).energy_j)
    assert tp_rec.energy_j == pytest.approx(
        EnergyModel(GPU_T4).from_roofline(rl_tp).energy_j)
    assert dp_rec.energy_j < tp_rec.energy_j
    assert dp_rec.energy_info["source"] == "roofline"
    # the old stub ranked price x time and would have kept tp
    # (0.5 x 1.0 < 0.8 x 1.2)
    assert tp_rec.best_time_s * tp_rec.price < \
        dp_rec.best_time_s * dp_rec.price
    # summary rows surface the energy columns
    rows = power.summary_rows()
    sel_row = next(row for row in rows if row["selected"])
    assert sel_row["energy_j"] is not None
    assert sel_row["avg_watts"] is not None


def test_edp_policy_ranks_energy_delay_product():
    app, cost_runner, rl_dp, rl_tp = _comm_bound_setup()
    common = _plan_kwargs(_dp_tp_registry(), cost_runner=cost_runner)
    report = plan_offload(app, UserTarget(), policy="edp", **common)
    assert report.policy == "edp"
    # dp has both lower energy and lower modeled delay here -> still wins
    assert report.selected.destination == "xla_dp"
    pol = get_policy("edp")
    recs = [r for r in report.records if r.method == "loop"]
    assert min(recs, key=pol.score).destination == "xla_dp"


def test_host_records_get_envelope_times_host_time_fallback():
    """No cost_runner: every correct record is still charged envelope x
    host time, so the power policy keeps working (and prefers the T4 here:
    0.5 s x 70 W < 0.8 s x 105 W)."""
    app = _scripted_app({"seq": 1.0, "dp": 0.8, "tp": 0.5})
    report = plan_offload(app, UserTarget(), policy="power",
                          **_plan_kwargs(_dp_tp_registry()))
    for r in report.records:
        if r.correct and r.best_time_s < float("inf"):
            assert r.energy_j is not None
            assert r.energy_info["source"] == "host-time"
    assert report.selected.destination == "sharded_tp"
    assert report.selected.energy_j == pytest.approx(GPU_T4.peak_w * 0.5)


# ------------------------------------------------- selection constraints
def _record(dest, time_s, *, watts=None, energy=None, correct=True):
    return VerificationRecord(
        order=1, destination=dest, paper_analogue=dest, method="loop",
        best_time_s=time_s, improvement=1.0, price=1.0, n_measurements=1,
        verify_elapsed_s=0.0, met_target=False, correct=correct,
        energy_j=energy, avg_watts=watts)


def test_power_budget_excludes_over_budget_destination():
    records = [
        _record("fast_hot", 0.5, watts=105.0, energy=52.5),
        _record("slow_cool", 0.8, watts=70.0, energy=56.0),
    ]
    host = get_policy("host-time")
    assert host.select(records).destination == "fast_hot"
    within = host.select(records, power_budget_w=80.0)
    assert within.destination == "slow_cool"
    # nothing fits an impossible budget
    assert host.select(records, power_budget_w=10.0) is None
    # a record with no modeled draw cannot prove it fits
    records.append(_record("unknown_draw", 0.1))
    assert host.select(records,
                       power_budget_w=80.0).destination == "slow_cool"


def test_power_budget_never_selects_incorrect_record():
    records = [
        _record("wrong_but_cool", 0.1, watts=5.0, energy=0.5,
                correct=False),
        _record("right", 0.8, watts=70.0, energy=56.0),
    ]
    for pol_name in ("host-time", "power", "edp"):
        sel = get_policy(pol_name).select(records, power_budget_w=80.0)
        assert sel.destination == "right"
    assert get_policy("power").select(records,
                                      power_budget_w=50.0) is None


def test_uncharged_record_scores_in_joules_not_seconds():
    """A record nothing charged (produced outside plan_offload) must not
    outrank charged records through a unit mismatch: the fallback is the
    generic envelope at peak over its time — joules, like everyone else."""
    records = [
        _record("charged", 0.5, watts=70.0, energy=35.0),
        _record("uncharged", 0.4),          # energy_j is None
    ]
    power = get_policy("power")
    assert power.score(records[1]) == pytest.approx(GENERIC.peak_w * 0.4)
    # generic-peak 150 W x 0.4 s = 60 J > 35 J -> the modeled record wins
    assert power.select(records).destination == "charged"
    edp = get_policy("edp")
    assert edp.score(records[1]) == pytest.approx(
        GENERIC.peak_w * 0.4 * 0.4)
    assert edp.select(records).destination == "charged"
    # cell scoring keeps the same unit rule when a cell has no energy
    # block — scaled by the cell's price (chip count), so an unmodelled
    # big slice cannot under-score a modeled one
    assert power.score_cell(0.4, price=8.0) == pytest.approx(
        GENERIC.peak_w * 0.4 * 8.0)
    assert edp.score_cell(0.4, price=8.0) == pytest.approx(
        GENERIC.peak_w * 0.16 * 8.0)


def test_custom_policy_with_legacy_select_signature_still_works():
    """A registered policy that overrode select(records) before the
    constraint kwargs existed must keep working for unconstrained calls."""
    class Legacy(SelectionPolicy):
        name = "test-legacy-select"

        def score_parts(self, time_s, price=1.0, modeled_s=None):
            return time_s

        def select(self, records):        # pre-constraint signature
            done = [r for r in records if r.correct]
            return min(done, key=self.score) if done else None

    app = _scripted_app({"seq": 1.0, "dp": 0.8, "tp": 0.5})
    report = plan_offload(app, UserTarget(), policy=Legacy(),
                          **_plan_kwargs(_dp_tp_registry()))
    assert report.selected.destination == "sharded_tp"
    with pytest.raises(TypeError):
        plan_offload(app, UserTarget(), policy=Legacy(),
                     power_budget_w=80.0,
                     **_plan_kwargs(_dp_tp_registry()))


def test_max_slowdown_bounds_the_energy_choice():
    """The follow-up's "power saving within allowed slowdown": the lowest-
    energy destination is only eligible while it stays within the factor
    of the fastest correct one."""
    records = [
        _record("fast_hot", 0.5, watts=105.0, energy=52.5),
        _record("slow_cool", 0.8, watts=50.0, energy=40.0),
    ]
    power = get_policy("power")
    assert power.select(records).destination == "slow_cool"
    # 0.8 > 1.3 x 0.5 -> the cool one is out of the allowed slowdown
    assert power.select(records,
                        max_slowdown=1.3).destination == "fast_hot"
    assert power.select(records,
                        max_slowdown=2.0).destination == "slow_cool"


def test_plan_offload_threads_constraints_through():
    app = _scripted_app({"seq": 1.0, "dp": 0.5, "tp": 0.8})
    # host-time would pick dp (0.5 s) but its Xeon envelope draws 105 W
    report = plan_offload(app, UserTarget(), policy="power",
                          power_budget_w=80.0,
                          **_plan_kwargs(_dp_tp_registry()))
    assert report.selected.destination == "sharded_tp"
    assert report.selected.avg_watts <= 80.0
    # within an allowed slowdown of 1.3 the cheap-but-slow tp (0.8 s) is
    # ineligible, so the fastest correct destination keeps winning
    report2 = plan_offload(app, UserTarget(), policy="power",
                           max_slowdown=1.3,
                           **_plan_kwargs(_dp_tp_registry()))
    assert report2.selected.destination == "xla_dp"


# -------------------------------------- function-blocks-only backend
def test_library_backend_slots_into_fb_phase_only():
    reg = registry_with_library_backend()
    order = reg.verification_order()
    # the default registry is untouched and the new registry has 4 backends
    assert len(DEFAULT_REGISTRY) == 3
    assert len(reg) == 4
    assert [(b.key, m) for b, m in order] == [
        ("dp", "function_block"),
        ("fb_gpu_lib", "function_block"),     # verify_time 1.2 slots here
        ("tp", "function_block"),
        ("pallas", "function_block"),
        ("dp", "loop"), ("tp", "loop"), ("pallas", "loop"),
    ]
    assert ("fb_gpu_lib", "loop") not in [(b.key, m) for b, m in order]
    assert GPU_LIBRARY.methods == ("function_block",)
    # forcing a loop search on it is a programming error, not a silent skip
    app = _scripted_app({"seq": 1.0})
    from repro.backends import SearchContext
    ctx = SearchContext(runner=ScriptedRunner(), inputs={}, ref_out=None)
    with pytest.raises(NotImplementedError):
        GPU_LIBRARY.search(app, ctx, method="loop")


def test_library_backend_offloads_via_function_block_db():
    """End-to-end: the FB-only backend wins when the DB has a library
    implementation for it — one extra FB verification, no loop one."""
    fb_db = Registry()
    fb_db.register(FunctionBlockEntry(
        name="stagekernel", match_names=("stage",),
        ref_fn=lambda s: s, example_args=lambda: ({},),
        impls={"fb_gpu_lib": _stage(0.1)}))
    # a library card with its own (cheaper) envelope: the loop searches all
    # re-measure the pinned FB pattern (residual rule, one nest), so the
    # envelope is what strictly separates the library from the tp loop
    lib_env = PowerEnvelope("lib-card", idle_w=5.0, peak_w=40.0)
    fb_only = Backend(key="fb_gpu_lib", name="gpu_fb_library",
                      paper_analogue="GPU library", price=1.0,
                      verify_time=1.2, methods=("function_block",),
                      power=lib_env)
    reg = _dp_tp_registry()
    reg.register(fb_only)

    app = _scripted_app({"seq": 1.0, "dp": 0.8, "tp": 0.5})
    report = plan_offload(app, UserTarget(), policy="power",
                          runner=ScriptedRunner(),
                          ga_cfg=GAConfig(population=2, generations=2),
                          registry=fb_db, backends=reg)
    # 3 FB verifications (dp, fb_lib, tp) + 2 loop verifications (dp, tp)
    assert [(r.destination, r.method) for r in report.records] == [
        ("xla_dp", "function_block"),
        ("gpu_fb_library", "function_block"),
        ("sharded_tp", "function_block"),
        ("xla_dp", "loop"), ("sharded_tp", "loop"),
    ]
    fb_rec = report.records[1]
    assert fb_rec.correct and fb_rec.best_time_s == pytest.approx(0.1)
    # fastest AND cheapest: 0.1 s x 40 W beats everything
    assert report.selected is fb_rec
    assert report.selected.energy_j == pytest.approx(40.0 * 0.1)


# -------------------------------------------- fleet draw aggregation (PR 8)
def test_envelope_addition_sums_draws_and_mixes_memory_fraction():
    a = PowerEnvelope("a", idle_w=10.0, peak_w=110.0,
                      memory_w_fraction=0.2)
    b = PowerEnvelope("b", idle_w=20.0, peak_w=320.0,
                      memory_w_fraction=0.4)
    c = a + b
    assert c.idle_w == pytest.approx(30.0)
    assert c.peak_w == pytest.approx(430.0)
    # active-weighted mix: (100*0.2 + 300*0.4) / 400
    assert c.memory_w_fraction == pytest.approx(0.35)
    assert c.name == "a+b"
    # sum() works via __radd__, and the operation is associative enough
    # for fleet aggregation
    total = sum([a, b, a])
    assert total.peak_w == pytest.approx(540.0)
    assert total.idle_w == pytest.approx(40.0)
    with pytest.raises(TypeError):
        a + 3.0


def test_fleet_draw_w_is_the_shared_summation():
    from repro.power import fleet_draw_w
    assert fleet_draw_w([10.0, 20.0, 30.0]) == pytest.approx(60.0)
    assert fleet_draw_w([]) == 0.0
    # an unmodeled draw contributes nothing (callers drop unmodeled
    # candidates at ranking time; the sum itself stays total-only)
    assert fleet_draw_w([10.0, None, 5.0]) == pytest.approx(15.0)
