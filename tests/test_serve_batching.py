"""repro.serve.batching: continuous batching parity + fixed-shape pool.

Pins the engine contract: greedy continuous-batched decode is
token-identical to the sequential ``generate`` reference for the same
request set — including requests that join mid-flight, finish early, and
recycle slots — and the jitted decode step / insert trace exactly once per
engine no matter how many requests flow through.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models.lm import Model
from repro.serve import ContinuousBatcher, Request

ARCH = "granite-3-2b"


def make_model(arch=ARCH, seed=0):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def prompts(cfg, n, prompt_len, seed=1):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, prompt_len), 0, cfg.vocab_size),
        dtype=np.int32)


def sequential_reference(model, params, toks, prompt_len, gen, cache_len):
    """Per-request batch-1 greedy decode through the public reference."""
    out = {}
    for i in range(toks.shape[0]):
        ref = generate(model, params, {"tokens": toks[i:i + 1]},
                       prompt_len=prompt_len, gen=gen, cache_len=cache_len)
        out[f"r{i}"] = np.asarray(ref)[0]
    return out


def test_parity_with_midflight_joins_and_early_finishes():
    """The satellite pin: staggered arrivals (requests join while others
    decode), heterogeneous max_gen (early finishers free slots mid-run),
    and more requests than slots (slot recycling) — token-identical to the
    sequential reference throughout."""
    cfg, model, params = make_model()
    prompt_len, cache_len = 8, 32
    gens = [6, 3, 9, 4, 7]                       # early finishes + stragglers
    toks = prompts(cfg, len(gens), prompt_len)
    engine = ContinuousBatcher(model, params, n_slots=2,
                               cache_len=cache_len)
    reqs = [Request(rid=f"r{i}", arch=cfg.name, prompt_len=prompt_len,
                    max_gen=gens[i], tokens=toks[i],
                    arrival_s=i * 1.5 * engine.tick_s)
            for i in range(len(gens))]
    out = engine.run(reqs)

    for i, g in enumerate(gens):
        ref = np.asarray(generate(
            model, params, {"tokens": toks[i:i + 1]},
            prompt_len=prompt_len, gen=g, cache_len=cache_len))[0]
        assert np.array_equal(out[f"r{i}"], ref), f"r{i}"
        assert out[f"r{i}"].shape == (g,)
    assert engine.metrics.summary()["completed"] == len(gens)


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "recurrentgemma-2b"])
def test_parity_holds_for_recurrent_families(arch):
    """ssm/hybrid recurrent state survives the slot pool: exact-length
    prefill + wholesale slot insert keep the state identical to the
    sequential path (right-padding would corrupt it)."""
    cfg, model, params = make_model(arch)
    toks = prompts(cfg, 3, 8)
    engine = ContinuousBatcher(model, params, n_slots=2, cache_len=16)
    reqs = [Request(rid=f"r{i}", arch=cfg.name, prompt_len=8, max_gen=5,
                    tokens=toks[i], arrival_s=i * engine.tick_s)
            for i in range(3)]
    out = engine.run(reqs)
    ref = sequential_reference(model, params, toks, 8, 5, 16)
    for rid in ref:
        assert np.array_equal(out[rid], ref[rid]), rid


def test_decode_step_traces_exactly_once():
    """Fixed-shape slot pool: the jitted step and the jitted insert are
    traced once per engine; a full run over joins/leaves/recycles adds no
    retrace, and prefill traces once per unique prompt length."""
    cfg, model, params = make_model()
    engine = ContinuousBatcher(model, params, n_slots=2, cache_len=32)
    toks8 = prompts(cfg, 4, 8)
    toks5 = prompts(cfg, 2, 5, seed=2)
    reqs = [Request(rid=f"a{i}", arch=cfg.name, prompt_len=8, max_gen=4,
                    tokens=toks8[i], arrival_s=i * engine.tick_s)
            for i in range(4)]
    reqs += [Request(rid=f"b{i}", arch=cfg.name, prompt_len=5, max_gen=3,
                     tokens=toks5[i], arrival_s=i * engine.tick_s)
             for i in range(2)]
    engine.run(reqs)
    assert engine.traces["decode_step"] == 1
    assert engine.traces["insert"] == 1
    assert engine.traces["prefill"] == 2         # one per unique length
    # a second wave through the same engine re-traces nothing
    more = [Request(rid=f"c{i}", arch=cfg.name, prompt_len=8, max_gen=4,
                    tokens=toks8[i]) for i in range(2)]
    engine.run(more)
    assert engine.traces == {"decode_step": 1, "insert": 1, "prefill": 2}


def test_metrics_ttft_energy_and_arrival_gating():
    from repro.power import GENERIC
    cfg, model, params = make_model()
    engine = ContinuousBatcher(model, params, n_slots=2, cache_len=32,
                               envelope=GENERIC)
    toks = prompts(cfg, 3, 8)
    # r2 arrives much later: its TTFT starts at its own arrival, and the
    # engine must not admit it early
    reqs = [Request(rid=f"r{i}", arch=cfg.name, prompt_len=8, max_gen=4,
                    tokens=toks[i],
                    arrival_s=[0.0, 0.0, 20 * engine.tick_s][i])
            for i in range(3)]
    engine.run(reqs)
    s = engine.metrics.summary()
    assert s["completed"] == 3 and s["rejected"] == 0
    assert s["tokens"] == 12
    assert s["ttft_p50_s"] is not None and s["ttft_p50_s"] > 0
    assert s["total_energy_j"] > 0 and s["joules_per_request"] > 0
    m2 = engine.metrics.requests["r2"]
    assert m2.admit_s >= 20 * engine.tick_s
    # per-request energy shares sum to the total charged on live ticks
    per_req = sum(m.energy_j for m in engine.metrics.requests.values())
    assert per_req <= s["total_energy_j"] + 1e-9


def test_eos_stops_a_request_early():
    cfg, model, params = make_model()
    toks = prompts(cfg, 1, 8)
    base = ContinuousBatcher(model, params, n_slots=1, cache_len=32)
    full = base.run([Request(rid="r0", arch=cfg.name, prompt_len=8,
                             max_gen=8, tokens=toks[0])])["r0"]
    # pick a mid-stream token whose first occurrence is that position, so
    # the stop point is unambiguous (greedy decode may repeat tokens)
    k = next(i for i in range(1, len(full))
             if int(full[i]) not in [int(t) for t in full[:i]])
    eos = int(full[k])
    engine = ContinuousBatcher(model, params, n_slots=1, cache_len=32,
                               eos_id=eos)
    out = engine.run([Request(rid="r0", arch=cfg.name, prompt_len=8,
                              max_gen=8, tokens=toks[0])])["r0"]
    assert len(out) == k + 1 and out[-1] == eos
    assert np.array_equal(out, full[:k + 1])


def test_engine_rejects_wrong_arch_and_bad_tokens():
    cfg, model, params = make_model()
    engine = ContinuousBatcher(model, params, n_slots=1, cache_len=32)
    with pytest.raises(ValueError, match="arch"):
        engine.submit(Request(rid="x", arch="other-arch", prompt_len=8,
                              max_gen=2))
    with pytest.raises(ValueError, match="prompt_len"):
        engine.run([Request(rid="y", arch=cfg.name, prompt_len=8,
                            max_gen=2, tokens=np.zeros(4, np.int32))])
    with pytest.raises(ValueError):
        Request(rid="z", arch=cfg.name, prompt_len=0, max_gen=2)


def test_generate_reference_does_not_retrace_across_calls():
    """Satellite pin for the launch.serve fix: repeated generate() calls
    reuse one jitted prefill/step pair instead of re-tracing per call."""
    cfg, model, params = make_model()
    toks = prompts(cfg, 2, 8)
    batch = {"tokens": toks[0:1]}
    generate(model, params, batch, prompt_len=8, gen=3, cache_len=32)
    from repro.launch.serve import _jits_for
    prefill, step = _jits_for(model, 32)
    # the memoized pair is stable and its jax cache shows exactly the
    # warm-up traces — further calls add none
    n0 = prefill._cache_size() + step._cache_size()
    generate(model, params, {"tokens": toks[1:2]}, prompt_len=8, gen=3,
             cache_len=32)
    generate(model, params, batch, prompt_len=8, gen=5, cache_len=32)
    assert (prefill, step) == _jits_for(model, 32)
    assert prefill._cache_size() + step._cache_size() == n0
