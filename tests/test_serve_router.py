"""repro.serve.router + repro.core.plan_lookup: the search/lookup split.

Pins the tentpole contract: after warm-up, routing any number of requests
performs zero traces and zero XLA compiles — the hot path is dict lookup +
roofline arithmetic.  ``CacheStats.misses`` is the compile counter (every
fresh compile or failure memo increments it; nothing else does), and the
tests additionally poison ``jax.jit`` so any trace attempt on the routing
path raises.
"""
import time

import pytest

from repro.backends.builtin import GPU, MANY_CORE
from repro.configs import get_config
from repro.core.plan_lookup import (PlanLookup, analysis_from_roofline,
                                    analysis_from_time, publish_record,
                                    serve_key)
from repro.serve import Endpoint, Request, Router

ARCH = "granite-3-2b"


def make_endpoints(cfg, *, n_slots=2, cache_len=64):
    gpu = Endpoint(name="gpu0", backend=GPU, arch=cfg.name,
                   n_slots=n_slots, cache_len=cache_len, cfg=cfg)
    mc = Endpoint(name="mc0", backend=MANY_CORE, arch=cfg.name,
                  n_slots=n_slots, cache_len=cache_len, cfg=cfg)
    return gpu, mc


def warm(lookup, gpu, mc, *, gpu_collective=0.0):
    # gpu: lighter compute => faster modeled step; mc: 50x the flops
    lookup.register(gpu.lookup_key(),
                    {"flops": 1e9, "bytes": 1e6,
                     "collective_bytes": gpu_collective})
    lookup.register(mc.lookup_key(),
                    {"flops": 5e10, "bytes": 1e6, "collective_bytes": 0.0})


def req(rid, *, prompt_len=8, max_gen=4, **kw):
    return Request(rid=rid, arch=ARCH, prompt_len=prompt_len,
                   max_gen=max_gen, **kw)


# ------------------------------------------------------------ plan lookup
def test_serve_key_distinguishes_backend_arch_and_plan():
    from repro.dist.plan import Plan, SERVE_LOW_MEM
    a = serve_key("gpu", "m1")
    assert a == serve_key("gpu", "m1")
    assert a != serve_key("cpu", "m1") and a != serve_key("gpu", "m2")
    assert serve_key("gpu", "m1", Plan()) != \
        serve_key("gpu", "m1", SERVE_LOW_MEM)
    # model-only genes don't split serving identities (structural_key)
    import dataclasses
    sched = dataclasses.replace(Plan(), pipeline_schedule="1f1b")
    assert serve_key("gpu", "m1", Plan()) == serve_key("gpu", "m1", sched)


def test_analysis_roundtrips_roofline_and_host_time():
    from repro.core.cost_model import roofline_from_analysis
    src = {"flops": 2e9, "bytes": 3e6, "collective_bytes": 4e5}
    rl = roofline_from_analysis(src, n_chips=1)
    back = analysis_from_roofline(rl.to_dict())
    assert back == pytest.approx(src)
    assert analysis_from_roofline({}) is None
    # host-time fallback reproduces the measured seconds when scored
    an = analysis_from_time(0.25)
    rl2 = roofline_from_analysis(an, n_chips=1)
    assert rl2.step_time_s == pytest.approx(0.25)
    assert analysis_from_time(float("inf")) is None


def test_lookup_score_and_failure_refusal():
    lk = PlanLookup()
    key = serve_key("gpu", ARCH)
    assert lk.score(key) is None                 # cold
    lk.register(key, {"flops": 1e9, "bytes": 1e6, "collective_bytes": 0.0})
    ev = lk.score(key)
    assert ev is not None and ev.correct and ev.time_s > 0
    # a later failure supersedes the success — never dispatched to again
    lk.register_failure(key, "wrong result")
    assert lk.score(key) is None
    assert not lk.usable(lk.lookup(key))


def test_publish_record_rules():
    class Rec:
        correct = True
        best_time_s = 0.01
        verify_elapsed_s = 1.0
        note = ""
        mesh_info = {}
    lk = PlanLookup()
    assert publish_record(lk, Rec(), GPU, "app")
    ev = lk.score(serve_key(GPU.name, "app"))
    assert ev.correct and ev.time_s == pytest.approx(0.01)
    # an incorrect record must NOT clobber the success from another
    # verification method of the same backend...
    bad = Rec()
    bad.correct = False
    bad.note = "result mismatch"
    assert not publish_record(lk, bad, GPU, "app")
    assert lk.score(serve_key(GPU.name, "app")) is not None
    # ...but on a cold key it is a recorded refusal
    assert publish_record(lk, bad, MANY_CORE, "app")
    assert lk.score(serve_key(MANY_CORE.name, "app")) is None


# ----------------------------------------------------------- hot routing
def test_hot_path_zero_traces_zero_compiles_after_warmup(monkeypatch):
    """The acceptance pin: after warm-up, routing N requests moves only
    ``lookups`` — ``misses`` (the compile counter) stays flat, and any
    attempt to trace on the path raises via the jax.jit poison."""
    cfg = get_config(ARCH).reduced()
    lk = PlanLookup()
    gpu, mc = make_endpoints(cfg)
    warm(lk, gpu, mc)
    router = Router([gpu, mc], lk, policy="modeled")
    router.route(req("warmup"))                  # exercise every code path

    import jax

    def poisoned(*a, **kw):
        raise AssertionError("hot routing path attempted a jax trace")

    monkeypatch.setattr(jax, "jit", poisoned)
    monkeypatch.setattr(jax, "vmap", poisoned)

    misses0 = lk.stats.misses
    lookups0 = lk.stats.lookups
    t0 = time.perf_counter()
    n = 200
    for i in range(n):
        d = router.route(req(f"q{i}"))
        assert d.accepted and d.endpoint.name == "gpu0"
    elapsed = time.perf_counter() - t0
    assert lk.stats.misses == misses0            # zero compiles
    assert lk.stats.lookups >= lookups0 + n      # the hot reads happened
    # sub-ms per route on any plausible host (generous 5x headroom)
    assert elapsed / n < 5e-3, f"{elapsed / n * 1e3:.2f} ms per route"


def test_policy_ranked_dispatch_flips_on_comm_bound_request():
    """Satellite pin: under the modeled policy the compute-light gpu wins,
    until its warm analysis shows a dominant collective — then the router
    flips to the many-core endpoint for the same request."""
    cfg = get_config(ARCH).reduced()
    lk = PlanLookup()
    gpu, mc = make_endpoints(cfg)
    warm(lk, gpu, mc)
    router = Router([gpu, mc], lk, policy="modeled")
    assert router.route(req("a")).endpoint.name == "gpu0"
    # re-warm gpu as comm-bound: collective term dwarfs mc's compute
    warm(lk, gpu, mc, gpu_collective=1e12)
    assert router.route(req("b")).endpoint.name == "mc0"


def test_power_budget_admission_rejects_when_fleet_saturated():
    cfg = get_config(ARCH).reduced()
    lk = PlanLookup()
    gpu, mc = make_endpoints(cfg, n_slots=8)
    warm(lk, gpu, mc)
    probe = Router([gpu, mc], lk, policy="modeled").route(req("probe"))
    assert probe.avg_watts is not None and probe.avg_watts > 0
    gpu.in_flight = mc.in_flight = 0
    # budget fits exactly two in-flight requests' draw
    budget = probe.avg_watts * 2.5
    router = Router([gpu, mc], lk, policy="modeled",
                    power_budget_w=budget)
    d1 = router.route(req("r1"))
    router.dispatch(d1)
    d2 = router.route(req("r2"))
    router.dispatch(d2)
    d3 = router.route(req("r3"))
    assert not d3.accepted and d3.reason == "power budget saturated"
    assert router.metrics.rejected == 1
    # completing one frees draw: admission recovers
    router.complete(d1)
    assert router.route(req("r4")).accepted


def test_double_complete_cannot_drive_accounting_negative():
    """Satellite pin: the admission ledger releases exactly what dispatch
    charged, once — double complete, completing a rejected decision, or
    completing a routed-but-never-dispatched decision are all no-ops, and
    double dispatch of one request is refused."""
    cfg = get_config(ARCH).reduced()
    lk = PlanLookup()
    gpu, mc = make_endpoints(cfg)
    warm(lk, gpu, mc)
    router = Router([gpu, mc], lk, policy="modeled")
    d = router.route(req("r1"))
    assert d.accepted and d.avg_watts > 0
    # routed but not dispatched: complete is a no-op
    assert not router.complete(d)
    assert router.fleet_draw_w == 0.0 and gpu.in_flight == 0
    router.dispatch(d)
    assert gpu.in_flight == 1
    assert router.fleet_draw_w == pytest.approx(d.avg_watts)
    with pytest.raises(ValueError):
        router.dispatch(d)                           # double dispatch
    assert router.complete(d)                        # the one real release
    assert gpu.in_flight == 0 and router.fleet_draw_w == 0.0
    assert not router.complete(d)                    # double complete
    assert not router.complete(d)
    assert gpu.in_flight == 0 and router.fleet_draw_w == 0.0
    # a rejected decision never touches the ledger
    rejected = router.route(req("slo", deadline_s=1e-12))
    assert not rejected.accepted
    assert not router.complete(rejected)
    assert router.fleet_draw_w == 0.0


def test_removed_endpoint_ledger_entries_stay_completable():
    """Satellite pin (dangling-ledger fix): removing an endpoint with
    requests in flight must keep their ledger entries completable — draw
    and slots release on ``complete`` exactly as if it were live, never
    orphaned — and the draw entry drops only once fully drained."""
    cfg = get_config(ARCH).reduced()
    lk = PlanLookup()
    gpu, mc = make_endpoints(cfg)
    warm(lk, gpu, mc)
    router = Router([gpu, mc], lk, policy="modeled")
    d1, d2 = router.route(req("r1")), None
    router.dispatch(d1)
    d2 = router.route(req("r2"))
    router.dispatch(d2)
    assert d1.endpoint.name == d2.endpoint.name == "gpu0"
    draw_full = router.fleet_draw_w
    assert draw_full == pytest.approx(d1.avg_watts + d2.avg_watts)
    router.remove_endpoint("gpu0")
    assert router.endpoint("gpu0") is None       # out of routing
    assert router.route(req("r3")).endpoint.name == "mc0"
    assert router.in_flight_of("gpu0") == 2      # ledger survives removal
    assert router.fleet_draw_w == pytest.approx(draw_full)
    assert router.complete(d1)                   # completable, not orphaned
    assert router.fleet_draw_w == pytest.approx(d2.avg_watts)
    assert not router.drained("gpu0")
    assert router.complete(d2)
    assert router.drained("gpu0")
    assert router.fleet_draw_w == 0.0            # books fully closed
    assert not router.complete(d1)               # idempotent after removal
    # re-admission after a full drain is legal again
    router.add_endpoint(Endpoint(name="gpu0", backend=GPU, arch=cfg.name,
                                 n_slots=2, cache_len=64, cfg=cfg))
    assert router.route(req("r4")).endpoint.name == "gpu0"


def test_drain_stops_dispatch_but_in_flight_completes():
    """Satellite pin: drain is the migration primitive — no new
    dispatches, in-flight requests keep their slots, removal only after
    ``drained`` reports the ledger empty."""
    cfg = get_config(ARCH).reduced()
    lk = PlanLookup()
    gpu, mc = make_endpoints(cfg)
    warm(lk, gpu, mc)
    router = Router([gpu, mc], lk, policy="modeled")
    d = router.route(req("r1"))
    router.dispatch(d)
    assert d.endpoint.name == "gpu0"
    router.drain("gpu0")
    assert router.route(req("r2")).endpoint.name == "mc0"
    assert not router.drained("gpu0")
    assert router.complete(d, latency_s=0.01)
    assert router.drained("gpu0") and gpu.in_flight == 0
    with pytest.raises(ValueError):
        router.drain("nope")


def test_quarantine_with_in_flight_requests_drains_cleanly():
    """Quarantine mid-flight: no new dispatches, but the admitted request
    still completes through the ledger and feeds the health machine."""
    cfg = get_config(ARCH).reduced()
    lk = PlanLookup()
    gpu, mc = make_endpoints(cfg)
    warm(lk, gpu, mc)
    router = Router([gpu, mc], lk, policy="modeled")
    d = router.route(req("r1"))
    router.dispatch(d)
    router.health["gpu0"].quarantine("operator")
    assert router.route(req("r2")).endpoint.name == "mc0"
    assert router.complete(d, latency_s=0.01)
    assert router.fleet_draw_w == 0.0 and gpu.in_flight == 0


def test_failure_reports_open_the_circuit_and_requests_shift():
    """Router-level circuit breaking: consecutive ``fail`` reports
    quarantine the endpoint; traffic shifts to the survivor and the
    refusal reason is specific once nothing is left."""
    from repro.serve import HealthConfig
    cfg = get_config(ARCH).reduced()
    lk = PlanLookup()
    gpu, mc = make_endpoints(cfg)
    warm(lk, gpu, mc)
    router = Router([gpu, mc], lk, policy="modeled",
                    health_cfg=HealthConfig(error_threshold=2))
    for _ in range(2):
        d = router.route(req("r"))
        assert d.endpoint.name == "gpu0"
        router.dispatch(d)
        assert router.fail(d, reason="endpoint died")
    assert router.health["gpu0"].state == "quarantined"
    d = router.route(req("shift"))
    assert d.accepted and d.endpoint.name == "mc0"
    router.health["mc0"].quarantine("chaos")
    refused = router.route(req("none"))
    assert not refused.accepted
    assert refused.reason == "endpoint quarantined"


def test_incorrect_record_backend_is_never_dispatched_to():
    cfg = get_config(ARCH).reduced()
    lk = PlanLookup()
    gpu, mc = make_endpoints(cfg)
    warm(lk, gpu, mc)
    lk.register_failure(gpu.lookup_key(), "wrong result")
    router = Router([gpu, mc], lk, policy="modeled")
    for i in range(20):
        d = router.route(req(f"q{i}"))
        assert d.accepted and d.endpoint.name == "mc0"
    lk.register_failure(mc.lookup_key(), "wrong result")
    d = router.route(req("last"))
    assert not d.accepted and d.reason == "no feasible endpoint"


def test_static_lint_prunes_endpoint_before_scoring():
    """PR-6 contract at serve time: a request the endpoint's cache cannot
    host is pruned by arithmetic (stats.static_pruned), not discovered by
    a failed prefill."""
    cfg = get_config(ARCH).reduced()             # full attention
    lk = PlanLookup()
    gpu, mc = make_endpoints(cfg, cache_len=64)
    warm(lk, gpu, mc)
    router = Router([gpu, mc], lk, policy="modeled")
    pruned0 = lk.stats.static_pruned
    d = router.route(req("big", prompt_len=60, max_gen=20))
    assert not d.accepted and d.reason == "no feasible endpoint"
    assert lk.stats.static_pruned == pruned0 + 2
    assert router.route(req("ok")).accepted      # small requests unaffected


def test_slo_deadline_and_slot_fallthrough():
    cfg = get_config(ARCH).reduced()
    lk = PlanLookup()
    gpu, mc = make_endpoints(cfg, n_slots=1)
    warm(lk, gpu, mc)
    router = Router([gpu, mc], lk, policy="modeled")
    # impossible SLO: rejected up front
    d = router.route(req("slo", deadline_s=1e-12))
    assert not d.accepted and d.reason == "SLO infeasible"
    # best endpoint full: ranked fallthrough to the next one
    d1 = router.route(req("a"))
    assert d1.endpoint.name == "gpu0"
    router.dispatch(d1)
    d2 = router.route(req("b"))
    assert d2.accepted and d2.endpoint.name == "mc0"
    router.dispatch(d2)
    d3 = router.route(req("c"))
    assert not d3.accepted and d3.reason == "all slots busy"


def test_planner_publish_feeds_router_end_to_end():
    """plan_offload(publish=...) warms the lookup the router consumes: the
    offline search is the write side, routing is the read side."""
    from repro.apps import APPS
    from repro.core.ga import GAConfig
    from repro.core.measure import TimedRunner
    from repro.core.planner import UserTarget, plan_offload

    app = APPS["tdFIR"]()
    inputs = app.make_inputs(0, small=True)
    lk = PlanLookup()
    report = plan_offload(app, UserTarget(), inputs=inputs,
                          runner=TimedRunner(repeats=1),
                          ga_cfg=GAConfig(population=3, generations=3,
                                          seed=0),
                          publish=lk)
    assert report.selected is not None
    warm_dests = [r.destination for r in report.records
                  if lk.score(serve_key(r.destination, app.name))
                  is not None]
    assert warm_dests                            # something is serveable
    # and scoring them is compile-free from here on
    misses0 = lk.stats.misses
    for dest in warm_dests:
        ev = lk.score(serve_key(dest, app.name))
        assert ev.correct and ev.time_s > 0
    assert lk.stats.misses == misses0
