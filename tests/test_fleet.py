"""repro.fleet: placement of N apps over a shared pool from warm state.

Pins the subsystem contract: planning is zero-compile (jit-poisoned, like
the router's hot path), a published verification failure is never placed
on, capacity (slots / memory / power cap) is enforced, the GA never does
worse than its greedy seed, and replan keeps unaffected apps pinned.
"""
import pytest

from repro.core.cost_model import PEAK_FLOPS
from repro.core.ga import Evaluation, GAConfig, run_ga
from repro.core.plan_lookup import PlanLookup, serve_key
from repro.fleet import (FleetApp, FleetPlanner, PoolBackend, round_robin)
from repro.power import PowerEnvelope


class FakeBackend:
    def __init__(self, name, price=1.0, power=None):
        self.name = name
        self.price = price
        self.paper_analogue = ""
        self.power = power


HOT = PowerEnvelope("hot", idle_w=100.0, peak_w=200.0)
COOL = PowerEnvelope("cool", idle_w=5.0, peak_w=10.0)


def warm_time(lookup, backend_name, arch, t):
    """Payload whose roofline step time is exactly ``t`` (compute-bound)."""
    lookup.register(serve_key(backend_name, arch),
                    {"flops": t * PEAK_FLOPS, "bytes": 0.0,
                     "collective_bytes": 0.0})


def make_world(*, hot_t=0.1, cool_t=0.2, n_apps=4, load_rps=1.0,
               slots=8.0, power_budget_w=None, policy=None):
    """Two-backend pool (fast+hot vs slow+cool), every pair warm."""
    lookup = PlanLookup()
    pool = [
        PoolBackend(name="hot", backend=FakeBackend("hot", power=HOT),
                    slots=slots),
        PoolBackend(name="cool", backend=FakeBackend("cool", power=COOL),
                    slots=slots),
    ]
    apps = [FleetApp(name=f"a{i}", arch=f"m{i}", load_rps=load_rps,
                     tokens_per_request=1.0) for i in range(n_apps)]
    for app in apps:
        warm_time(lookup, "hot", app.arch, hot_t)
        warm_time(lookup, "cool", app.arch, cool_t)
    planner = FleetPlanner(pool, lookup, policy=policy,
                           power_budget_w=power_budget_w,
                           ga_cfg=GAConfig(population=6, generations=6,
                                           seed=0,
                                           cardinalities=[2] * n_apps))
    return planner, apps, lookup


# --------------------------------------------------------- zero-compile pin
def test_fleet_planning_is_zero_compile(monkeypatch):
    """The acceptance pin: planning N apps over warm PlanLookup entries
    performs no traces/compiles — only ``lookups`` moves."""
    planner, apps, lookup = make_world()
    import jax

    def poisoned(*a, **kw):
        raise AssertionError("fleet planning attempted a jax trace")

    monkeypatch.setattr(jax, "jit", poisoned)
    monkeypatch.setattr(jax, "vmap", poisoned)
    misses0 = lookup.stats.misses
    lookups0 = lookup.stats.lookups
    placement = planner.plan(apps)
    assert placement.feasible
    assert lookup.stats.misses == misses0            # zero compiles
    assert lookup.stats.lookups > lookups0           # warm reads happened


# ----------------------------------------------------------- basic behavior
def test_host_time_policy_packs_everything_on_the_fast_backend():
    planner, apps, _ = make_world(hot_t=0.1, cool_t=0.2)
    placement = planner.plan(apps)
    assert placement.feasible
    assert all(b == "hot" for b in placement.by_app.values())
    # load-weighted service sum: 4 apps x 1 rps x 0.1 s
    assert placement.objective == pytest.approx(0.4, rel=1e-3)


def test_published_failure_verdict_is_never_placed_on():
    planner, apps, lookup = make_world()
    lookup.register_failure(serve_key("hot", apps[0].arch), "wrong result")
    planner._cand_cache.clear()
    placement = planner.plan(apps)
    assert placement.feasible
    assert placement.by_app["a0"] == "cool"          # refused, not retried
    # forcing the failed pair is recorded as a violation, never silent
    forced = planner.evaluate(apps, tuple([0] * len(apps)))
    assert not forced.feasible
    assert any("published failure" in v or "no warm verified plan" in v
               for v in forced.violations)


def test_cold_pair_is_infeasible_not_compiled():
    """An app nothing ever verified anywhere cannot be placed."""
    planner, apps, lookup = make_world()
    stranger = FleetApp(name="x", arch="unseen", tokens_per_request=1.0)
    placement = planner.plan(list(apps) + [stranger])
    assert not placement.feasible
    assert any("x:" in v for v in placement.violations)


def test_power_cap_moves_load_to_the_cool_backend():
    """Under a fleet power cap the fast backend's draw no longer fits:
    the planner degrades to the slow cool destination instead of
    breaching the budget."""
    # load 10 rps x 0.1 s = utilization 1.0 -> the hot backend draws its
    # full modeled watts (~200 W); the cool one ~10 W
    free, apps, _ = make_world(load_rps=10.0)
    unconstrained = free.plan(apps)
    assert unconstrained.feasible
    assert unconstrained.fleet_draw_w > 100.0
    capped, apps, _ = make_world(load_rps=10.0, power_budget_w=50.0)
    placement = capped.plan(apps)
    assert placement.feasible
    assert placement.fleet_draw_w <= 50.0
    assert all(b == "cool" for b in placement.by_app.values())


def test_slot_capacity_splits_load_across_the_pool():
    # u = 6 rps x 0.1 s = 0.6 (hot) / 6 x 0.15 = 0.9 (cool) slot-
    # equivalents per app; slots=1.0 fits one app per backend, not two
    planner, apps, _ = make_world(n_apps=2, load_rps=6.0, slots=1.0,
                                  cool_t=0.15)
    placement = planner.plan(apps)
    assert placement.feasible
    assert set(placement.by_app.values()) == {"hot", "cool"}
    # and three such apps cannot fit a two-backend pool at all
    planner3, apps3, _ = make_world(n_apps=3, load_rps=6.0, slots=1.0,
                                    cool_t=0.15)
    assert not planner3.plan(apps3).feasible


def test_memory_capacity_is_enforced():
    lookup = PlanLookup()
    pool = [PoolBackend(name="small", backend=FakeBackend("small"),
                        memory_bytes=100.0),
            PoolBackend(name="big", backend=FakeBackend("big"),
                        memory_bytes=1e9)]
    app = FleetApp(name="a", arch="m", memory_bytes=200.0,
                   tokens_per_request=1.0)
    warm_time(lookup, "small", "m", 0.1)             # faster, but too small
    warm_time(lookup, "big", "m", 0.2)
    planner = FleetPlanner(pool, lookup,
                           ga_cfg=GAConfig(population=2, generations=2,
                                           seed=0, cardinalities=[2]))
    placement = planner.plan([app])
    assert placement.feasible and placement.by_app["a"] == "big"
    forced = planner.evaluate([app], (0,))
    assert not forced.feasible and any("small" in v
                                       for v in forced.violations)


# ------------------------------------------------------------ greedy vs GA
def test_ga_never_does_worse_than_its_greedy_seed():
    planner, apps, _ = make_world(n_apps=5, load_rps=3.0, slots=2.0)
    seed = planner.greedy(apps)
    assert seed is not None
    greedy_p = planner.evaluate(apps, seed)
    placement = planner.plan(apps)
    assert placement.feasible
    assert placement.objective <= greedy_p.objective + 1e-12


def test_run_ga_seed_population_is_injected_and_optional():
    target = (1, 0, 1)

    def fitness(genes):
        d = sum(a != b for a, b in zip(genes, target))
        return Evaluation(time_s=1.0 + d, correct=True)

    cfg = GAConfig(population=3, generations=1, seed=0)
    seeded = run_ga(3, fitness, cfg, seed_population=[target])
    assert seeded.best_genes == target               # present in gen 0
    # omitted -> byte-identical to the pre-parameter behavior
    a = run_ga(3, fitness, GAConfig(population=4, generations=3, seed=1))
    b = run_ga(3, fitness, GAConfig(population=4, generations=3, seed=1),
               seed_population=None)
    assert a.best_genes == b.best_genes and a.history == b.history
    with pytest.raises(AssertionError):
        run_ga(3, fitness, cfg, seed_population=[(1, 0)])


# ------------------------------------------------------------------ replan
def test_replan_keeps_unaffected_apps_pinned():
    planner, apps, lookup = make_world(n_apps=4)
    # a3 was proven wrong on hot offline -> it starts (and stays) on cool
    lookup.register_failure(serve_key("hot", apps[3].arch), "wrong result")
    planner._cand_cache.clear()
    placement = planner.plan(apps)
    assert placement.feasible
    assert placement.by_app["a0"] == "hot"
    assert placement.by_app["a3"] == "cool"
    out = planner.replan(apps, placement, "hot")
    assert out.feasible
    assert "hot" not in out.by_app.values()          # dead backend unused
    assert out.by_app["a3"] == "cool"                # unaffected: pinned
    assert out.info["replan"]["failed"] == "hot"
    assert out.info["replan"]["mode"] == "pinned-greedy"


def test_replan_unknown_backend_raises():
    planner, apps, _ = make_world()
    with pytest.raises(ValueError):
        planner.replan(apps, planner.plan(apps), "nope")


def test_replan_reports_infeasible_when_survivors_cannot_hold_the_fleet():
    planner, apps, _ = make_world(n_apps=2, load_rps=6.0, slots=1.0,
                                  cool_t=0.15)
    placement = planner.plan(apps)
    assert placement.feasible
    out = planner.replan(apps, placement, "hot")
    assert not out.feasible                          # 2x0.6 u > 1 slot
    assert "hot" not in [b for a, b in out.by_app.items()
                         if out.candidates.get(a)]


def test_replan_under_live_traffic_uses_observed_loads():
    """Satellite pin: the control loop replans with *observed* per-arch
    load folded in (repro.fleet.observed_apps), not the declared
    estimates — survivors stay pinned, the displaced app is re-placed on
    the surviving backend under its real load."""
    from repro.fleet import observed_apps
    planner, apps, lookup = make_world(n_apps=3, load_rps=1.0, slots=8.0)
    lookup.register_failure(serve_key("hot", apps[2].arch), "wrong result")
    planner._cand_cache.clear()
    placement = planner.plan(apps)
    assert placement.feasible
    assert placement.by_app["a2"] == "cool"
    # live traffic doubled on a0/a1 and halved on a2 vs the estimates
    live = observed_apps(apps, {"m0": 2.0, "m1": 2.0, "m2": 0.5})
    assert [a.load_rps for a in live] == pytest.approx([2.0, 2.0, 0.5])
    out = planner.replan(live, placement, "hot")
    assert out.feasible
    assert "hot" not in out.by_app.values()      # dead backend unused
    assert out.by_app["a2"] == "cool"            # survivor: pinned
    assert out.by_app["a0"] == out.by_app["a1"] == "cool"
    # the objective reflects the observed loads, not the declared ones:
    # (2 + 2 + 0.5) rps x 0.2 s on cool
    assert out.objective == pytest.approx(0.9, rel=1e-3)


def test_replan_violations_name_the_overflowing_backend():
    """A placement that was feasible before the failure must come back
    with explicit violations when the shrunken pool cannot host it —
    never a silently-infeasible or silently-dropped app."""
    planner, apps, _ = make_world(n_apps=2, load_rps=6.0, slots=1.0,
                                  cool_t=0.15)
    placement = planner.plan(apps)
    assert placement.feasible                    # one app per backend fits
    out = planner.replan(apps, placement, "hot")
    assert not out.feasible
    assert out.violations                        # explicit, not silent
    # and the survivors-only assignment names the overflowing backend:
    # 2 apps x 6 rps x 0.15 s = 1.8 slot-equivalents > cool's 1.0
    forced = planner.evaluate(apps, (1, 1), usable=[False, True])
    assert not forced.feasible
    assert any("cool" in v and "slot" in v for v in forced.violations)


# ---------------------------------------------------------------- baseline
def test_round_robin_is_the_capacity_blind_baseline():
    planner, apps, _ = make_world(n_apps=4)
    rr = round_robin(apps, planner.pool)
    assert rr == (0, 1, 0, 1)
    p = planner.evaluate(apps, rr)
    assert p.feasible                                # fits here, by luck
    best = planner.plan(apps)
    assert best.objective <= p.objective + 1e-12
