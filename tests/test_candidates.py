"""repro.core.candidates: the unified scoring datatype.

Pins the refactor's equivalence contract: a Candidate built from any of
the four source shapes (record / warm analysis / mesh cell / roofline)
scores identically to the pre-refactor per-shape arithmetic, legacy
custom policies keep working through the deprecation bridge, and ranking
records directly vs. ranking their Candidate wrappers picks the same
winner.
"""
import math

import pytest

from repro.backends import SelectionPolicy, get_policy
from repro.backends.builtin import GPU
from repro.core.candidates import (Candidate, candidates_from_records,
                                   unwrap)
from repro.power import GENERIC, GPU_T4, EnergyModel, cell_energy


class FakeRecord:
    def __init__(self, destination, best_time_s, *, correct=True,
                 mesh_time_s=None, energy_j=None, avg_watts=None,
                 price=1.0):
        self.destination = destination
        self.best_time_s = best_time_s
        self.correct = correct
        self.mesh_time_s = mesh_time_s
        self.energy_j = energy_j
        self.avg_watts = avg_watts
        self.price = price
        self.note = "extra field only the record has"


def test_from_record_lifts_every_scoring_field():
    rec = FakeRecord("gpu", 0.5, mesh_time_s=0.4, energy_j=20.0,
                     avg_watts=50.0, price=2.0)
    c = Candidate.from_record(rec)
    assert (c.backend, c.best_time_s, c.price) == ("gpu", 0.5, 2.0)
    assert c.mesh_time_s == 0.4 and c.energy_j == 20.0
    assert c.avg_watts == 50.0 and c.correct and c.source == "record"
    assert unwrap(c) is rec
    # unknown attribute reads fall through to the wrapped record ...
    assert c.destination == "gpu"
    assert c.note == "extra field only the record has"
    # ... but a bare Candidate still raises like any object
    with pytest.raises(AttributeError):
        _ = Candidate(best_time_s=1.0).no_such_field
    assert unwrap(None) is None
    assert unwrap(rec) is rec                        # non-Candidate passes


def test_every_builtin_policy_scores_record_and_candidate_identically():
    rec = FakeRecord("gpu", 0.5, mesh_time_s=0.4, energy_j=20.0,
                     avg_watts=50.0, price=2.0)
    bare = FakeRecord("cpu", 0.7)                    # nothing modeled
    for name in ("host-time", "modeled", "price-weighted", "power", "edp"):
        pol = get_policy(name)
        for r in (rec, bare):
            assert pol.score_candidate(Candidate.from_record(r)) \
                == pytest.approx(pol.score(r))


def test_rank_over_records_and_over_candidates_picks_the_same_winner():
    records = [
        FakeRecord("slow", 0.9, energy_j=10.0, avg_watts=11.0),
        FakeRecord("fast", 0.3, energy_j=30.0, avg_watts=100.0),
        FakeRecord("wrong", 0.1, correct=False),
        FakeRecord("unfinished", math.inf),
    ]
    for name in ("host-time", "power", "edp"):
        pol = get_policy(name)
        direct = pol.select(records)
        wrapped = unwrap(pol.select(candidates_from_records(records)))
        assert wrapped is direct
        # constraints survive the wrapping identically
        direct_b = pol.select(records, power_budget_w=50.0)
        wrapped_b = unwrap(pol.select(candidates_from_records(records),
                                      power_budget_w=50.0))
        assert wrapped_b is direct_b


def test_legacy_score_parts_policy_ranks_candidates_via_the_bridge():
    class Legacy(SelectionPolicy):
        name = "test-legacy-parts"

        def score_parts(self, time_s, price=1.0, modeled_s=None):
            return (modeled_s if modeled_s is not None else time_s) * price

    pol = Legacy()
    c = Candidate(best_time_s=0.5, price=3.0, mesh_time_s=0.2)
    assert pol.score_candidate(c) == pytest.approx(0.6)
    assert pol.score(c) == pytest.approx(0.6)        # both faces agree

    class LegacyScore(SelectionPolicy):
        name = "test-legacy-score"

        def score(self, record):
            return record.best_time_s * 10.0

    assert LegacyScore().score_candidate(c) == pytest.approx(5.0)

    class Naked(SelectionPolicy):
        name = "test-naked"

    with pytest.raises(NotImplementedError):
        Naked().score_candidate(c)


def test_from_analysis_reproduces_the_router_arithmetic():
    """Candidate.from_analysis is the router's pre-refactor
    _score_endpoint arithmetic verbatim: score_analysis -> service
    scaling -> envelope charge."""
    from repro.core.measure import CompiledCostRunner
    analysis = {"flops": 1e9, "bytes": 1e6, "collective_bytes": 0.0}
    scale = 4 + 8 / 8.0                              # max_gen=4, prompt=8
    c = Candidate.from_analysis(analysis, backend=GPU, n_chips=1,
                                scale=scale)
    ev = CompiledCostRunner(n_chips=1).score_analysis(dict(analysis),
                                                      cache_hit=True)
    service = ev.time_s * scale
    assert c.best_time_s == pytest.approx(service)
    assert c.mesh_time_s == pytest.approx(service)
    assert c.price == GPU.price and c.backend == GPU.name
    rep = EnergyModel(GPU_T4).from_roofline(ev.info["roofline"])
    assert c.avg_watts == pytest.approx(rep.avg_watts)
    assert c.energy_j == pytest.approx(rep.avg_watts * service)
    # an explicit price overrides the backend's
    priced = Candidate.from_analysis(analysis, backend=GPU, price=9.0)
    assert priced.price == 9.0


def test_from_cell_matches_the_old_score_cell_faces():
    energy = {"energy_j": 12.0, "avg_watts": 60.0, "edp": 12.0 * 0.2}
    c = Candidate.from_cell(0.2, n_chips=8.0, energy=energy)
    assert get_policy("host-time").score_candidate(c) == pytest.approx(0.2)
    assert get_policy("price-weighted").score_candidate(c) \
        == pytest.approx(0.2 * 8.0)
    assert get_policy("power").score_candidate(c) == pytest.approx(12.0)
    assert get_policy("edp").score_candidate(c) \
        == pytest.approx(energy["edp"])
    # the deprecated face routes through the same Candidate
    assert get_policy("power").score_cell(0.2, price=8.0, energy=energy) \
        == pytest.approx(12.0)
    # uncharged cells keep the historical price-scaled joule fallback
    assert get_policy("power").score_cell(0.2, price=8.0) \
        == pytest.approx(GENERIC.peak_w * 0.2 * 8.0)


def test_from_roofline_charges_like_the_autoplan_rerank():
    rl = {"step_time_s": 0.01, "compute_util": 0.5, "memory_util": 0.2,
          "collective_util": 0.0, "bytes_per_device": 1e6}
    c = Candidate.from_roofline(rl, n_chips=8, price=1.5, time_s=0.01)
    rep = cell_energy(rl, 8)
    assert c.energy_j == pytest.approx(rep.energy_j)
    assert c.avg_watts == pytest.approx(rep.avg_watts)
    assert get_policy("power").score_candidate(c) \
        == pytest.approx(rep.energy_j)
    assert get_policy("edp").score_candidate(c) \
        == pytest.approx(rep.energy_j * 0.01)
    assert get_policy("price-weighted").score_candidate(c) \
        == pytest.approx(0.01 * 1.5)
