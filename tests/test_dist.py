"""repro.dist subsystem on a single-device mesh: Plan genes, Rules specs,
tree_shardings, batch_axes, pipeline fallback, and the planner mesh bridge.

Multi-device behaviour (real (2,4)/(2,2,2) meshes) lives in
tests/test_distributed.py; everything here runs in-process on 1 device.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.compat import AxisType, make_mesh
from repro.dist.plan import Plan
from repro.dist.sharding import (NullRules, Rules, batch_axes,
                                 tree_shardings)


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)


# ------------------------------------------------------------------- plan
def test_plan_gene_space_matches_fields():
    p = Plan()
    for gene in Plan.GENE_SPACE:
        assert hasattr(p, gene.field), gene.field
        assert len(gene.choices) >= 2, gene.field
        assert isinstance(gene.structural, bool)


def test_plan_genes_roundtrip_all_fields():
    cards = Plan.gene_cardinalities()
    assert len(cards) == len(Plan.GENE_SPACE)
    # every gene value decodes to a plan that re-encodes to the same genes
    for i, gene in enumerate(Plan.GENE_SPACE):
        for g in range(len(gene.choices)):
            genes = [0] * len(cards)
            genes[i] = g
            q = Plan.from_genes(genes)
            assert getattr(q, gene.field) == gene.choices[g]
            assert q.to_genes()[i] == g


def test_named_plans_discoverable():
    # repro.launch.dryrun resolves --plan <name> by scanning module globals
    from repro.dist import plan as plan_mod
    named = {p.name: p for p in vars(plan_mod).values()
             if isinstance(p, Plan)}
    assert "serve-low-mem" in named
    assert named["serve-low-mem"].kv_cache_quant is True


# ------------------------------------------------------------------ rules
def test_rules_specs_on_single_device_mesh(mesh):
    rules = Rules(mesh, Plan())
    assert rules.spec(("embed", "ff"), dims=(64, 16)) == P(("data",),
                                                          "model")
    # unknown / None logical axes replicate; trailing Nones are trimmed
    assert rules.spec(("batch", "seq", None), dims=(8, 16, 4)) == \
        P(("data",))
    assert rules.spec((None, None)) == P()


def test_rules_divisibility_replicates(mesh):
    # 1-device mesh divides everything; fake a bigger axis via dims=odd
    # against a 2-wide axis on a (1,1) mesh is moot, so check the rule
    # directly: a dim not divisible by the axis product falls back
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 4}
    r = Rules(FakeMesh(), Plan())
    assert r.spec(("embed", "heads", None), dims=(64, 10, 7)) == P(("data",))
    assert r.spec(("embed", "ff"), dims=(64, 16)) == P(("data",), "model")


def test_rules_shard_largest_divisible_prefix():
    """batch % (pod*data) != 0 must degrade to sharding over the divisible
    prefix ("pod",), not fall all the way back to replicated."""
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 4, "model": 2}
    rules = Rules(FakeMesh(), Plan())
    # 4 % (2*4) != 0 but 4 % 2 == 0 -> shard over ("pod",) only
    assert rules.spec(("batch", None), dims=(4, 8)) == P(("pod",))
    # divisible by the full tuple -> unchanged behavior
    assert rules.spec(("batch", None), dims=(16, 8)) == P(("pod", "data"))
    # not even the first axis divides -> replicated
    assert rules.spec(("batch", None), dims=(3, 8)) == P()
    # the taken prefix is marked used: a later dim cannot reuse "pod",
    # while the untaken "data" stays free for dims that map to it
    spec = rules.spec(("batch", "embed"), dims=(4, 8))
    assert spec == P(("pod",), ("data",))


def test_rules_duplicate_axis_falls_back():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 4}
    rules = Rules(FakeMesh(), Plan(decode_kv_seq_shard=True))
    # kv_seq claims "model" first; kv_heads falls back to replicated
    assert rules.spec(("batch", "kv_seq", "kv_heads", None),
                      dims=(8, 32, 8, 4)) == P(("data",), "model")


def test_rules_exclude_axes():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 2, "model": 2}
    rules = Rules(FakeMesh(), Plan(), exclude_axes=("pod",))
    # batch normally rides ("pod", "data"); with pod Manual it must not
    assert rules.spec(("batch", None), dims=(8, 4)) == P(("data",))


def test_batch_axes(mesh):
    assert batch_axes(mesh) == ("data",)
    pod_mesh = make_mesh((1, 1), ("pod", "data"))
    assert batch_axes(pod_mesh) == ("pod", "data")


def test_null_rules_are_identity():
    rules = NullRules()
    x = jnp.ones((2, 3))
    assert rules.constrain(x, ("batch", None)) is x
    assert rules.spec(("batch", None)) == P()
    assert rules.mesh is None


# --------------------------------------------------------- tree_shardings
def test_tree_shardings_produces_named_shardings(mesh):
    rules = Rules(mesh, Plan())
    axes = {"w": ("embed", "ff"), "b": ("ff",), "count": ()}
    sds = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32),
           "b": jax.ShapeDtypeStruct((4,), jnp.float32),
           "count": jax.ShapeDtypeStruct((), jnp.int32)}
    sh = tree_shardings(rules, axes, sds)
    assert set(sh) == {"w", "b", "count"}
    for v in sh.values():
        assert isinstance(v, NamedSharding)
    assert sh["w"].spec == P(("data",), "model")
    assert sh["count"].spec == P()


def test_plan_rules_tree_shardings_end_to_end(mesh):
    """Acceptance: Plan -> Rules -> tree_shardings yields valid shardings
    for a real model on a single-device mesh, and the constrained model
    still computes."""
    from repro.configs import get_config
    from repro.models.lm import Model, param_axes

    cfg = get_config("granite-3-2b").reduced()
    plan = Plan(vocab_chunk=8)
    rules = Rules(mesh, plan)
    model = Model(cfg, plan, rules)
    params = model.init(jax.random.PRNGKey(0))
    sds = jax.eval_shape(lambda: params)
    shardings = tree_shardings(rules, param_axes(cfg), sds)
    leaves = jax.tree.leaves(shardings,
                             is_leaf=lambda x: isinstance(x, NamedSharding))
    assert leaves and all(isinstance(s, NamedSharding) for s in leaves)

    params = jax.device_put(params, shardings)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert np.isfinite(float(loss))


# --------------------------------------------------------------- pipeline
def test_pipeline_falls_back_to_sequential_off_mesh(mesh):
    from repro.dist.pipeline import pipeline_apply, sequential_apply

    ws = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 8)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))

    def stage_fn(w, h):
        return jnp.tanh(h @ w)

    want = sequential_apply(stage_fn, ws, x)
    # mesh has no "pod" axis of size 3 -> sequential schedule
    got = pipeline_apply(stage_fn, ws, x, mesh, microbatches=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ----------------------------------------------------------------- bridge
def test_bridge_mesh_verify_dp_tp_only(mesh):
    from repro.apps import APPS
    from repro.core.destinations import FPGA, GPU, MANY_CORE
    from repro.core.measure import CompiledCostRunner
    from repro.dist import bridge

    app = APPS["tdFIR"]()
    inputs = app.make_inputs(seed=0, small=True)
    runner = CompiledCostRunner(mesh)
    fn = app.build({})
    ev_dp = bridge.mesh_verify(runner, MANY_CORE, fn, inputs)
    ev_tp = bridge.mesh_verify(runner, GPU, fn, inputs)
    assert ev_dp is not None and ev_dp.correct and ev_dp.time_s > 0
    assert ev_tp is not None and ev_tp.correct and ev_tp.time_s > 0
    assert "roofline" in ev_dp.info
    # the FPGA analogue is a kernel substitution, not a sharding
    assert bridge.mesh_verify(runner, FPGA, fn, inputs) is None
    assert bridge.mesh_verify(None, MANY_CORE, fn, inputs) is None


def test_planner_records_mesh_time(mesh):
    from repro.apps import APPS
    from repro.core.ga import GAConfig
    from repro.core.measure import CompiledCostRunner, TimedRunner
    from repro.core.planner import UserTarget, plan_offload

    app = APPS["tdFIR"]()
    report = plan_offload(
        app, UserTarget(), inputs=app.make_inputs(0, small=True),
        runner=TimedRunner(repeats=1),
        ga_cfg=GAConfig(population=3, generations=3, seed=0),
        cost_runner=CompiledCostRunner(mesh))
    assert len(report.records) == 6
    by_method = {(r.paper_analogue, r.method): r for r in report.records}
    for analogue in ("many-core CPU", "GPU"):
        rec = by_method[(analogue, "loop")]
        assert rec.mesh_time_s is not None and rec.mesh_time_s > 0
        assert "roofline" in rec.mesh_info
    # FPGA verifications carry no mesh analogue
    assert by_method[("FPGA", "loop")].mesh_time_s is None
