"""Structure-keyed search cache (repro.core.search_cache).

Pins the PR-4 contract: plan search pays one XLA compile per *unique
structural artifact*, not per candidate — schedule-only gene flips share an
artifact, warm disk caches compile nothing, corrupted caches degrade to a
recompile, and ``analyze_hlo`` runs at most once per executable.
"""
import json

import pytest

from repro.core import search_cache as sc
from repro.core.ga import GAConfig, run_ga
from repro.core.measure import CompiledCostRunner
from repro.dist.plan import MODEL_ONLY_FIELDS, Plan


# ----------------------------------------------------------- structural key
def genes_with(**overrides):
    idx = {g.field: i for i, g in enumerate(Plan.GENE_SPACE)}
    genes = [0] * len(Plan.GENE_SPACE)
    for f, choice_value in overrides.items():
        genes[idx[f]] = Plan.GENE_SPACE[idx[f]].choices.index(choice_value)
    return tuple(genes)


def test_model_only_fields_are_the_schedule_genes():
    assert MODEL_ONLY_FIELDS == {"pipeline_schedule", "virtual_stages"}
    for g in Plan.GENE_SPACE:
        assert g.structural == (g.field not in MODEL_ONLY_FIELDS)


def test_structural_key_ignores_schedule_genes():
    base = Plan.from_genes(list(genes_with()))
    sched = Plan.from_genes(list(genes_with(
        pipeline_schedule="interleaved", virtual_stages=2)))
    remat = Plan.from_genes(list(genes_with(remat="full")))
    assert base.structural_key() == sched.structural_key()
    assert base.structural_key() != remat.structural_key()
    # the key covers non-gene fields too (anything reaching the lowering)
    import dataclasses
    named = {f[0] for f in base.structural_key()}
    for f in dataclasses.fields(Plan):
        if f.name == "name" or f.name in MODEL_ONLY_FIELDS:
            assert f.name not in named
        else:
            assert f.name in named


def test_structural_key_is_stable_and_hashable():
    p = Plan.from_genes(list(genes_with(remat="block")))
    q = Plan.from_genes(list(genes_with(remat="block")), name="other")
    assert p.structural_key() == q.structural_key()     # name is a label
    assert hash(p.structural_key()) == hash(q.structural_key())
    assert sc.hash_key(p.structural_key()) == sc.hash_key(q.structural_key())


# ------------------------------------------------------------ fake compiler
HLO_TEXT = """\
ENTRY %main (p0: f32[64,64], p1: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64] parameter(0)
  %p1 = f32[64,64] parameter(1)
  ROOT %dot.1 = f32[64,64] dot(%p0, %p1), lhs_contracting_dims={1}
}
"""


class FakeCompiled:
    """Stands in for a jax Compiled: as_text() is the expensive call."""

    def __init__(self, text=HLO_TEXT):
        self.text = text
        self.as_text_calls = 0

    def as_text(self):
        self.as_text_calls += 1
        return self.text


class FakeLowered:
    def __init__(self, counter, text=HLO_TEXT):
        self.counter = counter
        self.text = text

    def compile(self):
        self.counter["compiles"] += 1
        return FakeCompiled(self.text)


def make_counting_lower_plan(counter):
    def lower_plan(plan):
        counter["lowers"] += 1
        return FakeLowered(counter)
    return lower_plan


def make_evaluator(cache, counter, **kw):
    kw.setdefault("pipe_ranks", 2)
    return sc.make_cached_batch_evaluator(
        make_counting_lower_plan(counter), CompiledCostRunner(n_chips=1),
        cache, key_extra=("test",), **kw)


# ------------------------------------------------- artifact-sharing dedupe
def test_schedule_flip_shares_artifact_remat_flip_misses():
    counter = {"lowers": 0, "compiles": 0}
    cache = sc.SearchCache()
    ev_batch = make_evaluator(cache, counter)

    base = genes_with(microbatches=4)
    flip_sched = genes_with(microbatches=4, pipeline_schedule="one_f_one_b")
    flip_virt = genes_with(microbatches=4, pipeline_schedule="interleaved",
                           virtual_stages=2)
    evs = ev_batch([base, flip_sched, flip_virt])
    assert counter["compiles"] == 1                  # one artifact, 3 scores
    assert counter["lowers"] == 1                    # deduped BEFORE tracing
    assert [e.correct for e in evs] == [True] * 3
    # the schedule genes still differentiate the modeled time via the bubble:
    # gpipe idles (R-1)/(m+R-1) = 0.2, interleaved(V=2) only 1/9
    assert evs[0].info["roofline"]["bubble_fraction"] > 0
    assert evs[2].time_s < evs[0].time_s

    evs2 = ev_batch([genes_with(remat="full")])      # structural flip
    assert counter["compiles"] == 2
    assert evs2[0].info["cache_hit"] is False
    assert cache.stats.unique_compiles == 2
    assert cache.stats.candidates == 4


def test_ga_compiles_once_per_unique_structural_key():
    """Acceptance: a full GA over Plan.GENE_SPACE performs at most one XLA
    compile per unique structural key (compile counter)."""
    counter = {"lowers": 0, "compiles": 0}
    ev_batch = make_evaluator(sc.SearchCache(), counter)
    cards = Plan.gene_cardinalities()
    cfg = GAConfig(population=8, generations=4, seed=3,
                   cardinalities=cards)
    res = run_ga(len(cards), ev_batch.evaluate, cfg,
                 evaluate_batch=ev_batch)
    unique = {Plan.from_genes(list(g)).structural_key()
              for g in res.evaluations}
    assert counter["compiles"] == len(unique)
    assert counter["lowers"] == len(unique)
    assert res.best_eval.correct


def test_warm_disk_cache_zero_compiles_same_best(tmp_path):
    path = tmp_path / "cache.json"
    cards = Plan.gene_cardinalities()
    cfg = GAConfig(population=6, generations=3, seed=7,
                   cardinalities=cards)

    c1 = {"lowers": 0, "compiles": 0}
    ev1 = make_evaluator(sc.SearchCache(path), c1)
    res1 = run_ga(len(cards), ev1.evaluate, cfg, evaluate_batch=ev1)
    assert c1["compiles"] > 0
    assert path.exists()

    c2 = {"lowers": 0, "compiles": 0}
    cache2 = sc.SearchCache(path)                   # fresh process analogue
    ev2 = make_evaluator(cache2, c2)
    res2 = run_ga(len(cards), ev2.evaluate, cfg, evaluate_batch=ev2)
    assert c2["compiles"] == 0                      # warm: zero fresh XLA
    assert c2["lowers"] == 0
    assert res2.best_genes == res1.best_genes
    assert cache2.stats.disk_hits > 0
    assert cache2.stats.hit_rate == 1.0


def test_corrupted_disk_cache_falls_back_to_recompile(tmp_path):
    path = tmp_path / "cache.json"
    path.write_text("{ not json !!")
    counter = {"lowers": 0, "compiles": 0}
    ev = make_evaluator(sc.SearchCache(path), counter)
    evs = ev([genes_with()])
    assert evs[0].correct and counter["compiles"] == 1
    # the recompile repaired the file in place
    assert sc.SearchCache(path).lookup(
        (("test",), Plan.from_genes(list(genes_with())).structural_key())
    ) is not None


def test_stale_disk_entries_are_ignored(tmp_path):
    path = tmp_path / "cache.json"
    key = (("test",), Plan.from_genes(list(genes_with())).structural_key())
    h = sc.hash_key(key)
    # wrong version: whole file ignored
    path.write_text(json.dumps({"version": -1, "entries": {
        h: {"analysis": {"flops": 1.0, "bytes": 1.0,
                         "collective_bytes": 0.0}, "compile_s": 0.1}}}))
    assert sc.SearchCache(path).lookup(key) is None
    # right version + runtime, malformed payloads: only those entries drop
    path.write_text(json.dumps({"version": sc.CACHE_VERSION,
                                "runtime": sc.runtime_fingerprint(),
                                "entries": {
        h: {"analysis": {"flops": "NaN-ish"}},
        "other": ["not", "a", "payload"]}}))
    cache = sc.SearchCache(path)
    assert cache.lookup(key) is None
    counter = {"lowers": 0, "compiles": 0}
    evs = make_evaluator(cache, counter)([genes_with()])
    assert evs[0].correct and counter["compiles"] == 1


def test_disk_cache_from_other_runtime_reads_cold(tmp_path):
    """A file written by a different jax/XLA/platform must not serve
    stale rooflines — the whole disk layer reads as cold."""
    path = tmp_path / "cache.json"
    counter = {"lowers": 0, "compiles": 0}
    make_evaluator(sc.SearchCache(path), counter)([genes_with()])
    assert counter["compiles"] == 1
    raw = json.loads(path.read_text())
    assert raw["runtime"] == sc.runtime_fingerprint()
    raw["runtime"] = "jax-0.0.0-tpu"
    path.write_text(json.dumps(raw))
    c2 = {"lowers": 0, "compiles": 0}
    make_evaluator(sc.SearchCache(path), c2)([genes_with()])
    assert c2["compiles"] == 1                   # recompiled, no stale hit


def test_artifact_layer_is_bounded():
    cache = sc.SearchCache(artifact_capacity=2)
    for i in range(5):
        cache.put_compiled(("k", i), FakeCompiled())
    assert len(cache._compiled) == 2
    assert cache.get_compiled(("k", 4)) is not None
    assert cache.get_compiled(("k", 0)) is None  # evicted FIFO


def test_compile_failure_is_memoized_not_cached_to_disk(tmp_path):
    path = tmp_path / "cache.json"
    cache = sc.SearchCache(path)
    calls = {"n": 0}

    def broken_lower_plan(plan):
        calls["n"] += 1
        raise RuntimeError("lowering exploded")

    ev = sc.make_cached_batch_evaluator(
        broken_lower_plan, CompiledCostRunner(n_chips=1), cache,
        key_extra=("test",))
    evs = ev([genes_with(), genes_with(pipeline_schedule="one_f_one_b")])
    assert calls["n"] == 1                       # one failure per key
    assert all(not e.correct for e in evs)
    assert "lowering exploded" in evs[0].info["error"]
    # same generation again: served from the failure memo, no retry storm
    ev([genes_with()])
    assert calls["n"] == 1
    # the disk layer never persists failures
    fresh = sc.SearchCache(path)
    key = (("test",), Plan.from_genes(list(genes_with())).structural_key())
    assert fresh.lookup(key) is None


# ------------------------------------------------------ analysis memoization
def test_analyze_compiled_memoizes_per_artifact():
    c = FakeCompiled()
    a1 = sc.analyze_compiled(c)
    a2 = sc.analyze_compiled(c)
    assert c.as_text_calls == 1
    assert a1 is a2
    assert a1["flops"] == pytest.approx(2.0 * 64 * 64 * 64)
    other = FakeCompiled()
    sc.analyze_compiled(other)
    assert other.as_text_calls == 1


def test_score_compiled_parses_hlo_once_across_rescoring():
    runner = CompiledCostRunner(n_chips=1)
    c = FakeCompiled()
    e1 = runner.score_compiled(c, bubble_fraction=0.0)
    e2 = runner.score_compiled(c, bubble_fraction=0.5)   # re-score: free
    assert c.as_text_calls == 1
    assert e1.correct and e2.correct
    assert e2.time_s == pytest.approx(e1.time_s * 2.0)


def test_score_analysis_matches_score_compiled():
    runner = CompiledCostRunner(n_chips=1)
    c = FakeCompiled()
    via_compiled = runner.score_compiled(c, 0.25, bubble_fraction=0.25)
    via_analysis = runner.score_analysis(sc.analyze_compiled(c), 0.25,
                                         bubble_fraction=0.25)
    assert via_analysis.time_s == pytest.approx(via_compiled.time_s)
    assert via_analysis.info["roofline"] == via_compiled.info["roofline"]


# ------------------------------------------------------------ key plumbing
def test_hash_key_stable_across_processes_and_orderings():
    k1 = (("a", 1), {"x": 1, "y": 2})
    k2 = (("a", 1), {"y": 2, "x": 1})       # dict order must not matter
    assert sc.hash_key(k1) == sc.hash_key(k2)
    assert sc.hash_key(k1) != sc.hash_key((("a", 2), {"x": 1, "y": 2}))


def test_loop_ga_reuses_identical_choice_measurements():
    """Paper-side structural dedupe: gene strings that build the same
    offload pattern (nest without the destination impl) measure once."""
    from repro.backends.builtin import MANY_CORE
    from repro.core.ga import Evaluation
    from repro.core.loop_offload import ga_search

    class Nest:
        def __init__(self, name, impls):
            self.name = name
            self.impls = impls

    class App:
        name = "dedupe-app"
        nests = [Nest("a", {"dp": None, "seq": None}),
                 Nest("b", {"seq": None})]        # no dp impl -> "seq"

        def build(self, choice):
            return dict(choice)

    class CountingRunner:
        def __init__(self):
            self.calls = []

        def measure(self, fn, inputs, ref_out):
            self.calls.append(fn)
            return Evaluation(time_s=1.0 + 0.1 * len(self.calls),
                              correct=True)

    runner = CountingRunner()
    res = ga_search(App(), MANY_CORE, runner, inputs=None, ref_out=None,
                    ga_cfg=GAConfig(population=4, generations=4, seed=0))
    # 2 binary genes -> 4 gene strings but only 2 distinct patterns
    assert res.cache_stats["measured"] == len(runner.calls)
    assert res.cache_stats["measured"] <= 2
    assert res.cache_stats["reused"] >= 1
    assert res.n_measurements >= res.cache_stats["measured"]
