"""Mixed-destination planner: six verifications, ordering, early stop,
residual rule (paper §II.C)."""
import pytest

from repro.apps import APPS
from repro.core.destinations import VERIFICATION_ORDER
from repro.core.ga import GAConfig
from repro.core.measure import TimedRunner
from repro.core.planner import UserTarget, plan_offload


@pytest.fixture(scope="module")
def tdfir_report():
    app = APPS["tdFIR"]()
    return plan_offload(
        app, UserTarget(),
        inputs=app.make_inputs(0, small=True),
        runner=TimedRunner(repeats=1),
        ga_cfg=GAConfig(population=3, generations=3, seed=0))


def test_verification_order_is_papers(tdfir_report):
    methods = [(r.paper_analogue, r.method) for r in tdfir_report.records]
    want = [(d.paper_analogue, m) for d, m in VERIFICATION_ORDER]
    assert methods == want[:len(methods)]
    # FB verifications strictly before loop verifications
    kinds = [r.method for r in tdfir_report.records]
    if "loop" in kinds:
        assert kinds.index("loop") >= kinds.count("function_block")


def test_all_six_run_without_target(tdfir_report):
    assert len(tdfir_report.records) == 6
    assert not tdfir_report.early_stopped
    assert tdfir_report.selected is not None


def test_early_stop_on_met_target():
    app = APPS["tdFIR"]()
    report = plan_offload(
        app, UserTarget(target_speedup=0.1),    # trivially met
        inputs=app.make_inputs(0, small=True),
        runner=TimedRunner(repeats=1),
        ga_cfg=GAConfig(population=3, generations=3, seed=0))
    assert report.early_stopped
    assert len(report.records) < 6


def test_price_constraint_blocks_early_stop():
    app = APPS["tdFIR"]()
    report = plan_offload(
        app, UserTarget(target_speedup=0.1, max_price=0.5),  # price never ok
        inputs=app.make_inputs(0, small=True),
        runner=TimedRunner(repeats=1),
        ga_cfg=GAConfig(population=3, generations=3, seed=0))
    assert not report.early_stopped
    assert len(report.records) == 6


def test_residual_rule_pins_fb_choice(tdfir_report):
    """After FB offload succeeds, loop searches keep the FB nest pinned."""
    fb = [r for r in tdfir_report.records if r.method == "function_block"
          and r.best_time_s < float("inf")]
    loops = [r for r in tdfir_report.records if r.method == "loop"]
    if fb and loops:
        best_fb = min(fb, key=lambda r: r.best_time_s)
        if best_fb.best_time_s < tdfir_report.ref_time_s:
            pinned = next(iter(best_fb.choice))
            for r in loops:
                assert r.choice.get(pinned) == best_fb.choice[pinned]


def test_selected_is_fastest(tdfir_report):
    finite = [r for r in tdfir_report.records
              if r.best_time_s < float("inf")]
    assert tdfir_report.selected.best_time_s == \
        min(r.best_time_s for r in finite)
