import os
import sys

# tests import shared helpers; make the tests dir importable
sys.path.insert(0, os.path.dirname(__file__))

# NOTE: no XLA_FLAGS here on purpose — smoke tests/benches must see exactly
# 1 device.  Multi-device tests go through helpers.run_multidevice.
