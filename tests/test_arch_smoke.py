"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs; plus a decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.lm import Model, init_cache


def _batch(cfg, b=2, s=32):
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "labels": jnp.ones((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["img_embed"] = jnp.ones((b, cfg.n_img_tokens, cfg.d_model),
                                      jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((b, cfg.n_frames, cfg.d_model),
                                   jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_train_step(arch):
    cfg = ARCHS[arch].reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(p):
        return model.train_loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), arch
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_decode_step(arch):
    cfg = ARCHS[arch].reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    cache = init_cache(cfg, b, s)
    logits, cache2 = jax.jit(model.decode_step)(
        params, cache, jnp.ones((b, 1), jnp.int32), jnp.int32(0))
    assert logits.shape == (b, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_param_count_sane(arch):
    """Full configs: analytic param count within 2x of the nameplate."""
    import re
    cfg = ARCHS[arch]
    m = re.search(r"(\d+(?:\.\d+)?)b", arch)
    n = cfg.n_params()
    assert n > 1e8, arch
    if m:
        nameplate = float(m.group(1)) * 1e9
        assert 0.3 * nameplate < n < 3.0 * nameplate, (arch, n, nameplate)


def test_vocab_padding_applied():
    cfg = ARCHS["granite-3-2b"]
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab >= cfg.vocab_size


def test_long500k_eligibility():
    from repro.configs import SHAPES, cell_runnable
    long = SHAPES["long_500k"]
    runnable = {a for a in ARCHS if cell_runnable(ARCHS[a], long)}
    assert runnable == {"mamba2-1.3b", "recurrentgemma-2b",
                        "h2o-danube-1.8b"}
