"""repro.runtime.fault_tolerance: direct unit coverage.

The substrate tests exercise this module through full training loops; these
pin the primitives themselves — ``StragglerWatchdog.record`` window
semantics and ``run_resilient`` resume-from-checkpoint across separate
invocations (the fleet replan path reuses the same degrade-and-continue
contract).
"""
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.fault_tolerance import StragglerWatchdog, run_resilient


# ------------------------------------------------------------- watchdog
def test_watchdog_needs_ten_samples_before_flagging():
    wd = StragglerWatchdog(window=50, threshold=3.0)
    for i in range(9):
        assert not wd.record(i, 1.0)
    # the 10th sample can flag — a 100x outlier against 9 stable steps
    assert wd.record(9, 100.0)
    assert wd.flagged[0]["step"] == 9
    assert wd.flagged[0]["mean"] == pytest.approx(1.0)


def test_watchdog_compares_against_previous_window_not_itself():
    """The outlier is judged against times[:-1]: a big dt must not dilute
    the statistics it is being compared to."""
    wd = StragglerWatchdog()
    for i in range(20):
        wd.record(i, 1.0)
    assert wd.record(20, 2.0)            # zero variance window: any jump
    assert wd.flagged[-1]["std"] == pytest.approx(1e-9)


def test_watchdog_window_evicts_old_samples():
    wd = StragglerWatchdog(window=10, threshold=3.0)
    for i in range(10):
        wd.record(i, 10.0)               # old regime: slow steps
    for i in range(10, 20):
        wd.record(i, 1.0)                # new regime fills the window
    assert len(wd.times) == 10
    assert all(t == 1.0 for t in wd.times)
    # 10.0 was normal under the old regime; after eviction it's an outlier
    assert wd.record(20, 10.0)


def test_watchdog_ewma_tracks_recent_steps():
    wd = StragglerWatchdog(ewma_alpha=0.5)
    wd.record(0, 1.0)
    assert wd.ewma == pytest.approx(1.0)  # first sample seeds the EWMA
    wd.record(1, 3.0)
    assert wd.ewma == pytest.approx(2.0)
    wd.record(2, 2.0)
    assert wd.ewma == pytest.approx(2.0)


def test_watchdog_window_is_a_bounded_deque():
    """Satellite pin: the window is a deque(maxlen=window) — recording
    beyond the window evicts from the left in O(1), never grows, and the
    bound holds under heavy sustained load."""
    from collections import deque
    wd = StragglerWatchdog(window=8)
    assert isinstance(wd.times, deque) and wd.times.maxlen == 8
    for i in range(1000):
        wd.record(i, 1.0 + (i % 5) * 1e-3)
    assert len(wd.times) == 8
    assert list(wd.times) == [1.0 + (i % 5) * 1e-3 for i in range(992, 1000)]


def test_watchdog_reset_gives_a_fresh_window():
    """After an endpoint recovers, its health machine calls reset(): the
    old (faulted) samples and EWMA must not poison the fresh regime."""
    wd = StragglerWatchdog(window=10, ewma_alpha=0.5)
    for i in range(10):
        wd.record(i, 10.0)               # the faulted regime
    assert wd.ewma is not None and len(wd.times) == 10
    wd.reset()
    assert len(wd.times) == 0 and wd.ewma is None
    assert wd.times.maxlen == 10         # the bound survives the reset
    # the fresh regime seeds cleanly: 1.0 is not an outlier now
    assert not wd.record(100, 1.0)
    assert wd.ewma == pytest.approx(1.0)
    # flag history is intentionally kept (it is the incident log)
    for i in range(101, 111):
        wd.record(i, 1.0)
    assert not wd.flagged


def test_watchdog_steady_steps_never_flag():
    wd = StragglerWatchdog(window=20, threshold=3.0)
    flagged = [wd.record(i, 1.0 + 0.001 * (i % 3)) for i in range(100)]
    assert not any(flagged)


# -------------------------------------------------------- run_resilient
def _counting_step(trace):
    def step_fn(state, step):
        trace.append(step)
        return {"x": state["x"] + 1.0}, {"loss": float(step)}
    return step_fn


def test_run_resilient_resumes_from_checkpoint_across_invocations(tmp_path):
    """The resume contract: a second invocation picks up at the persisted
    ``next_step`` instead of recomputing from 0."""
    ckpt = Checkpointer(tmp_path / "ck")
    trace1 = []
    res1 = run_resilient(total_steps=6, checkpointer=ckpt,
                         init_state=lambda: {"x": np.float64(0.0)},
                         step_fn=_counting_step(trace1), save_every=3,
                         async_checkpoint=False)
    assert res1.last_step == 6 and trace1 == [0, 1, 2, 3, 4, 5]
    assert ckpt.latest_step() == 6

    # a fresh loop (same directory) resumes: no step re-executed
    trace2 = []
    res2 = run_resilient(total_steps=10, checkpointer=ckpt,
                         init_state=lambda: pytest.fail(
                             "resume must not re-init state"),
                         step_fn=_counting_step(trace2), save_every=3,
                         async_checkpoint=False)
    assert trace2 == [6, 7, 8, 9]
    assert res2.last_step == 10 and res2.restarts == 0
    state, extra = ckpt.restore()
    assert extra["next_step"] == 10
    assert float(state["x"]) == pytest.approx(10.0)


def test_run_resilient_rolls_back_to_last_good_checkpoint(tmp_path):
    ckpt = Checkpointer(tmp_path / "ck")
    trace = []
    boom = {"armed": True}

    def fault_hook(step):
        if step == 4 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected device halt")

    res = run_resilient(total_steps=6, checkpointer=ckpt,
                        init_state=lambda: {"x": np.float64(0.0)},
                        step_fn=_counting_step(trace), save_every=3,
                        fault_hook=fault_hook, async_checkpoint=False)
    # the fault at 4 rolls back to the step-3 checkpoint: step 3 replays
    assert res.restarts == 1 and res.last_step == 6
    assert trace == [0, 1, 2, 3, 3, 4, 5]
    assert float(ckpt.restore()[0]["x"]) == pytest.approx(6.0)


def test_run_resilient_gives_up_after_max_restarts(tmp_path):
    ckpt = Checkpointer(tmp_path / "ck")

    def fault_hook(step):
        raise RuntimeError("permanently broken")

    with pytest.raises(RuntimeError, match="permanently broken"):
        run_resilient(total_steps=4, checkpointer=ckpt,
                      init_state=lambda: {"x": np.float64(0.0)},
                      step_fn=_counting_step([]), max_restarts=2,
                      fault_hook=fault_hook, async_checkpoint=False)
