"""repro.runtime.control: the closed plan -> serve -> observe -> replan loop.

Pins the PR's acceptance scenario: an endpoint killed mid-trace opens its
circuit, the quarantined endpoint receives zero non-probe dispatches,
in-flight requests drain to completion (zero dropped, zero
double-completed), the FleetController replans without placing on the
failed backend, and a half-open probe restores the endpoint after the
fault window — all on a deterministic tick clock with zero new XLA
compiles (jit-poisoned, like the router's and the fleet planner's pins).
"""
import pytest

from repro.core.cost_model import PEAK_FLOPS
from repro.core.ga import GAConfig
from repro.core.plan_lookup import PlanLookup, serve_key
from repro.fleet import FleetApp, FleetPlanner, PoolBackend, observed_apps
from repro.power import PowerEnvelope
from repro.runtime.control import (ControlLoop, Fault, FaultInjector,
                                   FleetController)
from repro.serve import Endpoint, HealthConfig, Request, Router
from repro.serve.health import HEALTHY, PROBING, QUARANTINED

TICK_S = 0.01


class FakeBackend:
    def __init__(self, name, power=None):
        self.name = name
        self.price = 1.0
        self.paper_analogue = ""
        self.power = power


HOT = PowerEnvelope("hot", idle_w=100.0, peak_w=200.0)
COOL = PowerEnvelope("cool", idle_w=5.0, peak_w=10.0)


def warm_time(lookup, backend_name, arch, t):
    lookup.register(serve_key(backend_name, arch),
                    {"flops": t * PEAK_FLOPS, "bytes": 0.0,
                     "collective_bytes": 0.0})


def req(rid, tick, *, arch="m0", max_gen=1):
    # scale = max_gen + prompt_len/8 = 2 decode-steps of modeled work
    return Request(rid=rid, arch=arch, prompt_len=8, max_gen=max_gen,
                   arrival_s=tick * TICK_S)


def make_world(*, hot_t=0.005, cool_t=0.02, load_rps=1.0,
               power_budget_w=None, health_cfg=None, n_slots=4):
    """One app, two destinations: hot0 (fast, hungry) and cool0 (slow,
    frugal), Router endpoints and FleetPlanner pool sharing one lookup
    and one backend namespace so serve keys line up."""
    lookup = PlanLookup()
    hot_b, cool_b = FakeBackend("hot", HOT), FakeBackend("cool", COOL)
    warm_time(lookup, "hot", "m0", hot_t)
    warm_time(lookup, "cool", "m0", cool_t)
    hot0 = Endpoint(name="hot0", backend=hot_b, arch="m0", n_slots=n_slots)
    cool0 = Endpoint(name="cool0", backend=cool_b, arch="m0",
                     n_slots=n_slots)
    cfg = health_cfg if health_cfg is not None else HealthConfig(
        error_threshold=1, backoff_ticks=4, backoff_mult=2.0,
        probe_quota=1, probe_successes=1)
    router = Router([hot0, cool0], lookup, policy="modeled",
                    health_cfg=cfg)
    pool = [PoolBackend(name="hot", backend=hot_b, slots=16.0),
            PoolBackend(name="cool", backend=cool_b, slots=16.0)]
    apps = [FleetApp(name="a0", arch="m0", load_rps=load_rps,
                     tokens_per_request=2.0)]
    planner = FleetPlanner(pool, lookup, power_budget_w=power_budget_w,
                           ga_cfg=GAConfig(population=4, generations=4,
                                           seed=0, cardinalities=[2]))
    return router, planner, apps, lookup, (hot0, cool0)


# ------------------------------------------------------------ fault plans
def test_fault_windows_are_pure_functions_of_tick():
    inj = FaultInjector([
        Fault(kind="kill", endpoint="a", at_tick=5, until_tick=10),
        Fault(kind="latency", endpoint="a", at_tick=0, until_tick=4,
              factor=3.0),
        Fault(kind="latency", endpoint="a", at_tick=2, until_tick=4,
              factor=2.0),
        Fault(kind="wrong_result", endpoint="b", at_tick=7),
        Fault(kind="power_spike", endpoint="b", at_tick=1, until_tick=3,
              factor=40.0),
    ])
    assert not inj.is_dead("a", 4) and inj.is_dead("a", 5)
    assert inj.is_dead("a", 9) and not inj.is_dead("a", 10)
    assert inj.latency_factor("a", 1) == pytest.approx(3.0)
    assert inj.latency_factor("a", 3) == pytest.approx(6.0)  # compounds
    assert inj.latency_factor("a", 4) == 1.0
    assert inj.latency_factor("b", 3) == 1.0                 # scoped
    assert not inj.wrong_result("b", 6)
    assert inj.wrong_result("b", 7) and inj.wrong_result("b", 10_000)
    assert inj.power_spike_w("b", 2) == pytest.approx(40.0)
    assert inj.power_spike_w("b", 3) == 0.0
    # querying never mutates: same answers on replay
    assert inj.is_dead("a", 5) and inj.latency_factor("a", 3) == 6.0


def test_fault_validation():
    with pytest.raises(ValueError):
        Fault(kind="meteor", endpoint="a", at_tick=0)
    with pytest.raises(ValueError):
        Fault(kind="kill", endpoint="a", at_tick=5, until_tick=5)


# ------------------------------------------------- the acceptance scenario
def test_chaos_kill_quarantine_drain_replan_probe_recover(monkeypatch):
    """The PR's acceptance pin, end to end on one deterministic clock."""
    router, planner, apps, lookup, (hot0, cool0) = make_world()
    placement = planner.plan(apps)
    assert placement.feasible and placement.by_app["a0"] == "hot"
    ctl = FleetController(router, planner, apps, placement=placement,
                          tick_s=TICK_S)
    kill = Fault(kind="kill", endpoint="hot0", at_tick=10, until_tick=30)
    loop = ControlLoop(
        router, [req(f"r{i:03d}", i) for i in range(60)],
        controller=ctl, injector=FaultInjector([kill]), tick_s=TICK_S)

    import jax

    def poisoned(*a, **kw):
        raise AssertionError("control loop attempted a jax trace")

    monkeypatch.setattr(jax, "jit", poisoned)
    monkeypatch.setattr(jax, "vmap", poisoned)
    misses0 = lookup.stats.misses
    lookups0 = lookup.stats.lookups

    out = loop.run()

    # zero-compile: the whole loop re-scored through PlanLookup only
    assert lookup.stats.misses == misses0
    assert lookup.stats.lookups > lookups0

    # every request completes exactly once: no drops, no double counting
    assert out["completed"] == 60
    assert out["dropped"] == []
    assert out["double_completed"] == 0
    assert out["failed"] >= 1                    # the kill was really felt
    assert out["fleet_draw_w_min"] >= 0.0

    # the circuit opened at the kill and closed only after the window
    health = router.health["hot0"]
    seq = [(t["from"], t["to"]) for t in health.transitions]
    assert (HEALTHY, QUARANTINED) == seq[0]
    assert (QUARANTINED, PROBING) in seq
    assert (PROBING, QUARANTINED) in seq         # a probe died in-window
    assert seq[-1] == (PROBING, HEALTHY)         # recovered post-window
    assert health.recoveries == 1
    recovered_tick = health.transitions[-1]["tick"]
    assert recovered_tick >= 30

    # while quarantined, hot0 saw zero non-probe dispatches: every
    # dispatch inside the fault window was a half-open probe that died
    quarantined_at = health.transitions[0]["tick"]
    in_window = [t for t, _, name in loop.dispatch_log
                 if name == "hot0" and quarantined_at < t < 30]
    probe_failures = sum(1 for a, b in seq if (a, b) ==
                         (PROBING, QUARANTINED))
    assert len(in_window) == probe_failures      # probes only, nothing else
    # after recovery the fast endpoint carries traffic again
    assert any(name == "hot0" and t > recovered_tick
               for t, _, name in loop.dispatch_log)

    # the controller replanned off the failed backend without placing on it
    replans = [e for e in ctl.events if e["event"] == "replan"]
    assert replans and replans[0]["failed"] == "hot"
    assert replans[0]["by_app"]["a0"] == "cool"
    assert all(e["fleet_draw_w"] >= 0.0 for e in replans)

    # in-flight work admitted before the kill drained through the ledger
    assert router.fleet_draw_w == 0.0
    assert all(ep.in_flight == 0 for ep in router.endpoints)


def test_chaos_replay_is_deterministic():
    """Same fault plan + same trace => identical summary, tick for tick."""
    def run_once():
        router, planner, apps, _, _ = make_world()
        ctl = FleetController(router, planner, apps,
                              placement=planner.plan(apps), tick_s=TICK_S)
        loop = ControlLoop(
            router, [req(f"r{i:03d}", i) for i in range(40)],
            controller=ctl,
            injector=FaultInjector([Fault(kind="kill", endpoint="hot0",
                                          at_tick=8, until_tick=20)]),
            tick_s=TICK_S)
        out = loop.run()
        return out, loop.dispatch_log

    (out_a, log_a), (out_b, log_b) = run_once(), run_once()
    assert log_a == log_b
    for key in ("ticks", "completed", "failed", "dropped",
                "double_completed", "dispatches", "refusals"):
        assert out_a[key] == out_b[key], key


def test_chaos_replay_trace_is_byte_identical():
    """The replay pin, extended to observability: tracing the identical
    scenario twice must serialize to byte-identical JSONL — every span and
    event rides the loop's virtual tick clock (Tracer.set_time), and ids
    are sequential, so nothing wall-clock-shaped can leak in."""
    from repro.obs import Tracer, jsonl_line, use_tracer

    def run_once() -> str:
        tr = Tracer()
        with use_tracer(tr):
            # pin the clock before the world is built so the pre-loop
            # records (fleet plan span, GA generation events) are pinned too
            tr.set_time(0.0)
            router, planner, apps, _, _ = make_world()
            ctl = FleetController(router, planner, apps,
                                  placement=planner.plan(apps),
                                  tick_s=TICK_S)
            loop = ControlLoop(
                router, [req(f"r{i:03d}", i) for i in range(40)],
                controller=ctl,
                injector=FaultInjector([Fault(kind="kill", endpoint="hot0",
                                              at_tick=8, until_tick=20)]),
                tick_s=TICK_S)
            loop.run()
        return "\n".join(jsonl_line(r) for r in tr.records) + "\n"

    a, b = run_once(), run_once()
    assert a == b
    # and the trace actually observed the scenario, layer by layer
    for marker in ('"name":"route"', '"name":"tick"', '"name":"request"',
                   '"name":"transition"', '"name":"replan"',
                   '"name":"generation"', '"name":"plan"'):
        assert marker in a, marker


# ------------------------------------------------------------ wrong result
def test_wrong_result_publishes_failure_and_replan_avoids_the_backend():
    """A wrong result is the online form of a verification failure: the
    request fails, the verdict lands in the lookup, and neither the
    router nor the next replan ever uses that destination again."""
    router, planner, apps, lookup, _ = make_world()
    ctl = FleetController(router, planner, apps,
                          placement=planner.plan(apps), tick_s=TICK_S)
    loop = ControlLoop(
        router, [req(f"r{i:02d}", i * 2) for i in range(10)],
        controller=ctl,
        injector=FaultInjector([Fault(kind="wrong_result",
                                      endpoint="hot0", at_tick=0)]),
        tick_s=TICK_S)
    out = loop.run()
    assert out["completed"] == 10 and out["dropped"] == []
    # the verdict is published: the key refuses statically from now on
    assert not lookup.usable(lookup.lookup(serve_key("hot", "m0")))
    # the wrongdoer saw exactly one dispatch — the one that caught it
    assert out["dispatches"]["hot0"] == 1
    assert out["dispatches"]["cool0"] == 10
    # and the replan (triggered by the quarantine) avoided it
    replans = [e for e in ctl.events if e["event"] == "replan"]
    assert replans and all(e["by_app"]["a0"] == "cool" for e in replans)
    assert ctl.placement.feasible
    assert ctl.placement.by_app["a0"] == "cool"


# --------------------------------------------------- drain-based migration
def test_observed_load_replans_and_migrates_by_draining():
    """Observed load (not the declared estimate) drives the replan; the
    freed endpoint is drained, its in-flight requests complete through
    the ledger (zero dropped / double-completed), and only then is it
    removed.  The migration never goes draw-negative."""
    router, planner, apps, lookup, (hot0, cool0) = make_world(
        hot_t=0.1, cool_t=0.2, load_rps=0.1, power_budget_w=50.0)
    placement = planner.plan(apps)
    assert placement.by_app["a0"] == "hot"       # cheap at the declared load
    ctl = FleetController(router, planner, apps, placement=placement,
                          tick_s=TICK_S)
    # admit three requests onto hot0 (the soon-to-be-migrated endpoint)
    decisions = []
    for i in range(3):
        d = router.route(req(f"fly{i}", 0))
        assert d.accepted and d.endpoint.name == "hot0"
        router.dispatch(d)
        decisions.append(d)
    draw_before = router.fleet_draw_w
    assert draw_before > 0.0
    # observe 20 rps of real traffic: utilization 2.0 slot-equivalents at
    # ~200 W on hot — over the 50 W budget; cool holds it at ~10 W
    for i in range(20):
        ctl.on_complete(req(f"obs{i}", i * 5), "hot0", 0.1, tick=i * 5)
    assert ctl.observed_load_rps()["m0"] == pytest.approx(20.0, rel=0.1)
    folded = ctl.observed_apps()
    assert folded[0].load_rps == pytest.approx(20.0, rel=0.1)

    new = ctl.replan(tick=100)
    assert new.feasible and new.by_app["a0"] == "cool"
    assert hot0.draining                         # migration = drain, not cut
    assert router.endpoint("hot0") is not None   # still live while draining
    # no new dispatches land on the draining endpoint
    d = router.route(req("after", 100))
    assert d.accepted and d.endpoint.name == "cool0"
    router.dispatch(d)
    # in-flight work completes through the ledger: nothing dropped
    for dec in decisions:
        assert router.complete(dec, latency_s=0.1)
        assert router.fleet_draw_w >= 0.0
    assert router.drained("hot0")
    ctl.step(101)                                # controller reaps the drain
    assert router.endpoint("hot0") is None
    removed = [e for e in ctl.events if e["event"] == "removed"]
    assert [e["endpoint"] for e in removed] == ["hot0"]
    # the survivor still serves and the books balance
    assert router.complete(d, latency_s=0.2)
    assert router.fleet_draw_w == 0.0


def test_quarantined_endpoint_is_never_drained():
    """Recovery owns a quarantined endpoint: migration must not drain it,
    or the half-open probes would have nothing to restore."""
    router, planner, apps, _, (hot0, _) = make_world()
    ctl = FleetController(router, planner, apps,
                          placement=planner.plan(apps), tick_s=TICK_S)
    router.health["hot0"].quarantine("died")
    ctl.replan(tick=5, failed="hot")
    assert not hot0.draining
    assert ctl.placement.by_app["a0"] == "cool"


# ------------------------------------------------------------------ resize
def test_elastic_resize_event_triggers_a_replan():
    from repro.runtime.elastic import ResizeEvent, detect_resize
    assert detect_resize(None, 4) is None        # first observation
    assert detect_resize(4, 4) is None           # stable
    ev = detect_resize(4, 2, tick=17)
    assert ev == ResizeEvent(tick=17, n_before=4, n_after=2)
    assert not ev.grew and detect_resize(2, 4, tick=18).grew

    router, planner, apps, _, _ = make_world()
    ctl = FleetController(router, planner, apps,
                          placement=planner.plan(apps), tick_s=TICK_S)
    out = ctl.on_resize(ev)
    assert out.feasible
    kinds = [e["event"] for e in ctl.events]
    assert kinds == ["resize", "replan"]
    assert ctl.events[0]["n_after"] == 2


# ----------------------------------------------------- metrics observation
def test_metrics_report_refusal_reasons_and_endpoint_percentiles():
    """All endpoints quarantined => the refusal says so (not a generic
    infeasibility), and completed requests feed per-endpoint p50/p95."""
    router, planner, apps, _, _ = make_world()
    loop = ControlLoop(
        router, [req(f"r{i}", i) for i in range(8)],
        injector=FaultInjector([
            Fault(kind="kill", endpoint="hot0", at_tick=2, until_tick=6),
            Fault(kind="latency", endpoint="cool0", at_tick=0, factor=2.0),
        ]), tick_s=TICK_S, max_ticks=120)
    out = loop.run()
    assert out["completed"] == 8 and out["dropped"] == []
    summary = router.metrics.summary()
    assert summary["refusals"] == out["refusals"]
    eps = summary["endpoints"]
    assert set(eps) <= {"hot0", "cool0"} and "cool0" in eps
    for name, s in eps.items():
        assert s["completed"] >= 1
        assert 0.0 <= s["latency_p50_s"] <= s["latency_p95_s"]
    # per-arch observation is stamped on every request record
    assert all(m.arch == "m0" for m in router.metrics.requests.values())


def test_all_endpoints_quarantined_refuses_with_the_right_reason():
    router, planner, apps, _, _ = make_world()
    for h in router.health.values():
        h.quarantine("chaos")
    d = router.route(req("r0", 0))
    assert not d.accepted and d.reason == "endpoint quarantined"
    assert router.metrics.refusals["endpoint quarantined"] == 1


def test_observed_apps_splits_load_across_apps_sharing_an_arch():
    apps = [FleetApp(name="a", arch="m"), FleetApp(name="b", arch="m"),
            FleetApp(name="c", arch="other", load_rps=7.0)]
    out = observed_apps(apps, {"m": 10.0})
    assert [a.load_rps for a in out] == pytest.approx([5.0, 5.0, 7.0])
    assert [a.name for a in out] == ["a", "b", "c"]
    assert observed_apps(apps, {})[2].load_rps == 7.0


def test_power_spike_fault_shows_up_in_the_draw_trace():
    router, planner, apps, _, _ = make_world()
    spike = Fault(kind="power_spike", endpoint="hot0", at_tick=0,
                  until_tick=5, factor=123.0)
    loop = ControlLoop(router, [req("r0", 0)],
                       injector=FaultInjector([spike]), tick_s=TICK_S)
    out = loop.run()
    assert out["completed"] == 1
    assert out["fleet_draw_w_max"] >= 123.0
    assert out["fleet_draw_w_min"] >= 0.0
