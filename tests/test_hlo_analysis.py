"""Loop-aware HLO analyzer: flops within tolerance of analytic counts."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.hlo_analysis import analyze_hlo
from repro.core import cost_model


def test_scanned_matmul_flops_scaled_by_trip_count():
    L, B, D = 7, 64, 128

    def f(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h.sum()

    comp = jax.jit(jax.grad(f)).lower(
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
        jax.ShapeDtypeStruct((B, D), jnp.float32)).compile()
    res = analyze_hlo(comp.as_text())
    fwd = 2 * L * B * D * D
    # fwd + bwd(2x) = 3x fwd, within 40% (elementwise + loss noise)
    assert fwd * 2.0 < res["flops"] < fwd * 4.5, res["flops"]
    # XLA's own counter misses the loop factor
    from repro.dist.compat import cost_analysis_dict
    xla = cost_analysis_dict(comp)["flops"]
    assert res["flops"] > 2.5 * xla


def test_single_matmul_flops_exact():
    def f(a, b):
        return a @ b

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 32), jnp.float32)).compile()
    res = analyze_hlo(comp.as_text())
    assert res["flops"] == pytest.approx(2 * 64 * 128 * 32, rel=0.05)


def test_bytes_lower_bounded_by_io():
    def f(a, b):
        return a @ b

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 32), jnp.float32)).compile()
    res = analyze_hlo(comp.as_text())
    io_bytes = 4 * (64 * 128 + 128 * 32 + 64 * 32)
    assert res["bytes"] >= io_bytes * 0.9


def test_roofline_terms_and_dominance():
    rl = cost_model.roofline_terms(
        1e12, 1e9, 1e6, n_chips=256, model_flops=2e14)
    assert rl.compute_s == pytest.approx(1e12 / cost_model.PEAK_FLOPS)
    assert rl.memory_s == pytest.approx(1e9 / cost_model.HBM_BW)
    assert rl.collective_s == pytest.approx(1e6 / cost_model.ICI_BW)
    assert rl.dominant == "compute"
    assert rl.step_time_s == rl.compute_s
    assert 0 < rl.roofline_fraction <= 1.0


def test_model_flops_train_vs_decode():
    from repro.configs import ARCHS, SHAPES
    cfg = ARCHS["granite-3-2b"]
    t = cost_model.model_flops_for(cfg, SHAPES["train_4k"])
    d = cost_model.model_flops_for(cfg, SHAPES["decode_32k"])
    assert t == pytest.approx(6 * cfg.n_params() * 256 * 4096, rel=1e-6)
    assert d == pytest.approx(2 * cfg.n_params() * 128, rel=1e-6)


def test_moe_uses_active_params():
    from repro.configs import ARCHS, SHAPES
    cfg = ARCHS["arctic-480b"]
    assert cfg.active_params() < 0.2 * cfg.n_params()
    t = cost_model.model_flops_for(cfg, SHAPES["train_4k"])
    assert t == pytest.approx(6 * cfg.active_params() * 256 * 4096,
                              rel=1e-6)
