"""Assemble EXPERIMENTS.md from the collected experiment artifacts.

    PYTHONPATH=src python scripts/build_experiments_md.py
"""
import io
import json
import subprocess
import sys
from contextlib import redirect_stdout
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRY = ROOT / "experiments" / "dryrun"
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))


def roofline_md() -> str:
    from benchmarks import roofline
    buf = io.StringIO()
    with redirect_stdout(buf):
        roofline.main()
    return buf.getvalue()


def dryrun_stats():
    ok = skip = err = 0
    compile_times = []
    for f in DRY.glob("*.json"):
        r = json.loads(f.read_text())
        if r.get("plan") not in (None, "auto", "baseline"):
            continue
        if "skip" in r:
            skip += 1
        elif "error" in r:
            err += 1
        else:
            ok += 1
            compile_times.append(r["compile_s"])
    return ok, skip, err, compile_times


def fig3_md() -> str:
    p = ROOT / "experiments" / "fig3_results.json"
    if not p.exists():
        return "(run `python -m benchmarks.run` first)\n"
    res = json.loads(p.read_text())
    out = ["| app | single-core | selected destination | method | time | "
           "modeled (mesh) | improvement | correct | runner-up |",
           "|---|---|---|---|---|---|---|---|---|"]

    def modeled_of(rec):
        m = rec.get("mesh_time_s")
        try:
            return f"{float(m)*1e6:.1f} us"
        except (TypeError, ValueError):
            return "—"

    for app, r in res.items():
        sel = r["selected"]
        if sel is None:      # no correct candidate survived verification
            out.append(f"| {app} | {r['ref_time_s']*1e3:.2f} ms | — | — "
                       f"| — | — | — | all penalized | — |")
            continue
        others = sorted((x for x in r["records"]
                         if x["best_time_s"] < 1e30
                         and x["order"] != sel["order"]),
                        key=lambda x: x["best_time_s"])
        runner = (f"{others[0]['paper_analogue']}/{others[0]['method']} "
                  f"x{others[0]['improvement']:.1f}" if others else "—")
        n_penalized = sum(not x.get("correct", True) for x in r["records"])
        correct = ("yes" if sel.get("correct", True) else "PENALIZED")
        if n_penalized:
            correct += f" ({n_penalized} penalized rec.)"
        out.append(
            f"| {app} | {r['ref_time_s']*1e3:.2f} ms "
            f"| **{sel['paper_analogue']}** | {sel['method']} "
            f"| {sel['best_time_s']*1e3:.2f} ms | {modeled_of(sel)} "
            f"| x{sel['improvement']:.2f} | {correct} | {runner} |")
    return "\n".join(out) + "\n"


def modeled_md() -> str:
    p = ROOT / "experiments" / "modeled_fig3.json"
    if not p.exists():
        return ""
    rows = json.loads(p.read_text())
    out = ["| app | destination | modeled step | dominant |",
           "|---|---|---|---|"]
    best = {}
    for r in rows:
        best.setdefault(r["app"], []).append(r)
    for app, rs in best.items():
        fastest = min(rs, key=lambda r: r["step_time_s"])
        for r in rs:
            mark = " **(selected)**" if r is fastest else ""
            out.append(f"| {app} | {r['destination']}{mark} "
                       f"| {r['step_time_s']*1e6:.1f} us | {r['dominant']} |")
    return "\n".join(out) + "\n"


def ga_md() -> str:
    p = ROOT / "experiments" / "ga_convergence.json"
    if not p.exists():
        return ""
    hist = json.loads(p.read_text())
    out = ["| generation | best time (ms) | correct individuals |",
           "|---|---|---|"]
    for h in hist:
        out.append(f"| {h['generation']} | {h['best_time_s']*1e3:.2f} "
                   f"| {h['n_correct']}/{len(hist)} |")
    return "\n".join(out) + "\n"


TEMPLATE = open(ROOT / "scripts" / "experiments_template.md").read()

ok, skip, err, ct = dryrun_stats()
subs = {
    "{n_ok}": str(ok), "{n_skip}": str(skip), "{n_err}": str(err),
    "{compile_min}": f"{min(ct):.1f}", "{compile_max}": f"{max(ct):.1f}",
    "{compile_mean}": f"{sum(ct)/len(ct):.1f}",
    "{fig3}": fig3_md(), "{modeled}": modeled_md(), "{ga}": ga_md(),
    "{roofline}": roofline_md(),
}
body = TEMPLATE
for k, v in subs.items():
    body = body.replace(k, v)
(ROOT / "EXPERIMENTS.md").write_text(body)
print(f"EXPERIMENTS.md written ({ok} ok / {skip} skip / {err} err cells)")
